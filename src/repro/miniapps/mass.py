"""MASS — Mini-App for Stream Source (paper §5).

Pluggable, tunable data producers: message rate, message size, serialization
and compression are all configuration. Two base source types as in the
paper — ``cluster`` (random points around centroids, for streaming-ML
workloads) and ``template`` (replays a payload, e.g. an APS-format
light-source frame) — plus a ``tokens`` source for the LM workloads.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.broker.cluster import BrokerCluster
from repro.broker.producer import Producer


@dataclass
class SourceConfig:
    topic: str
    rate_msgs_per_s: float | None = None  # None = as fast as possible
    total_messages: int | None = None
    n_producers: int = 1
    compress: bool = False
    seed: int = 0
    #: keyed=True pins each producer to one partition (ordering per source);
    #: False round-robins across partitions/broker nodes (max throughput)
    keyed: bool = False


class StreamSource:
    """Base: runs ``n_producers`` producer threads against the broker."""

    serializer = "npy"

    def __init__(self, cluster: BrokerCluster, config: SourceConfig):
        self.cluster = cluster
        self.config = config
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self.producers: list[Producer] = []

    def make_message(self, rng: np.random.Generator, i: int) -> Any:
        raise NotImplementedError

    def make_timestamp(self, rng: np.random.Generator, i: int) -> float | None:
        """Event timestamp for message ``i`` (None = broker stamps wall
        clock, the default). Override with a logical clock to make
        event-time windowing reproducible — rescale chaos tests compare
        window firings bit-for-bit across runs, which wall-clock stamps
        cannot provide."""
        return None

    def _produce(self, worker: int) -> None:
        cfg = self.config
        rng = np.random.default_rng(cfg.seed + worker)
        rate = cfg.rate_msgs_per_s / cfg.n_producers if cfg.rate_msgs_per_s else None
        prod = Producer(
            self.cluster, cfg.topic, serializer=self.serializer,
            compress=cfg.compress, rate_msgs_per_s=rate,
        )
        self.producers.append(prod)
        quota = None if cfg.total_messages is None else cfg.total_messages // cfg.n_producers
        key = str(worker).encode() if cfg.keyed else None
        i = 0
        while not self._stop.is_set() and (quota is None or i < quota):
            if self.config.rate_msgs_per_s == 0:  # paused, not unthrottled
                self._stop.wait(0.01)
                continue
            prod.send(self.make_message(rng, i), key=key,
                      timestamp=self.make_timestamp(rng, i))
            i += 1

    def start(self) -> "StreamSource":
        for w in range(self.config.n_producers):
            t = threading.Thread(target=self._produce, args=(w,), daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def join(self, timeout: float | None = None) -> None:
        for t in self._threads:
            t.join(timeout)

    @property
    def finished(self) -> bool:
        """True once every producer thread has run to completion (only
        finite sources — ``total_messages`` set — ever finish)."""
        return bool(self._threads) and all(
            not t.is_alive() for t in self._threads
        )

    def stop(self) -> None:
        self._stop.set()
        self.join(1.0)

    def set_rate(self, rate_msgs_per_s: float | None) -> None:
        """Change the aggregate production rate at runtime.

        ``None`` = unthrottled, ``0`` = paused (producer threads idle until
        the rate is raised again — NOT unthrottled). Producers read their
        limiter per send, so live threads pick the new rate up on the next
        message — this is what rate-step elasticity scenarios drive.
        """
        self.config.rate_msgs_per_s = rate_msgs_per_s
        per = rate_msgs_per_s / self.config.n_producers if rate_msgs_per_s else None
        for p in self.producers:
            p.rate = per

    @property
    def sent_records(self) -> int:
        return sum(p.sent_records for p in self.producers)

    @property
    def sent_bytes(self) -> int:
        return sum(p.sent_bytes for p in self.producers)


class KMeansClusterSource(StreamSource):
    """Paper's ``cluster`` source: points drawn around ``n_clusters``
    centroids; 5000 x 3-D doubles per message ≈ 0.12 MB (the paper's 0.3 MB
    at string serialization; binary npy here)."""

    def __init__(self, cluster, config, *, n_clusters: int = 10, dim: int = 3,
                 points_per_msg: int = 5000, spread: float = 0.5):
        super().__init__(cluster, config)
        rng = np.random.default_rng(config.seed + 10_000)
        self.centers = rng.uniform(-10, 10, size=(n_clusters, dim))
        self.points_per_msg = points_per_msg
        self.spread = spread

    def make_message(self, rng, i):
        k = rng.integers(0, len(self.centers), size=self.points_per_msg)
        pts = self.centers[k] + rng.normal(0, self.spread, size=(self.points_per_msg, self.centers.shape[1]))
        return pts.astype(np.float64)


class KMeansStaticSource(StreamSource):
    """Paper's ``KMeans-static``: one pre-generated message replayed at the
    configured rate (isolates broker throughput from RNG cost — the paper
    measured 1.6x higher throughput vs KMeans-random)."""

    def __init__(self, cluster, config, *, dim: int = 3, points_per_msg: int = 5000):
        super().__init__(cluster, config)
        rng = np.random.default_rng(config.seed)
        self._payload = rng.normal(size=(points_per_msg, dim)).astype(np.float64)

    def make_message(self, rng, i):
        return self._payload


class LightsourceTemplateSource(StreamSource):
    """Paper's ``template``/light-source source: replays a synthetic
    sinogram frame ("APS data format" analog); ~2 MB per message at the
    paper's sizes (n_angles x n_det f32)."""

    def __init__(self, cluster, config, *, n_angles: int = 360, n_det: int = 1448):
        super().__init__(cluster, config)
        from repro.kernels.tomo import project_ref, shepp_logan
        import jax.numpy as jnp

        n = min(n_det, 128)  # synthesize at modest resolution, tile up
        img = shepp_logan(n)
        angles = jnp.linspace(0, jnp.pi, n_angles, endpoint=False)
        sino = np.asarray(project_ref(img, angles, n))
        reps = int(np.ceil(n_det / sino.shape[1]))
        self._payload = np.tile(sino, (1, reps))[:, :n_det].astype(np.float32)

    def make_message(self, rng, i):
        return self._payload


class TokenSource(StreamSource):
    """LM token stream: (seqs_per_msg, seq_len) int32 batches (Type 2
    coupling — a simulation/corpus feeding streaming training)."""

    def __init__(self, cluster, config, *, vocab_size: int, seq_len: int, seqs_per_msg: int = 8):
        super().__init__(cluster, config)
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seqs_per_msg = seqs_per_msg

    def make_message(self, rng, i):
        # zipfian-ish synthetic text: heavy head, long tail
        z = rng.zipf(1.3, size=(self.seqs_per_msg, self.seq_len))
        return np.minimum(z - 1, self.vocab_size - 1).astype(np.int32)


@dataclass
class RateStep:
    """Hold ``rate_msgs_per_s`` (None = unthrottled, 0 = paused) for
    ``duration`` seconds."""

    duration: float
    rate_msgs_per_s: float | None


class RateStepScenario:
    """Drives a source through a rate schedule — the workload generator for
    dynamic-resourcing experiments (paper Fig. 8: step the producer rate up,
    watch the autoscaler grow the pilot; step it down, watch it shrink).

    ``steps`` accepts :class:`RateStep` or bare ``(duration, rate)`` tuples.
    Transitions are recorded as ``(t_monotonic, rate)`` in ``transitions``
    so tests/benchmarks can line them up against MetricsBus history.
    """

    def __init__(self, source: StreamSource, steps: list, *, loop: bool = False):
        self.source = source
        self.steps = [s if isinstance(s, RateStep) else RateStep(*s) for s in steps]
        self.loop = loop
        self.transitions: list[tuple[float, float | None]] = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _run(self) -> None:
        while True:
            for step in self.steps:
                if self._stop.is_set():
                    return
                self.source.set_rate(step.rate_msgs_per_s)
                self.transitions.append((time.monotonic(), step.rate_msgs_per_s))
                if self._stop.wait(step.duration):
                    return
            if not self.loop:
                return

    def start(self) -> "RateStepScenario":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    @property
    def finished(self) -> bool:
        return self._thread is not None and not self._thread.is_alive()

    def stop(self) -> None:
        self._stop.set()
        self.join(1.0)

    @property
    def total_duration(self) -> float:
        return sum(s.duration for s in self.steps)


SOURCES: dict[str, type[StreamSource]] = {
    "cluster": KMeansClusterSource,
    "static": KMeansStaticSource,
    "lightsource": LightsourceTemplateSource,
    "tokens": TokenSource,
}

"""MASA — Mini-App for Streaming Analysis (paper §5).

Pluggable processors for the micro-batch engine:

* ``StreamingKMeans``   — score + decayed centroid update (paper Table 1)
* ``ReconstructionApp`` — GridRec / ML-EM per frame (paper §3.2.2, Fig. 9)
* ``LMTrainApp``        — streaming LM training (micro-batch train_step)
* ``LMServeApp``        — streaming LM inference (prefill/decode)

Each exposes ``process(state, msgs) -> state`` for
``MicroBatchPlugin.stream`` plus an ``on_rescale(devices)`` hook used by the
elastic path (live state resharding).

Hot-path design (docs/perf.md): variable-length batches are padded to a
small set of shape buckets so steady state never recompiles; per-message
Python loops are replaced with stacked/vmapped per-micro-batch calls;
results are double-buffered (``streaming.dispatch.AsyncWindow``) so batch
N+1 dispatches while N executes, syncing only at stats/checkpoint/rescale
boundaries; and ``use_kernel=True`` routes through the Pallas kernels
(native on TPU, interpret-mode fallback elsewhere).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import kmeans as kmeans_ops
from repro.kernels import tomo as tomo_ops
from repro.streaming.dispatch import (
    AsyncWindow,
    LatencyWindow,
    ShapeBuckets,
    compile_count,
    kernel_interpret,
    pad_rows,
)


@dataclass
class AppStats:
    messages: int = 0
    items: int = 0
    batches: int = 0
    compute_time: float = 0.0
    latency: LatencyWindow = field(default_factory=LatencyWindow)

    @property
    def msgs_per_sec(self) -> float:
        return self.messages / self.compute_time if self.compute_time else 0.0


class _HotPathApp:
    """Shared double-buffering plumbing for the MASA processors.

    Subclasses dispatch work with :meth:`_submit` and override
    :meth:`_on_complete` to fold a finished batch's (tiny, already-computed)
    outputs into their exposed attributes. ``sync()`` is the barrier the
    engine calls at checkpoint/rescale boundaries; stats accessors that need
    completed results call it implicitly.
    """

    def _init_hotpath(self, *, async_depth: int = 2, metrics: Any = None,
                      name: str | None = None) -> None:
        self.stats = AppStats()
        self.metrics = metrics
        self._metrics_name = name or type(self).__name__
        self._window = AsyncWindow(async_depth, self.stats.latency)

    def _submit(self, result: Any, meta: Any = None, t0: float | None = None) -> None:
        """Enqueue a dispatched batch; ``t0`` = start of the batch's host
        work, so drained latencies span prep+compute. ``compute_time`` sums
        those per-batch completion latencies — identical to the legacy
        block-every-batch accounting at depth 0, and the honest per-batch
        cost (not mere dispatch time) when batches overlap."""
        for res, m, dt in self._window.push(result, meta, t0=t0):
            self.stats.compute_time += dt
            self._on_complete(res, m, dt)
            self._publish_latency()

    def sync(self) -> None:
        """Block until every in-flight batch has completed (the
        stats/checkpoint/rescale barrier — see docs/perf.md)."""
        done = self._window.sync()
        if not done:
            return
        for res, m, dt in done:
            self.stats.compute_time += dt
            self._on_complete(res, m, dt)
        self._publish_latency()

    def _on_complete(self, result: Any, meta: Any, dt: float) -> None:
        pass

    def reset_stats(self) -> None:
        """Sync and zero the counters (benchmarks: exclude warmup batches)."""
        self.sync()
        self.stats = AppStats()
        self._window.latency = self.stats.latency

    def _publish_latency(self) -> None:
        if self.metrics is None or len(self.stats.latency) == 0:
            return
        lat, labels = self.stats.latency, {"app": self._metrics_name}
        self.metrics.publish("app.latency_p50", lat.p50, **labels)
        self.metrics.publish("app.latency_p99", lat.p99, **labels)

    @property
    def in_flight(self) -> int:
        return self._window.in_flight


class StreamingKMeans(_HotPathApp):
    """Assign incoming points to centroids, update the model with decay.

    ``bucketed=True`` pads each batch up to a power-of-two row bucket and
    runs the masked update — bit-identical centroids, at most
    ``len(buckets)`` compiles regardless of how batch sizes vary.
    ``bucketed=False, async_depth=0`` reproduces the legacy one-compile-per-
    shape, block-every-batch behavior (the benchmark baseline).
    """

    def __init__(self, n_clusters: int = 10, dim: int = 3, *, decay: float = 0.9,
                 use_kernel: bool = False, seed: int = 0,
                 bucketed: bool = True, buckets: ShapeBuckets | None = None,
                 async_depth: int = 2, interpret: bool | None = None,
                 metrics: Any = None):
        rng = np.random.default_rng(seed)
        self.centroids = jnp.asarray(rng.normal(size=(n_clusters, dim)), jnp.float32)
        self.decay = decay
        self.use_kernel = use_kernel
        self.bucketed = bucketed
        self.buckets = buckets or ShapeBuckets(min_size=512, max_size=65536)
        self._init_hotpath(async_depth=async_depth, metrics=metrics, name="kmeans")
        self._inertia = float("nan")
        if interpret is None:
            interpret = kernel_interpret()
        self._step = jax.jit(functools.partial(
            kmeans_ops.minibatch_update_masked,
            decay=decay, use_kernel=use_kernel, interpret=interpret,
        ))
        self._step_legacy = jax.jit(functools.partial(
            kmeans_ops.minibatch_update,
            decay=decay, use_kernel=use_kernel, interpret=interpret,
        ))

    def process(self, state, msgs):
        centroids = state if state is not None else self.centroids
        pts = np.concatenate([np.asarray(m.value) for m in msgs]).astype(np.float32)
        n = pts.shape[0]
        t0 = time.monotonic()
        if self.bucketed:
            padded = pad_rows(pts, self.buckets.fit(n))
            # n is a dynamic scalar: every size sharing a bucket reuses the
            # same executable
            centroids, labels, inertia = self._step(jnp.asarray(padded), centroids, n)
        else:
            centroids, labels, inertia = self._step_legacy(jnp.asarray(pts), centroids)
        self.stats.messages += len(msgs)
        self.stats.items += n
        self.stats.batches += 1
        self._submit(centroids, meta=(inertia, n), t0=t0)
        return centroids

    def _on_complete(self, result, meta, dt):
        inertia, n = meta
        self._inertia = float(inertia) / max(n, 1)

    @property
    def inertia(self) -> float:
        """Mean inertia of the most recent batch (syncs in-flight work)."""
        self.sync()
        return self._inertia

    @property
    def compiles(self) -> int:
        return compile_count(self._step if self.bucketed else self._step_legacy)

    def on_rescale(self, devices):
        # centroids are tiny: re-placement is a device_put
        def f(state):
            return jax.device_put(state, devices[0]) if state is not None else state
        return f


class ReconstructionApp(_HotPathApp):
    """Per-frame tomographic reconstruction (GridRec or ML-EM).

    ``batched=True`` groups a micro-batch's frames by sinogram shape, stacks
    each group and reconstructs it in one vmapped call, padding the stack
    depth to a small bucket set so compile count stays bounded.
    ``batched=False, async_depth=0`` is the legacy per-message loop.
    """

    def __init__(self, algorithm: str = "gridrec", *, n: int = 64, mlem_iters: int = 4,
                 use_kernel: bool = False, batched: bool = True,
                 batch_buckets: ShapeBuckets | None = None, async_depth: int = 2,
                 interpret: bool | None = None, metrics: Any = None):
        assert algorithm in ("gridrec", "mlem")
        self.algorithm = algorithm
        self.n = n
        self.use_kernel = use_kernel
        self.batched = batched
        self.batch_buckets = batch_buckets or ShapeBuckets(min_size=1, max_size=8)
        self._init_hotpath(async_depth=async_depth, metrics=metrics, name=algorithm)
        self._angles_cache: dict[int, jax.Array] = {}
        if interpret is None:
            interpret = kernel_interpret()
        if algorithm == "gridrec":
            one = functools.partial(tomo_ops.gridrec, n=n,
                                    use_kernel=use_kernel, interpret=interpret)
            many = functools.partial(tomo_ops.gridrec_batch, n=n,
                                     use_kernel=use_kernel, interpret=interpret)
        else:
            one = functools.partial(tomo_ops.mlem, n=n, iters=mlem_iters,
                                    use_kernel=use_kernel, interpret=interpret)
            many = functools.partial(tomo_ops.mlem_batch, n=n, iters=mlem_iters,
                                     use_kernel=use_kernel, interpret=interpret)
        self._rec = jax.jit(one)
        self._rec_batch = jax.jit(many)

    def _angles(self, n_angles: int) -> jax.Array:
        """Per-shape cache: the same angle grid is re-used for every frame of
        that sinogram shape instead of re-materializing per message."""
        a = self._angles_cache.get(n_angles)
        if a is None:
            a = self._angles_cache[n_angles] = jnp.linspace(
                0, jnp.pi, n_angles, endpoint=False)
        return a

    def process(self, state, msgs):
        t0 = time.monotonic()
        if self.batched:
            recon = self._process_batched(msgs)
        else:
            recon = self._process_loop(msgs)
        self.stats.messages += len(msgs)
        self.stats.items += len(msgs)
        self.stats.batches += 1
        self._submit(recon, t0=t0)
        return recon  # last reconstruction = state (exposed for inspection)

    def _process_batched(self, msgs):
        groups: dict[tuple, list[np.ndarray]] = {}
        for m in msgs:
            sino = np.asarray(m.value, np.float32)
            groups.setdefault(sino.shape, []).append(sino)
        last_shape = np.asarray(msgs[-1].value).shape
        recon = None
        for shape, frames in groups.items():
            angles = self._angles(shape[0])
            if len(frames) == 1:
                # the scalar path beats a B=1 batched matmul (degenerate gemm)
                rec = self._rec(jnp.asarray(frames[0]), angles)
            else:
                stack = pad_rows(np.stack(frames), self.batch_buckets.fit(len(frames)))
                rec = self._rec_batch(jnp.asarray(stack), angles)[len(frames) - 1]
            # state contract: the LAST message's reconstruction (its frame is
            # the last element of its shape group)
            if shape == last_shape:
                recon = rec
        return recon

    def _process_loop(self, msgs):
        recon = None
        for m in msgs:
            sino = jnp.asarray(np.asarray(m.value), jnp.float32)
            angles = jnp.linspace(0, jnp.pi, sino.shape[0], endpoint=False)
            recon = self._rec(sino, angles)
        return recon

    @property
    def compiles(self) -> int:
        return compile_count(self._rec_batch if self.batched else self._rec)


class LMTrainApp(_HotPathApp):
    """Streaming LM training: consume token messages, run train steps.

    State = (params, opt_state); rescale re-lowers the step on a new mesh
    and device_puts the live state (checkpoint-free migration). The train
    step donates params/opt-state buffers, and per-step losses are read
    back lazily at sync boundaries instead of forcing a device round-trip
    per batch.
    """

    def __init__(self, cfg, *, mesh=None, opt_cfg=None, seqs_per_step: int = 8,
                 seq_len: int = 128, async_depth: int = 2, metrics: Any = None):
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model
        from repro.configs.base import ShapeConfig
        from repro.runtime.steps import build_train_step

        self.cfg = cfg
        self.model = build_model(cfg)
        self.mesh = mesh or make_local_mesh()
        self.shape = ShapeConfig("stream", seq_len, seqs_per_step, "train")
        self.opt_cfg = opt_cfg
        self.bundle = build_train_step(self.model, self.mesh, self.shape, opt_cfg, donate=True)
        self._init_hotpath(async_depth=async_depth, metrics=metrics, name="lm_train")
        self._losses: list[float] = []

    def init_state(self, seed: int = 0):
        from repro.runtime.optimizer import Optimizer, OptimizerConfig

        params = self.model.init(jax.random.key(seed))
        opt = Optimizer(self.opt_cfg or OptimizerConfig(name=self.cfg.optimizer))
        return {"params": params, "opt": opt.init(params)}

    def process(self, state, msgs):
        if state is None:
            state = self.init_state()
        toks = np.concatenate([np.asarray(m.value) for m in msgs])  # (n_seqs, S)
        B = self.shape.global_batch
        n_steps = len(toks) // B
        t0 = time.monotonic()
        for s in range(max(n_steps, 1)):
            batch = toks[s * B : (s + 1) * B]
            if len(batch) < B:  # pad the tail window
                batch = np.concatenate([batch, np.zeros((B - len(batch), batch.shape[1] if batch.size else self.shape.seq_len), np.int32)])
            params, opt, metrics = self.bundle.fn(
                state["params"], state["opt"], {"tokens": jnp.asarray(batch, jnp.int32)}
            )
            state = {"params": params, "opt": opt}
        self.stats.messages += len(msgs)
        self.stats.items += int(len(toks)) * self.shape.seq_len
        self.stats.batches += 1
        self._submit(metrics["loss"], t0=t0)
        return state

    def _on_complete(self, result, meta, dt):
        self._losses.append(float(result))

    @property
    def losses(self) -> list[float]:
        """Per-batch final-step losses (syncs in-flight work)."""
        self.sync()
        return self._losses

    @property
    def compiles(self) -> int:
        return compile_count(self.bundle.fn)

    def on_rescale(self, devices):
        """Elastic: rebuild mesh over the new device set, reshard live state."""
        from repro.launch.mesh import make_mesh
        from repro.runtime.steps import build_train_step

        def f(state):
            self.sync()  # in-flight steps must land before buffers move
            n = len(devices)
            self.mesh = make_mesh((n, 1), ("data", "model"))
            self.bundle = build_train_step(self.model, self.mesh, self.shape, self.opt_cfg, donate=True)
            if state is not None:
                p_sh, o_sh, _ = self.bundle.in_shardings
                state = {
                    "params": jax.device_put(state["params"], p_sh),
                    "opt": jax.device_put(state["opt"], o_sh),
                }
            return state

        return f


class LMServeApp(_HotPathApp):
    """Streaming LM inference: prefill each request batch, decode n tokens.

    ``mode="lockstep"`` (default): the whole micro-batch's requests are
    stacked into one prefill (rows padded to a bucket) and the per-token
    decode loop runs as one fused ``lax.scan`` with the KV cache donated
    between steps — every row enters and exits together.

    ``mode="continuous"``: requests go through the in-flight batching
    scheduler (``repro.serving.ContinuousBatcher``) — prompts prefill into
    paged KV-cache slots and join the live decode batch mid-stream, finished
    rows exit per step and free their pages immediately. Same greedy tokens
    (see docs/serving.md for the equivalence argument), radically different
    tail latency under heavy-tail prompt lengths.
    """

    def __init__(self, cfg, *, mesh=None, prompt_len: int = 32, gen_tokens: int = 8,
                 batch: int = 4, async_depth: int = 2, metrics: Any = None,
                 row_buckets: ShapeBuckets | None = None, mode: str = "lockstep",
                 n_pages: int = 256, page_size: int = 16,
                 use_kernel: bool = False, interpret: bool | None = None):
        from repro.models import build_model

        assert mode in ("lockstep", "continuous"), mode
        self.cfg = cfg
        self.model = build_model(cfg)
        # single-host serving jits the model directly; a mesh is only needed
        # when the caller shards params explicitly, so none is built here
        self.mesh = mesh
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self.batch = batch
        self.mode = mode
        self.row_buckets = row_buckets or ShapeBuckets(min_size=batch, max_size=batch * 8)
        self._init_hotpath(async_depth=async_depth, metrics=metrics, name="lm_serve")
        # cache sized for prompt + generation inside the jitted path: growing
        # it afterwards (jnp.pad on the host) copied the entire KV cache per
        # batch (see _prefill_grown)
        self._prefill = jax.jit(self._prefill_grown)
        # donate the KV cache: each scan step reuses the same buffers
        self._generate = jax.jit(self._generate_impl, donate_argnums=(1,))
        self._batcher = None
        if mode == "continuous":
            from repro.serving import ContinuousBatcher

            self._batcher = ContinuousBatcher(
                self.model, n_pages=n_pages, page_size=page_size,
                use_kernel=use_kernel, interpret=interpret,
                max_queue=max(64, batch * 16), metrics=metrics)
            self._rid = 0
            self._now = 0.0

    def _prefill_grown(self, params, batch):
        """Prefill with the KV cache allocated at prompt_len + gen_tokens —
        the pad happens inside the jit, so XLA materializes the full-size
        cache once instead of prefill-size buffers plus a host-side copy."""
        logits, cache = self.model.prefill(params, batch)
        cache = jax.tree.map(
            lambda c: jnp.pad(
                c, [(0, 0)] * 2 + [(0, self.gen_tokens)] + [(0, 0)] * (c.ndim - 3))
            if c.ndim >= 4 else c,
            cache,
        )
        return logits, cache

    def _generate_impl(self, params, cache, tok, pos):
        def step(carry, _):
            tok, pos, cache = carry
            pos = pos + 1
            logits, cache = self.model.decode(params, cache, {"tokens": tok, "positions": pos})
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (tok, pos, cache), tok

        (tok, _, _), toks = jax.lax.scan(
            step, (tok, pos, cache), None, length=self.gen_tokens - 1)
        return toks  # (gen_tokens-1, B, 1)

    def _stack_requests(self, msgs) -> np.ndarray:
        """(sum_i b_i, prompt_len) int32: every message's requests in one
        batch, right-padded to prompt_len columns."""
        rows = []
        for m in msgs:
            t = np.asarray(m.value)[: self.batch, : self.prompt_len].astype(np.int32)
            if t.shape[1] < self.prompt_len:
                t = np.pad(t, [(0, 0), (0, self.prompt_len - t.shape[1])])
            rows.append(t)
        return np.concatenate(rows)

    def _serve_batch(self, params, msgs):
        """One stacked prefill + fused scan decode for a whole micro-batch.
        Returns (seq (gen_tokens, B, 1) greedy tokens, n_req live rows)."""
        toks = self._stack_requests(msgs)
        n_req = toks.shape[0]
        tok_in = jnp.asarray(pad_rows(toks, self.row_buckets.fit(n_req)))
        logits, cache = self._prefill(params, {"tokens": tok_in})
        pos = jnp.full((tok_in.shape[0],), self.prompt_len - 1, jnp.int32)
        tok0 = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if self.gen_tokens > 1:
            rest = self._generate(params, cache, tok0, pos)  # (T-1, B, 1)
            seq = jnp.concatenate([tok0[None], rest])
        else:
            seq = tok0[None]
        return seq, n_req

    def _serve_continuous(self, params, msgs) -> np.ndarray:
        """Route a micro-batch through the in-flight scheduler; returns
        (n_req, gen_tokens) greedy tokens in request order."""
        from repro.serving.trace import Request

        b = self._batcher
        b.params = params
        toks = self._stack_requests(msgs)
        rids = []
        for row in toks:
            r = Request(self._rid, self._now, tuple(int(t) for t in row),
                        self.gen_tokens)
            self._rid += 1
            verdict = b.submit(r, self._now)
            assert verdict != "reject", "drop-in mode must not shed requests"
            rids.append(r.rid)
            self._now += b.step(self._now)
        self._now = b.drain(self._now)
        return np.array([b.results[r]["tokens"] for r in rids], np.int32)

    def process(self, state, msgs):
        params = state  # serving state = model params
        t0 = time.monotonic()
        if self.mode == "continuous":
            out = self._serve_continuous(params, msgs)
            n_req = out.shape[0]
        else:
            out, n_req = self._serve_batch(params, msgs)
        self.stats.messages += len(msgs)
        self.stats.items += n_req * self.gen_tokens
        self.stats.batches += 1
        self._submit(out, t0=t0)
        return params

    def generate_tokens(self, params, msgs) -> np.ndarray:
        """Greedy tokens for a message batch: (n_req, gen_tokens) int32.
        Convenience/inspection path; ``process`` is the streaming hot path."""
        if self.mode == "continuous":
            return self._serve_continuous(params, msgs)
        seq, n_req = self._serve_batch(params, msgs)
        return np.asarray(seq[:, :n_req, 0]).T

    @property
    def compiles(self) -> int:
        if self.mode == "continuous":
            return self._batcher.decode_compiles
        return compile_count(self._generate)

    @property
    def prefill_compiles(self) -> int:
        """Steady-state contract (satellite of docs/perf.md): one compile per
        row bucket — the in-jit cache growth must not retrigger per batch."""
        if self.mode == "continuous":
            return self._batcher.prefill_compiles
        return compile_count(self._prefill)


PROCESSORS = {
    "kmeans": StreamingKMeans,
    "gridrec": lambda **kw: ReconstructionApp("gridrec", **kw),
    "mlem": lambda **kw: ReconstructionApp("mlem", **kw),
    "lm_train": LMTrainApp,
    "lm_serve": LMServeApp,
}

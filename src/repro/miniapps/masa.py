"""MASA — Mini-App for Streaming Analysis (paper §5).

Pluggable processors for the micro-batch engine:

* ``StreamingKMeans``   — score + decayed centroid update (paper Table 1)
* ``ReconstructionApp`` — GridRec / ML-EM per frame (paper §3.2.2, Fig. 9)
* ``LMTrainApp``        — streaming LM training (micro-batch train_step)
* ``LMServeApp``        — streaming LM inference (prefill/decode)

Each exposes ``process(state, msgs) -> state`` for
``MicroBatchPlugin.stream`` plus an ``on_rescale(devices)`` hook used by the
elastic path (live state resharding).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import kmeans as kmeans_ops
from repro.kernels import tomo as tomo_ops


@dataclass
class AppStats:
    messages: int = 0
    items: int = 0
    batches: int = 0
    compute_time: float = 0.0

    @property
    def msgs_per_sec(self) -> float:
        return self.messages / self.compute_time if self.compute_time else 0.0


class StreamingKMeans:
    """Assign incoming points to centroids, update the model with decay."""

    def __init__(self, n_clusters: int = 10, dim: int = 3, *, decay: float = 0.9,
                 use_kernel: bool = False, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.centroids = jnp.asarray(rng.normal(size=(n_clusters, dim)), jnp.float32)
        self.decay = decay
        self.use_kernel = use_kernel
        self.stats = AppStats()
        self._step = jax.jit(
            lambda pts, cen: kmeans_ops.minibatch_update(
                pts, cen, decay=decay, use_kernel=False
            )
        )

    def process(self, state, msgs):
        centroids = state if state is not None else self.centroids
        pts = jnp.asarray(np.concatenate([np.asarray(m.value) for m in msgs]), jnp.float32)
        t0 = time.monotonic()
        centroids, labels, inertia = self._step(pts, centroids)
        centroids.block_until_ready()
        self.stats.compute_time += time.monotonic() - t0
        self.stats.messages += len(msgs)
        self.stats.items += pts.shape[0]
        self.stats.batches += 1
        self.inertia = float(inertia) / max(pts.shape[0], 1)
        return centroids

    def on_rescale(self, devices):
        # centroids are tiny: re-placement is a device_put
        def f(state):
            return jax.device_put(state, devices[0]) if state is not None else state
        return f


class ReconstructionApp:
    """Per-frame tomographic reconstruction (GridRec or ML-EM)."""

    def __init__(self, algorithm: str = "gridrec", *, n: int = 64, mlem_iters: int = 4,
                 use_kernel: bool = False):
        assert algorithm in ("gridrec", "mlem")
        self.algorithm = algorithm
        self.n = n
        self.stats = AppStats()
        if algorithm == "gridrec":
            self._rec = jax.jit(
                lambda sino, angles: tomo_ops.gridrec(sino, angles, n, use_kernel=False)
            )
        else:
            self._rec = jax.jit(
                lambda sino, angles: tomo_ops.mlem(sino, angles, n, iters=mlem_iters, use_kernel=False)
            )

    def process(self, state, msgs):
        recon = None
        t0 = time.monotonic()
        for m in msgs:
            sino = jnp.asarray(np.asarray(m.value), jnp.float32)
            a = sino.shape[0]
            angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
            recon = self._rec(sino, angles)
        if recon is not None:
            recon.block_until_ready()
        self.stats.compute_time += time.monotonic() - t0
        self.stats.messages += len(msgs)
        self.stats.batches += 1
        return recon  # last reconstruction = state (exposed for inspection)


class LMTrainApp:
    """Streaming LM training: consume token messages, run train steps.

    State = (params, opt_state); rescale re-lowers the step on a new mesh
    and device_puts the live state (checkpoint-free migration).
    """

    def __init__(self, cfg, *, mesh=None, opt_cfg=None, seqs_per_step: int = 8, seq_len: int = 128):
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model
        from repro.configs.base import ShapeConfig
        from repro.runtime.steps import build_train_step

        self.cfg = cfg
        self.model = build_model(cfg)
        self.mesh = mesh or make_local_mesh()
        self.shape = ShapeConfig("stream", seq_len, seqs_per_step, "train")
        self.opt_cfg = opt_cfg
        self.bundle = build_train_step(self.model, self.mesh, self.shape, opt_cfg, donate=False)
        self.stats = AppStats()
        self.losses: list[float] = []

    def init_state(self, seed: int = 0):
        from repro.runtime.optimizer import Optimizer, OptimizerConfig

        params = self.model.init(jax.random.key(seed))
        opt = Optimizer(self.opt_cfg or OptimizerConfig(name=self.cfg.optimizer))
        return {"params": params, "opt": opt.init(params)}

    def process(self, state, msgs):
        if state is None:
            state = self.init_state()
        toks = np.concatenate([np.asarray(m.value) for m in msgs])  # (n_seqs, S)
        B = self.shape.global_batch
        n_steps = len(toks) // B
        t0 = time.monotonic()
        for s in range(max(n_steps, 1)):
            batch = toks[s * B : (s + 1) * B]
            if len(batch) < B:  # pad the tail window
                batch = np.concatenate([batch, np.zeros((B - len(batch), batch.shape[1] if batch.size else self.shape.seq_len), np.int32)])
            params, opt, metrics = self.bundle.fn(
                state["params"], state["opt"], {"tokens": jnp.asarray(batch, jnp.int32)}
            )
            state = {"params": params, "opt": opt}
        jax.block_until_ready(state["params"])
        self.losses.append(float(metrics["loss"]))
        self.stats.compute_time += time.monotonic() - t0
        self.stats.messages += len(msgs)
        self.stats.items += int(len(toks)) * self.shape.seq_len
        self.stats.batches += 1
        return state

    def on_rescale(self, devices):
        """Elastic: rebuild mesh over the new device set, reshard live state."""
        from repro.launch.mesh import make_mesh
        from repro.runtime.steps import build_train_step

        def f(state):
            n = len(devices)
            self.mesh = make_mesh((n, 1), ("data", "model"))
            self.bundle = build_train_step(self.model, self.mesh, self.shape, self.opt_cfg, donate=False)
            if state is not None:
                p_sh, o_sh, _ = self.bundle.in_shardings
                state = {
                    "params": jax.device_put(state["params"], p_sh),
                    "opt": jax.device_put(state["opt"], o_sh),
                }
            return state

        return f


class LMServeApp:
    """Streaming LM inference: prefill each request batch, decode n tokens."""

    def __init__(self, cfg, *, mesh=None, prompt_len: int = 32, gen_tokens: int = 8, batch: int = 4):
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model
        from repro.configs.base import ShapeConfig

        self.cfg = cfg
        self.model = build_model(cfg)
        self.mesh = mesh or make_local_mesh()
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self.batch = batch
        self.stats = AppStats()
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def process(self, state, msgs):
        params = state  # serving state = model params
        t0 = time.monotonic()
        for m in msgs:
            toks = jnp.asarray(np.asarray(m.value)[: self.batch, : self.prompt_len], jnp.int32)
            logits, cache = self._prefill(params, {"tokens": toks})
            # grow cache for generated tokens
            cache = jax.tree.map(
                lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, self.gen_tokens)] + [(0, 0)] * (c.ndim - 3))
                if c.ndim >= 4 else c,
                cache,
            )
            pos = jnp.full((toks.shape[0],), self.prompt_len - 1, jnp.int32)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for _ in range(self.gen_tokens - 1):
                pos = pos + 1
                logits, cache = self._decode(params, cache, {"tokens": tok, "positions": pos})
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok.block_until_ready()
            self.stats.items += int(toks.shape[0]) * self.gen_tokens
        self.stats.compute_time += time.monotonic() - t0
        self.stats.messages += len(msgs)
        self.stats.batches += 1
        return params


PROCESSORS = {
    "kmeans": StreamingKMeans,
    "gridrec": lambda **kw: ReconstructionApp("gridrec", **kw),
    "mlem": lambda **kw: ReconstructionApp("mlem", **kw),
    "lm_train": LMTrainApp,
    "lm_serve": LMServeApp,
}

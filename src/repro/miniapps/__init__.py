"""Streaming Mini-Apps (paper §5): MASS sources + MASA processors."""
from repro.miniapps.mass import (
    SOURCES,
    KMeansClusterSource,
    KMeansStaticSource,
    LightsourceTemplateSource,
    SourceConfig,
    StreamSource,
    TokenSource,
)
from repro.miniapps.masa import (
    PROCESSORS,
    LMServeApp,
    LMTrainApp,
    ReconstructionApp,
    StreamingKMeans,
)

__all__ = [
    "KMeansClusterSource",
    "KMeansStaticSource",
    "LMServeApp",
    "LMTrainApp",
    "LightsourceTemplateSource",
    "PROCESSORS",
    "ReconstructionApp",
    "SOURCES",
    "SourceConfig",
    "StreamSource",
    "StreamingKMeans",
    "TokenSource",
]

"""Streaming Mini-Apps (paper §5): MASS sources + MASA processors."""
from repro.miniapps.mass import (
    SOURCES,
    KMeansClusterSource,
    KMeansStaticSource,
    LightsourceTemplateSource,
    RateStep,
    RateStepScenario,
    SourceConfig,
    StreamSource,
    TokenSource,
)
from repro.miniapps.detector import DetectorSimSource
from repro.miniapps.masa import (
    PROCESSORS,
    LMServeApp,
    LMTrainApp,
    ReconstructionApp,
    StreamingKMeans,
)

__all__ = [
    "DetectorSimSource",
    "KMeansClusterSource",
    "KMeansStaticSource",
    "LMServeApp",
    "LMTrainApp",
    "LightsourceTemplateSource",
    "PROCESSORS",
    "RateStep",
    "RateStepScenario",
    "ReconstructionApp",
    "SOURCES",
    "SourceConfig",
    "StreamSource",
    "StreamingKMeans",
    "TokenSource",
]

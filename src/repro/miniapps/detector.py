"""Detector-simulator source: instrument-scale frame replay (MASS family).

Modeled on pvaPy's ``AdSimServer`` (the EPICS area-detector simulator the
light-source streaming stacks test against): a small cache of frames is
generated — or loaded from an HDF5 dataset — up front, then replayed at a
controlled rate, so the measured ceiling is the *transport*, not the
generator. Frames go out through :meth:`Producer.send_batch` in columnar
batches: on an shm-mounted topic each batch is one ring-slot write plus
slot-handle records (see docs/transport.md), which is what lets
``benchmarks/transport.py`` chase msgs/s numbers the per-message serde
path can't reach.

HDF5 input is optional and gated on ``h5py`` being importable; without
it (or without a path) frames are synthetic Poisson-ish counts in the
detector's native dtype.
"""
from __future__ import annotations

import numpy as np

from repro.broker.producer import Producer
from repro.miniapps.mass import SOURCES, StreamSource


class DetectorSimSource(StreamSource):
    """Replay cached detector frames in rate-controlled batches."""

    def __init__(self, cluster, config, *, ny: int = 128, nx: int = 128,
                 dtype: str = "uint16", n_cached: int = 16,
                 frames_per_batch: int = 32,
                 hdf5_path: str | None = None, hdf5_dataset: str = "frames"):
        super().__init__(cluster, config)
        self.frames_per_batch = max(int(frames_per_batch), 1)
        if hdf5_path is not None:
            self._cache = self._load_hdf5(hdf5_path, hdf5_dataset, n_cached)
        else:
            rng = np.random.default_rng(config.seed + 40_000)
            dt = np.dtype(dtype)
            hi = min(4096, int(np.iinfo(dt).max)) if dt.kind in "iu" else 4096
            self._cache = [
                rng.integers(0, hi, size=(ny, nx)).astype(dt)
                for _ in range(max(n_cached, 1))
            ]
        self.frame_bytes = self._cache[0].nbytes

    @staticmethod
    def _load_hdf5(path: str, dataset: str, n_cached: int) -> list[np.ndarray]:
        try:
            import h5py
        except ImportError as exc:  # pragma: no cover - h5py is in the image
            raise RuntimeError(
                "hdf5_path given but h5py is not installed") from exc
        with h5py.File(path, "r") as f:
            ds = f[dataset]
            n = min(n_cached, ds.shape[0])
            return [np.ascontiguousarray(ds[i]) for i in range(n)]

    def make_message(self, rng, i):
        return self._cache[i % len(self._cache)]

    def _produce(self, worker: int) -> None:
        """Batched override of the per-message base loop: one
        ``send_batch`` per ``frames_per_batch`` frames, cycling the cache.
        The producer's rate limiter accounts whole batches, so the
        configured msgs/s still means frames/s."""
        cfg = self.config
        rng = np.random.default_rng(cfg.seed + worker)
        rate = cfg.rate_msgs_per_s / cfg.n_producers if cfg.rate_msgs_per_s else None
        prod = Producer(self.cluster, cfg.topic, serializer=self.serializer,
                        compress=cfg.compress, rate_msgs_per_s=rate)
        self.producers.append(prod)
        quota = None if cfg.total_messages is None else cfg.total_messages // cfg.n_producers
        key = str(worker).encode() if cfg.keyed else None
        i = 0
        while not self._stop.is_set() and (quota is None or i < quota):
            if self.config.rate_msgs_per_s == 0:  # paused, not unthrottled
                self._stop.wait(0.01)
                continue
            n = self.frames_per_batch
            if quota is not None:
                n = min(n, quota - i)
            frames = [self.make_message(rng, i + j) for j in range(n)]
            stamps = [self.make_timestamp(rng, i + j) for j in range(n)]
            prod.send_batch(
                frames, key=key,
                timestamps=None if stamps[0] is None else stamps)
            i += n


SOURCES["detector"] = DetectorSimSource

"""llava-next-mistral-7b — VLM; Mistral-7B backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The anyres vision tower is a STUB: ``input_specs()`` provides precomputed
patch embeddings (B, n_patches, d_model) prepended to the token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    n_patches=576,  # one anyres base tile (24x24 @ patch 14, CLIP-L/336)
    frontend="vision",
    param_dtype="bfloat16",
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
)

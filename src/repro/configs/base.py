"""Architecture/config dataclasses shared by the model zoo and launchers.

Every assigned architecture is expressed as an :class:`ArchConfig`. The same
dataclass drives:

* parameter-spec construction (``models.build_model``),
* sharding rules (``runtime.sharding``),
* the dry-run (``launch.dryrun``) via ``input_specs()``,
* reduced smoke-test configs (``cfg.reduced()``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any

# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len, global_batch, kind).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    """A single architecture from the assigned pool (or a reduced variant)."""

    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm"

    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # stablelm partial rotary
    qk_norm: bool = False  # qwen3
    tie_embeddings: bool = False
    gated_mlp: bool = True  # False = classic 2-matrix gelu MLP (starcoder2)
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_group_size: int = 256
    capacity_factor: float = 1.25

    # SSM / RWKV
    ssm_state: int = 0  # mamba2 d_state
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # hybrid (zamba2): shared attention block applied every k inner layers
    shared_block_every: int = 6

    # enc-dec
    n_enc_layers: int = 0  # seamless: encoder depth (n_layers = decoder depth)

    # vlm / audio frontend stubs
    n_patches: int = 0  # llava: patch embeddings prepended to the sequence
    frontend: str = "none"  # "none" | "vision" | "audio"

    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    moment_dtype: str = "float32"  # optimizer moment dtype
    first_moment: bool = True  # adafactor: False = momentum-free (1T configs)
    remat: str = "full"  # "none" | "full" | "dots"
    scan_layers: bool = True
    grad_accum: int = 1

    # attention backend: "blockwise" (pure-jax flash), "naive", "ring"
    attention_impl: str = "blockwise"
    attention_block_q: int = 512
    attention_block_kv: int = 1024

    source: str = ""  # provenance note ([hf:...], [arXiv:...])

    # ---- derived ---------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding shards evenly on any mesh axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models import build_model  # lazy; avoids cycle

        from repro.utils.tree import tree_count

        return tree_count(build_model(self).param_struct())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-to experts)."""
        total = self.param_count()
        if self.family != "moe" or not self.n_experts:
            return total
        from repro.models import build_model

        model = build_model(self)
        expert = model.expert_param_count()
        used = self.experts_per_token + self.n_shared_experts
        return total - expert + expert * used // self.n_experts

    # ---- variants --------------------------------------------------------

    def reduced(self, **overrides: Any) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            head_dim=32,
            vocab_size=512,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
            scan_layers=self.scan_layers,
        )
        if self.n_experts:
            small.update(n_experts=4, experts_per_token=2, moe_group_size=16)
            small.update(n_shared_experts=min(self.n_shared_experts, 1))
            # non-binding capacity (cf >= E/k): keeps prefill == decode
            # exactly — capacity dropping is group-dependent and differs
            # between the two paths
            small.update(capacity_factor=4.0)
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=16, ssm_head_dim=32, rwkv_head_dim=32)
            small.update(shared_block_every=2)
        if self.n_enc_layers:
            small.update(n_enc_layers=2)
        if self.n_patches:
            small.update(n_patches=16)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def replace(self, **overrides: Any) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[arXiv:2501.kimi2; unverified]
61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8,
1 shared expert (DeepSeek-V3-style). Trains only with full ZeRO-3 over all
chips + bf16/factored optimizer state — see EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,
    rope_theta=50_000.0,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    moe_group_size=512,
    capacity_factor=1.25,
    param_dtype="bfloat16",
    optimizer="adafactor",
    moment_dtype="bfloat16",
    first_moment=False,
    source="[arXiv:2501.kimi2; unverified]",
)

"""rwkv6-3b ("Finch") — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]
32L d_model=2560 (attn-free) d_ff=8960 vocab=65536. WKV6 recurrence with
data-dependent per-channel decay; chunked-parallel implementation.
Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,  # 40 wkv heads
    param_dtype="bfloat16",
    source="[arXiv:2404.05892; hf]",
)

"""qwen3-14b — dense, GQA + per-head qk-norm.

[hf:Qwen/Qwen3-8B; hf] (14B row of the Qwen3 family table)
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
    param_dtype="bfloat16",
    source="[hf:Qwen/Qwen3-8B; hf]",
)

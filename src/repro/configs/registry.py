"""Architecture registry: ``get_arch("qwen3-14b") -> ArchConfig``."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.configs.llava_next_mistral_7b import CONFIG as LLAVA
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS
from repro.configs.phi35_moe import CONFIG as PHI35
from repro.configs.kimi_k2 import CONFIG as KIMI
from repro.configs.rwkv6_3b import CONFIG as RWKV6
from repro.configs.qwen3_14b import CONFIG as QWEN3
from repro.configs.smollm_135m import CONFIG as SMOLLM
from repro.configs.stablelm_1_6b import CONFIG as STABLELM
from repro.configs.starcoder2_3b import CONFIG as STARCODER2
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        LLAVA,
        SEAMLESS,
        PHI35,
        KIMI,
        RWKV6,
        QWEN3,
        SMOLLM,
        STABLELM,
        STARCODER2,
        ZAMBA2,
    ]
}

#: archs whose sequence mixing is sub-quadratic -> eligible for long_500k
SUBQUADRATIC = {"rwkv6-3b", "zamba2-1.2b"}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; else (False, why)."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 512k dense KV is quadratic-regime (see DESIGN.md §Arch-applicability)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]

"""seamless-m4t-medium — audio enc-dec transformer backbone.

[arXiv:2308.11596; hf]
12L d_model=1024 16H (kv=16, MHA) d_ff=4096 vocab=256206; enc-dec.
The speech frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, S_enc, d_model) for the encoder. ``train_4k`` splits the
sequence budget 1/2 encoder frames + 1/2 decoder tokens (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,  # decoder depth
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,  # padded to 256256 internally for even sharding
    head_dim=64,
    frontend="audio",
    param_dtype="bfloat16",
    source="[arXiv:2308.11596; hf]",
)

"""stablelm-1.6b — dense MHA with partial rotary embeddings.

[hf:stabilityai/stablelm-2-1_6b; unverified]
24L d_model=2048 32H (kv=32, MHA) d_ff=5632 vocab=100352; rotary_pct=0.25.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    head_dim=64,
    rope_theta=10_000.0,
    rope_pct=0.25,
    param_dtype="bfloat16",
    source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
)

"""zamba2-1.2b — hybrid: Mamba2 backbone + weight-tied shared attention block.

[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
The shared attention+MLP block is applied every ``shared_block_every``
Mamba2 layers (weight-tied across applications, Zamba2-style).
Sub-quadratic backbone -> runs long_500k (attention sites are decode-time
KV reads, O(seq) per token).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,  # mamba2 layers
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,  # shared block MLP
    vocab_size=32000,
    head_dim=64,
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_block_every=6,
    param_dtype="bfloat16",
    source="[arXiv:2411.15242; hf]",
)

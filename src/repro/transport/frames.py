"""Columnar batch frames: one header, raw array bytes, frombuffer views.

A frame is a batch of messages encoded once: arrays are grouped by
``(dtype, shape)`` — the same columnar idiom as
``repro.state.store.serialize_partition`` — with a single msgpack header
(group table, per-element placement, per-element event timestamps,
optional key) followed by the groups' raw bytes back to back. Same-host
consumers decode a frame into ``numpy.frombuffer`` **views** over the
shared-memory slot: zero per-message serde, zero per-message copies.

Unlike the state store's serializer, dtypes travel as
``np.lib.format`` descriptors, so structured dtypes round-trip exactly
(``dtype.str`` is lossy for them — the property suite pins this).

``ShmArrayView`` makes the zero-copy contract explicit and portable:
it remembers which ring slot (and epoch) backs it, pickles to a slot
descriptor instead of its bytes, and reattaches by segment name in
another process — the multiprocess-worker payoff. ``verify()`` detects
a reclaim that happened under the view (epoch mismatch) instead of
letting recycled bytes pass silently.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import msgpack
import numpy as np

from repro.transport.ring import SharedMemoryRing, SlotReclaimedError, get_ring


def _records():
    # repro.broker.consumer imports this module, so a top-level import of
    # repro.broker.records would cycle when repro.transport loads first;
    # the npy fallback codec is only needed per non-columnar value anyway
    from repro.broker import records

    return records

_LEN = 4  # u32 header-length prefix in a packed frame


def _descr_from_wire(d):
    """msgpack turns dtype-descr tuples into lists; rebuild the tuple
    shape ``descr_to_dtype`` expects (recursively, for nested records)."""
    if isinstance(d, str):
        return d
    out = []
    for f in d:
        f = list(f)
        if not isinstance(f[1], str):
            f[1] = _descr_from_wire(f[1])
        if len(f) == 3:
            f[2] = tuple(f[2])
        out.append(tuple(f))
    return out


@dataclass
class FrameBatch:
    """A decoded frame: per-element values/timestamps plus the slot
    provenance needed to validate zero-copy views after the fact."""

    values: list
    timestamps: list | None
    key: bytes | None = None
    #: (ring_name, slot, epoch) when the values are views into a ring slot
    source: tuple[str, int, int] | None = None
    zero_copy: bool = False

    def __len__(self) -> int:
        return len(self.values)

    def verify(self) -> None:
        """Detect-on-reclaim: raise :class:`SlotReclaimedError` if the
        backing slot was recycled since decode. Call after consuming
        zero-copy values; a no-op for copied-out frames."""
        if not self.zero_copy or self.source is None:
            return
        name, slot, epoch = self.source
        if not get_ring(name).is_valid(slot, epoch):
            raise SlotReclaimedError(
                f"frame views into {name} slot {slot} outlived the slot")


class ShmArrayView(np.ndarray):
    """ndarray view into a ring slot that survives pickling by descriptor.

    ``__reduce__`` ships (segment name, slot, epoch, byte offset, dtype
    descriptor, shape) — a few hundred bytes — and the receiving process
    reattaches the segment by name and rebuilds the view, epoch-checked.
    ``verify()`` re-checks the epoch after a read."""

    #: (name, slot, epoch, byte_off of the wrapped array, its data pointer)
    _slot_ref: tuple[str, int, int, int, int] | None = None

    @classmethod
    def wrap(cls, arr: np.ndarray, name: str, slot: int, epoch: int,
             byte_off: int) -> "ShmArrayView":
        view = arr.view(cls)
        view._slot_ref = (name, slot, epoch, byte_off, view.ctypes.data)
        return view

    def __array_finalize__(self, obj):
        # derived views (rows of a wrapped block, slices) inherit the
        # parent's ref untouched — this runs once per row on the decode
        # hot path, so the per-view byte offset is resolved lazily from
        # the pointer delta only when pickling or verifying
        if obj is not None and self._slot_ref is None:
            self._slot_ref = getattr(obj, "_slot_ref", None)

    def verify(self) -> None:
        if self._slot_ref is None:
            return
        name, slot, epoch = self._slot_ref[:3]
        if not get_ring(name).is_valid(slot, epoch):
            raise SlotReclaimedError(
                f"view into {name} slot {slot} outlived the slot")

    def __reduce__(self):
        if self._slot_ref is None:  # detached view: fall back to a copy
            arr = np.asarray(self)
            return (np.array, (arr.tolist(), arr.dtype))
        name, slot, epoch, base_off, base_ptr = self._slot_ref
        byte_off = base_off + (self.ctypes.data - base_ptr)
        return (_reattach_view, (
            name, slot, epoch, byte_off,
            np.lib.format.dtype_to_descr(self.dtype), self.shape))


def _reattach_view(name, slot, epoch, byte_off, descr, shape) -> ShmArrayView:
    ring = get_ring(name)
    buf = ring.view(slot, epoch)  # raises SlotReclaimedError when recycled
    dtype = np.lib.format.descr_to_dtype(descr)
    n = math.prod(shape)
    arr = np.frombuffer(buf, dtype=dtype, count=n, offset=byte_off).reshape(shape)
    return ShmArrayView.wrap(arr, name, slot, epoch, byte_off)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------


def _groupable(arr: np.ndarray) -> bool:
    return arr.ndim >= 1 and not arr.dtype.hasobject


#: dtype -> (descr, hashable-key): dtype_to_descr costs ~13us and detector
#: batches call it once per frame element — cache by dtype identity
_DESCR_CACHE: dict = {}


def _descr_for(dtype: np.dtype) -> tuple:
    entry = _DESCR_CACHE.get(dtype)
    if entry is None:
        descr = np.lib.format.dtype_to_descr(dtype)
        entry = (descr, repr(descr))
        if len(_DESCR_CACHE) < 1024:
            _DESCR_CACHE[dtype] = entry
    return entry


def _encode_uniform(arrs, timestamps, key: bytes | None):
    """Single-group encode for the detector-ingest common case: every
    value is a contiguous ndarray of one dtype and shape, so the group
    table, placement vectors, and parts fall out without per-element
    grouping machinery."""
    n = len(arrs)
    a0 = arrs[0]
    descr, _ = _descr_for(a0.dtype)
    header = msgpack.packb({
        "v": 1,
        "n": n,
        "groups": [[descr, list(a0.shape), n, 0]],
        "vgid": [0] * n,
        "vrow": list(range(n)),
        "other": [],
        "ts": list(timestamps) if timestamps is not None else None,
        "key": key,
    }, use_bin_type=True)
    return header, [memoryview(a).cast("B") for a in arrs]


def encode_frame(values, timestamps=None, key: bytes | None = None):
    """Columnar-encode a batch into ``(header_bytes, parts)`` where
    ``parts`` are buffer-protocol views over the source arrays (no
    intermediate concatenation — the only copy happens when a caller
    writes the parts into a ring slot or joins them inline)."""
    if values and isinstance(values[0], np.ndarray):
        a0 = values[0]
        # dtype identity (not equality) short-circuits: a false negative
        # just takes the general path below, which handles everything
        if (a0.ndim >= 1 and not a0.dtype.hasobject and all(
                isinstance(v, np.ndarray) and v.dtype is a0.dtype
                and v.shape == a0.shape and v.flags.c_contiguous
                for v in values)):
            return _encode_uniform(values, timestamps, key)
    groups: dict[tuple[str, tuple], list] = {}
    vgid: list[int] = []
    vrow: list[int] = []
    other: list[tuple[int, bytes]] = []
    group_list: list[list] = []
    parts: list = []
    for i, v in enumerate(values):
        arr = v if isinstance(v, np.ndarray) else None
        if arr is None and isinstance(v, (int, float, list, tuple)):
            arr = np.asarray(v)
        if arr is not None and _groupable(arr):
            arr = np.ascontiguousarray(arr)
            # structured descrs are (unhashable) nested lists: key on repr
            descr, rkey = _descr_for(arr.dtype)
            gkey = (rkey, arr.shape)
            entry = groups.get(gkey)
            if entry is None:
                entry = [len(group_list), 0]
                groups[gkey] = entry
                group_list.append([descr, list(arr.shape), 0, arr.dtype.itemsize])
            vgid.append(entry[0])
            vrow.append(entry[1])
            entry[1] += 1
            group_list[entry[0]][2] += 1
            parts.append((entry[0], memoryview(arr).cast("B")))
        else:
            # non-columnar fallback: npy envelope inside the frame (0-d,
            # object arrays, raw bytes...) — still one header per batch
            blob = v if isinstance(v, bytes) else _records().encode_array(np.asarray(v))
            tag = 0 if isinstance(v, bytes) else 1
            vgid.append(-1)
            vrow.append(len(other))
            other.append((tag, blob))
    # lay groups out contiguously: group 0's rows, then group 1's, ...
    parts.sort(key=lambda t: t[0])
    payload_parts = [p for _, p in parts]
    offsets, off = [], 0
    for g in group_list:
        offsets.append(off)
        off += g[2] * g[3] * math.prod(g[1])
    header = msgpack.packb({
        "v": 1,
        "n": len(values),
        "groups": [[g[0], g[1], g[2], o] for g, o in zip(group_list, offsets)],
        "vgid": vgid,
        "vrow": vrow,
        "other": [[t, b] for t, b in other],
        "ts": list(timestamps) if timestamps is not None else None,
        "key": key,
    }, use_bin_type=True)
    return header, payload_parts


def pack_frame(values, timestamps=None, key: bytes | None = None) -> bytes:
    """One contiguous buffer: u32 header length, header, payload — the
    exact layout a ring slot holds, reusable as an inline (copy-out)
    record value."""
    header, parts = encode_frame(values, timestamps, key)
    return b"".join([len(header).to_bytes(_LEN, "little"), header, *parts])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_frame(buf, *, zero_copy: bool = False,
                 source: tuple[str, int, int] | None = None) -> FrameBatch:
    """Decode a packed frame. ``zero_copy=True`` returns views into
    ``buf`` (:class:`ShmArrayView` when ``source`` names the backing ring
    slot); the default copies out — one bulk copy per *group*, never per
    message, so the batch win survives even on the safe path."""
    mv = memoryview(buf)
    hlen = int.from_bytes(mv[:_LEN], "little")
    header = msgpack.unpackb(mv[_LEN:_LEN + hlen], raw=False)
    payload = mv[_LEN + hlen:]
    rows_by_group: list[list] = []
    for descr, shape, n, off in header["groups"]:
        dtype = np.lib.format.descr_to_dtype(_descr_from_wire(descr))
        shape = tuple(shape)
        per = math.prod(shape)
        block = np.frombuffer(payload, dtype=dtype, count=n * per, offset=off)
        block = block.reshape((n, *shape))
        if not zero_copy:
            block = block.copy()
        if zero_copy and source is not None:
            name, slot, epoch = source
            block = ShmArrayView.wrap(block, name, slot, epoch,
                                      _LEN + hlen + off)
        rows = list(block)
        rows_by_group.append(rows)
    other = header["other"]
    values: list[Any] = []
    for gid, row in zip(header["vgid"], header["vrow"]):
        if gid >= 0:
            values.append(rows_by_group[gid][row])
        else:
            tag, blob = other[row]
            values.append(blob if tag == 0 else _records().decode_array(blob))
    return FrameBatch(values=values, timestamps=header["ts"], key=header["key"],
                      source=source, zero_copy=zero_copy)


def unpack_frame(buf, *, zero_copy: bool = False,
                 source: tuple[str, int, int] | None = None) -> FrameBatch:
    """Alias kept next to :func:`pack_frame` for symmetry."""
    return decode_frame(buf, zero_copy=zero_copy, source=source)

"""Control plane / data plane split: ring slot handles in the log.

``ShmTransport`` mounts one :class:`SharedMemoryRing` per topic. The
:class:`PartitionLog` keeps doing everything it already does — offset
assignment, acks-all replication metadata, retention, blocking reads —
but for shm topics a record's *value* shrinks to an ``S``-tagged slot
handle (ring name, slot, epoch, element row): a few dozen bytes of
control plane, while the payload sits in shared memory, written once.

Slot lifetime is tied to consumer progress, not log retention: the
cluster reports commit/replay floors (min over registered groups, with
checkpointing streams pinning their replay horizon) and
``reclaim_below`` releases every slot whose frame is wholly below the
floor. A full ring therefore stalls the *producer* — backpressure —
until consumers commit, and the stall feeds the same saturation signal
as the token buckets.

Copy-out rules (docs/transport.md): replication_factor > 1 means a slot
handle would alias one mutable payload across replicas whose logs must
survive the ring's host — so ``use_ring`` refuses and the producer falls
back to inline per-record serde. Oversized frames (> slot_bytes) fall
back the same way. Consumers that outlive a slot get
:class:`SlotReclaimedError` (epoch mismatch), never recycled bytes.
"""
from __future__ import annotations

import struct
import threading
from collections import OrderedDict, deque

from repro.transport.frames import decode_frame
from repro.transport.ring import RingTimeout, SharedMemoryRing, get_ring

TAG_SLOT = b"S"

# fixed-layout wire format (struct beats msgpack ~5x on this hot path):
# b"S" | u8 name_len | name | u32 slot | u64 epoch | u32 row
_SLOT_TAIL = struct.Struct("<IQI")


def slot_record_prefix(ring_name: str, slot: int, epoch: int) -> bytes:
    """Everything but the row — producers emit one record per frame
    element, so the shared prefix is built once per frame."""
    nb = ring_name.encode()
    return b"".join((TAG_SLOT, bytes((len(nb),)), nb,
                     struct.pack("<IQ", slot, epoch)))


_ROW = struct.Struct("<I")
pack_row = _ROW.pack


def encode_slot_record(ring_name: str, slot: int, epoch: int, row: int) -> bytes:
    """The entire on-log value of one shm-transported message."""
    nb = ring_name.encode()
    return b"".join((TAG_SLOT, bytes((len(nb),)), nb,
                     _SLOT_TAIL.pack(slot, epoch, row)))


def decode_slot_record(data: bytes):
    """-> (ring_name, slot, epoch, row)"""
    ln = data[1]
    slot, epoch, row = _SLOT_TAIL.unpack_from(data, 2 + ln)
    return data[2:2 + ln].decode(), slot, epoch, row


class FrameCache:
    """Small per-consumer LRU of decoded frames keyed by slot incarnation:
    expanding N records of one frame decodes the header exactly once."""

    def __init__(self, capacity: int = 8):
        self.capacity = capacity
        self._frames: OrderedDict[tuple, object] = OrderedDict()

    def get(self, key):
        frame = self._frames.get(key)
        if frame is not None:
            self._frames.move_to_end(key)
        return frame

    def put(self, key, frame) -> None:
        self._frames[key] = frame
        self._frames.move_to_end(key)
        while len(self._frames) > self.capacity:
            self._frames.popitem(last=False)

    def clear(self) -> None:
        """Drop cached frames (and any zero-copy views they pin) so ring
        segments can close cleanly — consumers call this on shutdown."""
        self._frames.clear()


class ShmTransport:
    """Per-topic rings plus the offset→slot bookkeeping that drives
    consumer-progress reclaim. Attach to a cluster with
    ``cluster.attach_transport(transport)``."""

    def __init__(self, *, slot_bytes: int = 1 << 20, n_slots: int = 64):
        self.slot_bytes = slot_bytes
        self.n_slots = n_slots
        self._rings: dict[str, SharedMemoryRing] = {}
        #: (topic, partition) -> deque[(last_offset_of_frame, slot, epoch)]
        self._tracked: dict[tuple[str, int], deque] = {}
        #: last reclaim floor seen per partition (for the lazy pass)
        self._floors: dict[tuple[str, int], int] = {}
        self._lock = threading.Lock()

    # ---- mounting -----------------------------------------------------------

    def mount(self, topic: str, *, slot_bytes: int | None = None,
              n_slots: int | None = None) -> SharedMemoryRing:
        with self._lock:
            ring = self._rings.get(topic)
            if ring is None:
                ring = SharedMemoryRing(slot_bytes=slot_bytes or self.slot_bytes,
                                        n_slots=n_slots or self.n_slots)
                self._rings[topic] = ring
            return ring

    def unmount(self, topic: str) -> None:
        with self._lock:
            ring = self._rings.pop(topic, None)
            for key in [k for k in self._tracked if k[0] == topic]:
                del self._tracked[key]
        if ring is not None:
            ring.destroy()

    def ring_for(self, topic: str) -> SharedMemoryRing | None:
        with self._lock:
            return self._rings.get(topic)

    def serves(self, topic: str) -> bool:
        with self._lock:
            return topic in self._rings

    # ---- producer path ------------------------------------------------------

    def use_ring(self, topic: str, replication_factor: int) -> SharedMemoryRing | None:
        """The copy-out gate: a ring, or None when payloads must travel
        inline (topic not mounted, or rf>1 — replica logs must not alias
        one reclaimable slot)."""
        if replication_factor > 1:
            return None
        return self.ring_for(topic)

    def write_frame(self, topic: str, header: bytes, parts,
                    *, deadline: float | None = None) -> tuple[int, int]:
        """Allocate a slot (stalling on a full ring = backpressure; a lazy
        reclaim pass runs first) and write one packed frame into it.
        Returns (slot, epoch); ValueError for oversized frames,
        :class:`RingTimeout` past the deadline."""
        ring = self.ring_for(topic)
        total = 4 + len(header) + sum(len(p) for p in parts)
        if total > ring.slot_bytes:
            raise ValueError(f"frame of {total}B exceeds slot size")
        slot, epoch = ring.alloc(
            deadline=deadline,
            reclaim_hook=lambda: self._reclaim_pending(topic))
        ring.write(slot, epoch,
                   [len(header).to_bytes(4, "little"), header, *parts])
        return slot, epoch

    def track(self, topic: str, partition: int, last_offset: int,
              slot: int, epoch: int) -> None:
        """Bind a written slot to the log offset of its frame's last
        record; reclaim releases it once the floor passes that offset."""
        with self._lock:
            self._tracked.setdefault((topic, partition), deque()).append(
                (last_offset, slot, epoch))

    def release(self, topic: str, slot: int, epoch: int) -> None:
        """Untracked release — a producer whose append ultimately failed
        gives the slot straight back."""
        ring = self.ring_for(topic)
        if ring is not None:
            ring.release(slot, epoch)

    # ---- reclaim (consumer progress) ----------------------------------------

    def reclaim_below(self, topic: str, partition: int, floor: int) -> int:
        """Release every slot whose frame ends below ``floor`` (the min
        commit/replay offset across the topic's consumer groups). Returns
        the number of slots released."""
        ring = self.ring_for(topic)
        if ring is None:
            return 0
        released = []
        with self._lock:
            dq = self._tracked.get((topic, partition))
            if not dq:
                return 0
            while dq and dq[0][0] < floor:
                released.append(dq.popleft())
            self._floors[(topic, partition)] = floor
        for _, slot, epoch in released:
            ring.release(slot, epoch)
        return len(released)

    def _reclaim_pending(self, topic: str) -> None:
        """Lazy pass used by a stalling allocator: re-apply the last known
        floors for the topic (a commit may have landed while no producer
        was allocating)."""
        with self._lock:
            floors = dict(self._floors)
        for (t, p), floor in floors.items():
            if t == topic:
                self.reclaim_below(t, p, floor)

    # ---- saturation / lifecycle ---------------------------------------------

    def stall_seconds(self) -> float:
        """Cumulative producer stall on full rings — summed into
        ``BrokerCluster.io_stall_seconds`` next to token-bucket stall so
        the broker saturation probe (and elasticity) sees ring pressure."""
        with self._lock:
            return sum(r.stall_seconds for r in self._rings.values())

    def ring_names(self) -> dict[str, str]:
        with self._lock:
            return {t: r.name for t, r in self._rings.items()}

    def close(self) -> None:
        with self._lock:
            rings = list(self._rings.values())
            self._rings.clear()
            self._tracked.clear()
        for ring in rings:
            ring.destroy()


def expand_slot_value(data: bytes, *, zero_copy: bool = False):
    """Resolve an ``S``-tagged record value to its decoded
    :class:`FrameBatch` (no cache — see ``Consumer`` for the cached path)."""
    name, slot, epoch, row = decode_slot_record(data)
    ring = get_ring(name)
    frame = decode_frame(ring.view(slot, epoch), zero_copy=zero_copy,
                         source=(name, slot, epoch))
    if not zero_copy:
        # the copy already happened; make sure it didn't race a reclaim
        frame.zero_copy = True
        frame.verify()
        frame.zero_copy = False
    return frame, row

"""Zero-copy shared-memory data plane (docs/transport.md).

The broker stays the control plane (offsets, replication metadata,
retention); record *payloads* move through a ``multiprocessing.
shared_memory`` ring of fixed-size slots, written once as columnar batch
frames and read by same-host consumers as ``numpy.frombuffer`` views —
no per-message serde on the hot path. Backpressure is the slot
allocator's stall, surfaced through the same saturation signal the
broker token buckets feed (``BrokerCluster.io_stall_seconds``), so
broker elasticity keeps working unchanged.
"""
from repro.transport.frames import (
    FrameBatch,
    ShmArrayView,
    decode_frame,
    encode_frame,
    pack_frame,
    unpack_frame,
)
from repro.transport.plane import ShmTransport, decode_slot_record, encode_slot_record
from repro.transport.ring import (
    RingTimeout,
    SharedMemoryRing,
    SlotReclaimedError,
    get_ring,
)

__all__ = [
    "FrameBatch",
    "RingTimeout",
    "SharedMemoryRing",
    "ShmArrayView",
    "ShmTransport",
    "SlotReclaimedError",
    "decode_frame",
    "decode_slot_record",
    "encode_frame",
    "encode_slot_record",
    "get_ring",
    "pack_frame",
    "unpack_frame",
]

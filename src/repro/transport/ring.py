"""Shared-memory slot ring: the zero-copy payload store (docs/transport.md).

One ``multiprocessing.shared_memory`` segment per mounted topic, split
into fixed-size slots. The segment is self-describing — a small header
carries the geometry, and a per-slot metadata table (epoch, length)
lives in shared memory — so any process can ``attach()`` by name and
validate a slot handle without talking to the broker host.

Epoch protocol: a slot's epoch starts at 0 (free) and is bumped on every
state change — odd while a frame lives in it, even when reclaimed. A
handle carries the odd epoch it was written under; any later read
compares against the table and raises :class:`SlotReclaimedError` on
mismatch instead of returning silently-recycled bytes.

Allocation, reference counts, and the free list are host-side (the
broker owns the segment; only *reads* cross process boundaries).
``alloc`` stalling on a full ring IS the data-plane backpressure: the
accumulated ``stall_seconds`` feeds ``BrokerCluster.io_stall_seconds``
next to the token buckets, so the broker saturation probe — and with it
broker elasticity — sees ring pressure exactly like NIC pressure.
"""
from __future__ import annotations

import struct
import threading
import time
import uuid
import weakref
from collections import deque
from multiprocessing import resource_tracker, shared_memory

import numpy as np

_MAGIC = b"RRG1"
_HEADER = struct.Struct("<4sIQ")  # magic, n_slots, slot_bytes
_META_OFF = 64  # header padded to a cache line
_ALIGN = 64


class SlotReclaimedError(RuntimeError):
    """A slot handle outlived its slot: the epoch in the shared table no
    longer matches the handle's. The view (or copy) must not be trusted."""


class RingTimeout(RuntimeError):
    """``alloc`` stalled past its deadline — the ring stayed full."""


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


#: name -> ring, so consumers in the broker host reuse the creator's
#: object (free-list authority) and forked workers attach once per name
_RINGS: "weakref.WeakValueDictionary[str, SharedMemoryRing]" = weakref.WeakValueDictionary()
_RINGS_LOCK = threading.Lock()


def get_ring(name: str) -> "SharedMemoryRing":
    """Resolve a ring by segment name: the in-process instance when this
    process created (or already attached) it, else a fresh attach."""
    with _RINGS_LOCK:
        ring = _RINGS.get(name)
        if ring is not None:
            return ring
    ring = SharedMemoryRing.attach(name)
    return ring


class SharedMemoryRing:
    """Fixed-slot shared-memory ring with epoch-tagged reclaim."""

    def __init__(self, *, slot_bytes: int = 1 << 20, n_slots: int = 64,
                 name: str | None = None):
        if slot_bytes <= 0 or n_slots <= 0:
            raise ValueError("slot_bytes and n_slots must be positive")
        self.slot_bytes = int(slot_bytes)
        self.n_slots = int(n_slots)
        self._data_off = _align(_META_OFF + self.n_slots * 16)
        size = self._data_off + self.n_slots * self.slot_bytes
        self.name = name or f"rring-{uuid.uuid4().hex[:12]}"
        self._shm = shared_memory.SharedMemory(self.name, create=True, size=size)
        self._owner = True
        self._shm.buf[:_HEADER.size] = _HEADER.pack(_MAGIC, self.n_slots, self.slot_bytes)
        self._init_views()
        self._meta[:] = 0
        # pre-fault the data region (one write per page): first-touch page
        # allocation costs ~7x bandwidth, and paying it at mount time keeps
        # the first pass over the ring as fast as the steady state
        self._bytes_np[self._data_off::4096] = 0
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)
        self._free: deque[int] = deque(range(self.n_slots))
        self._refs: dict[int, int] = {}
        self._pending_release: set[int] = set()
        #: cumulative seconds alloc callers spent blocked on a full ring —
        #: the data-plane backpressure signal (see module docstring)
        self.stall_seconds = 0.0
        self.alloc_count = 0
        self.reclaim_count = 0
        with _RINGS_LOCK:
            _RINGS[self.name] = self

    def _init_views(self) -> None:
        self._meta = np.frombuffer(
            self._shm.buf, dtype=np.uint64, count=self.n_slots * 2, offset=_META_OFF
        ).reshape(self.n_slots, 2)  # columns: epoch, length
        # byte view over the whole segment: numpy bulk assignment copies at
        # memcpy speed, where memoryview slice-assign of cast views doesn't
        self._bytes_np = np.frombuffer(self._shm.buf, dtype=np.uint8)

    # ---- attach (other processes / late joiners) ---------------------------

    @classmethod
    def attach(cls, name: str) -> "SharedMemoryRing":
        shm = shared_memory.SharedMemory(name)
        # the creator's resource tracker owns the segment; unregister this
        # process's handle so a reader exiting doesn't unlink (or warn
        # about) a segment it never owned
        try:  # pragma: no cover - tracker internals vary across versions
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
        magic, n_slots, slot_bytes = _HEADER.unpack_from(shm.buf, 0)
        if magic != _MAGIC:
            shm.close()
            raise ValueError(f"{name!r} is not a repro transport ring")
        ring = cls.__new__(cls)
        ring.slot_bytes = slot_bytes
        ring.n_slots = n_slots
        ring._data_off = _align(_META_OFF + n_slots * 16)
        ring.name = name
        ring._shm = shm
        ring._owner = False
        ring._init_views()
        ring._lock = threading.Lock()
        ring._space = threading.Condition(ring._lock)
        ring._free = deque()
        ring._refs = {}
        ring._pending_release = set()
        ring.stall_seconds = 0.0
        ring.alloc_count = 0
        ring.reclaim_count = 0
        with _RINGS_LOCK:
            _RINGS.setdefault(name, ring)
        return ring

    # ---- producer side (owner only) ----------------------------------------

    def alloc(self, *, deadline: float | None = None,
              reclaim_hook=None) -> tuple[int, int]:
        """Claim a free slot, returning ``(slot, epoch)`` with the epoch
        already bumped to its live (odd) value. A full ring stalls —
        accumulating ``stall_seconds`` — until a release or the deadline;
        ``reclaim_hook`` (if given) is invoked once before the first wait so
        the plane can release consumed slots lazily."""
        hooked = False
        with self._space:
            while not self._free:
                if reclaim_hook is not None and not hooked:
                    hooked = True
                    self._lock.release()
                    try:
                        reclaim_hook()
                    finally:
                        self._lock.acquire()
                    continue
                t0 = time.monotonic()
                if deadline is not None and t0 >= deadline:
                    raise RingTimeout(
                        f"ring {self.name}: no free slot before deadline "
                        f"({self.n_slots} slots, all retained)")
                wait = 0.05 if deadline is None else min(0.05, max(deadline - t0, 0.001))
                self._space.wait(timeout=wait)
                self.stall_seconds += time.monotonic() - t0
            slot = self._free.popleft()
            epoch = int(self._meta[slot, 0]) + 1
            if epoch % 2 == 0:  # was mid-bump? never happens, keep odd invariant
                epoch += 1
            self._meta[slot, 0] = epoch
            self._meta[slot, 1] = 0
            self.alloc_count += 1
            return slot, epoch

    def write(self, slot: int, epoch: int, parts) -> int:
        """Copy ``parts`` (buffer-protocol objects) contiguously into the
        slot — the single unavoidable copy into shared memory — and publish
        the total length. Raises ValueError when the frame exceeds the slot
        (callers fall back to the inline copy-out path)."""
        total = sum(len(p) for p in parts)
        if total > self.slot_bytes:
            raise ValueError(
                f"frame of {total}B exceeds slot size {self.slot_bytes}B")
        # raw memoryview slice-assign memcpys contiguous 1-D "B" parts
        # (~3x the throughput of routing each part through numpy)
        buf = self._shm.buf
        off = self._data_off + slot * self.slot_bytes
        for p in parts:
            n = len(p)
            buf[off:off + n] = p
            off += n
        self._meta[slot, 1] = total
        return total

    def release(self, slot: int, epoch: int) -> None:
        """Producer/control-plane release: the slot is reclaimed (epoch
        bumped to even, slot back on the free list) once no reader holds a
        reference; with readers outstanding, reclaim is deferred until the
        last ``release_ref``. Stale epochs are ignored (already recycled)."""
        with self._space:
            self._release_locked(slot, epoch)

    def _release_locked(self, slot: int, epoch: int) -> None:
        if int(self._meta[slot, 0]) != epoch:
            return
        if self._refs.get(slot, 0) > 0:
            self._pending_release.add(slot)
            return
        self._meta[slot, 0] = epoch + 1
        self._pending_release.discard(slot)
        self._free.append(slot)
        self.reclaim_count += 1
        self._space.notify_all()

    # ---- reader side (any process) ------------------------------------------

    def retain(self, slot: int, epoch: int) -> bool:
        """Pin a live slot against reclaim; False if already reclaimed."""
        with self._lock:
            if int(self._meta[slot, 0]) != epoch:
                return False
            self._refs[slot] = self._refs.get(slot, 0) + 1
            return True

    def release_ref(self, slot: int, epoch: int) -> None:
        with self._space:
            refs = self._refs.get(slot, 0)
            if refs <= 1:
                self._refs.pop(slot, None)
                if slot in self._pending_release:
                    self._release_locked(slot, epoch)
            else:
                self._refs[slot] = refs - 1

    def is_valid(self, slot: int, epoch: int) -> bool:
        return 0 <= slot < self.n_slots and int(self._meta[slot, 0]) == epoch

    def view(self, slot: int, epoch: int) -> memoryview:
        """Zero-copy view of the slot's frame bytes. Epoch-checked on
        entry; re-check (``is_valid``) after consuming the view — detection,
        not prevention, is the contract for readers that raced a reclaim."""
        if not self.is_valid(slot, epoch):
            raise SlotReclaimedError(
                f"ring {self.name} slot {slot}: epoch {epoch} reclaimed "
                f"(now {int(self._meta[slot, 0])})")
        length = int(self._meta[slot, 1])
        base = self._data_off + slot * self.slot_bytes
        return self._shm.buf[base:base + length]

    # ---- introspection / lifecycle ------------------------------------------

    @property
    def free_slots(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def used_slots(self) -> int:
        return self.n_slots - self.free_slots

    def close(self) -> None:
        """Unmap this process's view. Outstanding zero-copy numpy views pin
        the mapping — close then fails quietly and the OS reclaims at
        process exit (unlink below is what frees the name)."""
        self._meta = None
        self._bytes_np = None
        try:
            self._shm.close()
        except BufferError:  # a consumer still holds a frombuffer view
            pass

    def unlink(self) -> None:
        if self._owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:  # pragma: no cover - already gone
                pass

    def destroy(self) -> None:
        self.close()
        self.unlink()

"""Version shims for the pinned jax (0.4.37).

The runtime modules were written against the promoted ``jax.shard_map``
API; 0.4.37 still carries it as ``jax.experimental.shard_map.shard_map``
with the replication check named ``check_rep`` instead of ``check_vma``.
Everything else (specs, collectives) is call-compatible, so one thin
wrapper keeps the call sites on the modern spelling.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
                  check_vma: bool = True) -> Callable:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
                  check_vma: bool = True) -> Callable:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

"""Monotonic timing helpers for benchmarks and engine metrics."""
from __future__ import annotations

import time
from dataclasses import dataclass, field


def now_monotonic() -> float:
    return time.monotonic()


@dataclass
class Timer:
    """Accumulating timer: ``with timer: ...`` adds to ``total``."""

    total: float = 0.0
    count: int = 0
    _start: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._start = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        self.total += time.monotonic() - self._start
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)

"""Small shared utilities: pytrees, timing, deterministic RNG streams."""
from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_zeros_like,
)
from repro.utils.timer import Timer, now_monotonic

__all__ = [
    "Timer",
    "now_monotonic",
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_paths",
    "tree_zeros_like",
]

"""Pytree helpers used across the runtime, checkpointing and tests."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree into ``[("a/b/0", leaf), ...]`` with stable paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:  # pragma: no cover - defensive
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def tree_count(tree: Any) -> int:
    """Total number of array elements in the tree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total number of bytes in the tree (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)

"""Continuous (in-flight) batching scheduler over the paged KV cache.

The lockstep serving path (``LMServeApp``) prefills a whole micro-batch
together and decodes a fixed token budget in a fused scan — every request
waits for the batch's longest prompt, and a finished row keeps occupying its
batch slot to the end. The :class:`ContinuousBatcher` replaces that with a
per-token scheduler loop:

1. queued prompts whose lifetime fits the free pages prefill as stacked
   rows — grouped by prompt bucket, one dispatch per (row-bucket, prompt-
   bucket) pair — and *join the live decode batch mid-stream*;
2. the live batch takes one greedy decode step against the page pool
   (gather/scatter in ``runtime/steps.py``; batch size and table width are
   shape-bucketed so the compile count stays bounded);
3. finished sequences (budget or EOS) exit immediately, releasing their
   pages — which is exactly what admits the next queued prompt.

Admission is **reservation-based**: pages for a request's whole lifetime
(``max(prompt_bucket, prompt + out_budget)`` tokens) are allocated at admit
time, so a live sequence can never stall mid-decode waiting for pages —
``lost_requests = 0`` by construction, traded against the higher pool
utilization an incremental allocator (with preemption) could reach.

Time is virtual: callers pass ``now`` into :meth:`submit`/:meth:`step`; the
step measures its own device time and stamps first-token/finish events at
``now + measured``, so the benchmark can replay a trace on a virtual clock
with no sleeping and the same code path serves real wall-clock callers.

Crash/recovery (the serving pilot contract): every admitted-or-queued
request sits in a journal until its response is recorded; ``crash()`` drops
all live state including the device pages, ``recover()`` re-queues the
journal in arrival order. Completed responses are never re-run (journal
entries are removed on delivery) and greedy decode is deterministic, so a
mid-trace crash yields the same response set as a fault-free run — no
duplicates, no losses.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.admission import ADMIT, QUEUE, REJECT, AdmissionController
from repro.serving.pages import PagedKVCache
from repro.serving.trace import Request
from repro.streaming.dispatch import LatencyWindow, ShapeBuckets, compile_count


@dataclass
class _Seq:
    """One live sequence: its request plus decode-loop position state."""

    req: Request
    tokens: list[int] = field(default_factory=list)  # generated so far
    t_first: float = 0.0

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def pos(self) -> int:
        """Write index of the next decode step (= live cache length)."""
        return self.req.prompt_len + len(self.tokens) - 1

    def done(self, eos_id: int | None) -> bool:
        if len(self.tokens) >= self.req.out_tokens:
            return True
        return eos_id is not None and bool(self.tokens) and self.tokens[-1] == eos_id


class ContinuousBatcher:
    """Scheduler loop: admit → prefill-into-pages → joint decode → exit.

    ``params`` must be assigned before the first :meth:`step` (the serving
    state arrives with the stream, not at construction). All scheduling is
    host-side and deterministic: live order is admission order, the queue is
    FIFO with no head-of-line bypass.
    """

    def __init__(self, model: Any, *, n_pages: int = 256, page_size: int = 16,
                 cache: PagedKVCache | None = None, eos_id: int | None = None,
                 rate: float = 0.0, burst: float | None = None, max_queue: int = 64,
                 use_kernel: bool = False, interpret: bool | None = None,
                 max_live: int = 64, metrics: Any = None, stream: str = "serving",
                 decode_quantum: int = 1):
        from repro.runtime.steps import build_paged_decode_step, build_paged_prefill_step

        self.model = model
        self.params: Any = None
        self.cache = cache or PagedKVCache.from_model(
            model, n_pages=n_pages, page_size=page_size)
        ps = self.cache.page_size
        self.eos_id = eos_id
        self.max_live = int(max_live)
        self.metrics = metrics
        self._labels = {"stream": stream}
        self.admission = AdmissionController(
            self.cache.pool, rate=rate, burst=burst, max_queue=max_queue)
        # buckets: prompt lengths (>= page_size => always page multiples),
        # live-batch rows, and page-table width — these bound compile count
        self.prompt_buckets = ShapeBuckets(min_size=ps, max_size=4 * ps)
        self.batch_buckets = ShapeBuckets(min_size=1, max_size=self.max_live)
        self.pages_buckets = ShapeBuckets(
            min_size=1, max_size=max(self.cache.pool.capacity_pages, 1))
        self._prefill = build_paged_prefill_step(model, page_size=ps)
        # >1 amortizes dispatch overhead: one fused call emits q tokens per
        # live row, surplus past a row's budget/EOS discarded on the host
        self.decode_quantum = max(int(decode_quantum), 1)
        self._decode = build_paged_decode_step(
            model, page_size=ps, use_kernel=use_kernel, interpret=interpret,
            quantum=self.decode_quantum)

        self._queue: deque[Request] = deque()
        self._pending: list[Request] = []  # admitted, awaiting prefill
        self._live: list[_Seq] = []
        self._journal: dict[int, Request] = {}  # rid -> not-yet-delivered
        self.results: dict[int, dict] = {}  # rid -> delivered response
        self.latency = LatencyWindow()  # arrival -> finish, per request

    # ---- arrival side -----------------------------------------------------

    def submit(self, req: Request, now: float = 0.0) -> str:
        """Classify one arrival; ADMIT reserves its lifetime pages now."""
        verdict = self.admission.offer(
            self._lifetime_tokens(req), now, queue_depth=len(self._queue))
        if verdict == ADMIT:
            ok = self.cache.admit(req.rid, self._lifetime_tokens(req))
            assert ok, "admission said place but the pool refused"
            self._pending.append(req)
            self._journal[req.rid] = req
        elif verdict == QUEUE:
            self._queue.append(req)
            self._journal[req.rid] = req
        return verdict

    def _lifetime_tokens(self, req: Request) -> int:
        # prefill scatters the whole prompt bucket, so the reservation covers
        # max(bucket, true lifetime)
        return max(self.prompt_buckets.fit(req.prompt_len), req.total_tokens)

    # ---- the scheduler step ----------------------------------------------

    def step(self, now: float = 0.0) -> float:
        """One scheduler iteration: drain the queue into free pages, prefill
        joiners, one decode step for the live batch, retire finished
        sequences. Returns the measured device seconds (the caller advances
        its clock by this)."""
        self._publish_gauges()
        # FIFO drain: strictly the head, so a small request can never starve
        # a big one that arrived first
        while (self._queue and len(self._live) + len(self._pending) < self.max_live
               and self.admission.can_place(self._lifetime_tokens(self._queue[0]))):
            req = self._queue.popleft()
            ok = self.cache.admit(req.rid, self._lifetime_tokens(req))
            assert ok
            self._pending.append(req)
        dt = 0.0
        if self._pending and self.params is not None:
            t0 = time.monotonic()
            joiners, self._pending = self._pending, []
            self._prefill_joiners(joiners)
            jax.block_until_ready((self.cache.k, self.cache.v))
            dt += time.monotonic() - t0
            for req in joiners:
                self._seq_of(req.rid).t_first = now + dt
            self._retire(now + dt)  # out_tokens == 1 finishes at prefill
        if self._live:
            t0 = time.monotonic()
            self._decode_step()
            dt += time.monotonic() - t0
            self._retire(now + dt)
        return dt

    def _seq_of(self, rid: int) -> _Seq:
        for s in self._live:
            if s.rid == rid:
                return s
        raise KeyError(rid)

    def _prefill_one(self, req: Request) -> None:
        self._prefill_joiners([req])

    def _prefill_joiners(self, joiners: list[Request]) -> None:
        """A step's joiners prefill as stacked calls, one per occupied
        prompt bucket: rows padded to a batch bucket, prompts padded to
        their own bucket. Stacking amortizes the per-call host overhead
        that would otherwise dominate an arrival burst; splitting by bucket
        keeps a burst's one long prompt from padding every row to its
        length. Padding rows scatter into scratch page 0 and their sampled
        token is discarded."""
        by_bucket: dict[int, list[Request]] = {}
        for r in joiners:
            by_bucket.setdefault(self.prompt_buckets.fit(r.prompt_len), []).append(r)
        for bucket, group in sorted(by_bucket.items()):
            self._prefill_group(group, bucket)

    def _prefill_group(self, joiners: list[Request], bucket: int) -> None:
        rows = self.batch_buckets.fit(len(joiners))
        toks = np.zeros((rows, bucket), np.int32)
        last = np.zeros((rows,), np.int32)
        for i, r in enumerate(joiners):
            toks[i, : r.prompt_len] = r.prompt
            last[i] = r.prompt_len - 1
        table = self.cache.table(
            [r.rid for r in joiners], bucket // self.cache.page_size,
            rows=rows, truncate=True)
        next_tok, self.cache.k, self.cache.v = self._prefill(
            self.params, self.cache.k, self.cache.v,
            jnp.asarray(toks), jnp.asarray(last), jnp.asarray(table))
        out = np.asarray(next_tok).reshape(-1)
        for i, r in enumerate(joiners):
            seq = _Seq(r)
            seq.tokens.append(int(out[i]))
            self._live.append(seq)

    def _decode_step(self) -> None:
        live = self._live
        mp = self.pages_buckets.fit(
            max(len(self.cache.pool.owned(s.rid)) for s in live))
        B = self.batch_buckets.fit(len(live))
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        left = np.zeros((B,), np.int32)  # budget remaining (0 = padding row)
        for i, s in enumerate(live):
            toks[i, 0] = s.tokens[-1]
            pos[i] = s.pos
            left[i] = s.req.out_tokens - len(s.tokens)
        table = self.cache.table((s.rid for s in live), mp, rows=B)
        if self.decode_quantum == 1:
            next_tok, self.cache.k, self.cache.v = self._decode(
                self.params, self.cache.k, self.cache.v,
                jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(table))
            out = np.asarray(next_tok).reshape(B, 1)
        else:
            next_tok, self.cache.k, self.cache.v = self._decode(
                self.params, self.cache.k, self.cache.v,
                jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(table),
                jnp.asarray(left))
            out = np.asarray(next_tok)  # (B, quantum)
        keep = 1 if self.decode_quantum == 1 else None
        for i, s in enumerate(live):
            for t in out[i, : keep or max(int(left[i]), 1)]:
                s.tokens.append(int(t))
                if s.done(self.eos_id):
                    break

    def _retire(self, t: float) -> None:
        still = []
        for s in self._live:
            if s.done(self.eos_id):
                self._deliver(s, t)
            else:
                still.append(s)
        self._live = still

    def _deliver(self, s: _Seq, t: float) -> None:
        assert s.rid not in self.results, f"duplicate response for {s.rid}"
        self.results[s.rid] = {
            "tokens": tuple(s.tokens),
            "arrival": s.req.arrival,
            "first_token": s.t_first,
            "finish": t,
        }
        self._journal.pop(s.rid, None)
        self.cache.release(s.rid)
        self.latency.record(max(t - s.req.arrival, 0.0))

    # ---- draining / state ------------------------------------------------

    @property
    def idle(self) -> bool:
        return not (self._live or self._pending or self._queue)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def live_count(self) -> int:
        return len(self._live)

    def drain(self, now: float = 0.0, *, max_steps: int = 100_000) -> float:
        """Step until every submitted request has a response."""
        t = now
        for _ in range(max_steps):
            if self.idle:
                return t
            t += self.step(t)
        raise RuntimeError("drain did not converge (scheduler wedged?)")

    @property
    def prefill_compiles(self) -> int:
        return compile_count(self._prefill)

    @property
    def decode_compiles(self) -> int:
        return compile_count(self._decode)

    def _publish_gauges(self) -> None:
        if self.metrics is None:
            return
        self.metrics.publish("serving.free_pages", self.cache.free_pages, **self._labels)
        self.metrics.publish("serving.queue_depth", len(self._queue), **self._labels)
        self.metrics.publish("serving.live", len(self._live), **self._labels)
        self.metrics.publish("serving.page_utilization", self.cache.utilization,
                             **self._labels)
        if len(self.latency):
            # the gauges SLOPolicy reads via MetricsSnapshot.latency_p50/p99
            self.metrics.publish("stream.latency_p50", self.latency.p50, **self._labels)
            self.metrics.publish("stream.latency_p99", self.latency.p99, **self._labels)

    # ---- crash / recovery (serving-pilot contract) -----------------------

    def crash(self) -> None:
        """Simulate a pilot kill: device pages and all scheduler state gone.
        ``results`` (delivered responses) and the journal survive — they
        model the durable output stream and the request log."""
        self._live = []
        self._pending = []
        self._queue.clear()
        self.cache.reset()

    def recover(self) -> None:
        """Re-queue every undelivered journaled request in arrival order.
        Greedy decode is deterministic, so regenerated responses are
        identical to what the lost in-flight work would have produced."""
        self._live = []
        self._pending = []
        self._queue = deque(
            sorted(self._journal.values(), key=lambda r: (r.arrival, r.rid)))

    def reset(self) -> None:
        """Full reset for benchmark warmup: keep compiled steps, drop state."""
        self.crash()
        self._journal.clear()
        self.results.clear()
        self.latency = LatencyWindow()
        self.admission.stats.__init__()
        self.admission.bucket.__post_init__()
        self.admission.bucket._t = 0.0

    def warmup(self, *, max_prompt: int | None = None,
               max_tokens: int | None = None,
               max_live: int | None = None) -> int:
        """Pre-compile every bucketed step shape the scheduler can reach.

        Replaying the trace once before timing is not enough on its own:
        how many scheduler steps land between two arrivals depends on
        *measured* device time, so the warm pass can visit a different set
        of (batch-rows, table-width) buckets than the timed pass — and a
        single leaked XLA compile (~0.5 s) swamps a virtual clock that
        otherwise bills milliseconds. This drives the jitted prefill and
        decode steps through the bucket cross-product with page tables
        pointing at the reserved scratch page 0, so no pool or scheduler
        state is touched. Caps (``max_prompt`` tokens, ``max_tokens``
        lifetime tokens per sequence, ``max_live`` rows) keep the sweep to
        the shapes a given trace can actually produce. Returns the number
        of step variants compiled."""
        assert self.params is not None, "assign params before warmup()"
        ps = self.cache.page_size
        before = self.prefill_compiles + self.decode_compiles
        pb_cap = self.prompt_buckets.fit(max_prompt) if max_prompt else \
            self.prompt_buckets.max_size
        mp_cap = self.pages_buckets.fit(self.cache.pool.pages_for(max_tokens)) \
            if max_tokens else self.pages_buckets.max_size
        b_cap = self.batch_buckets.fit(min(max_live or self.max_live, self.max_live))
        for pb in self.prompt_buckets.sizes:
            if pb > pb_cap:
                continue
            for b in self.batch_buckets.sizes:  # joiners batch per step
                if b > b_cap:
                    continue
                _, self.cache.k, self.cache.v = self._prefill(
                    self.params, self.cache.k, self.cache.v,
                    jnp.zeros((b, pb), jnp.int32), jnp.zeros((b,), jnp.int32),
                    jnp.zeros((b, pb // ps), jnp.int32))
        for b in self.batch_buckets.sizes:
            if b > b_cap:
                continue
            for mp in self.pages_buckets.sizes:
                if mp > mp_cap:
                    continue
                args = (self.params, self.cache.k, self.cache.v,
                        jnp.zeros((b, 1), jnp.int32), jnp.zeros((b,), jnp.int32),
                        jnp.zeros((b, mp), jnp.int32))
                if self.decode_quantum > 1:
                    args += (jnp.zeros((b,), jnp.int32),)
                _, self.cache.k, self.cache.v = self._decode(*args)
        jax.block_until_ready((self.cache.k, self.cache.v))
        return self.prefill_compiles + self.decode_compiles - before

"""Admission control for the serving engine: queue-or-reject at the door.

Continuous batching makes the page pool the real capacity limit — a request
admitted without pages for its *whole* lifetime (prompt + generated tokens)
would deadlock the decode loop mid-stream. The controller therefore gates
arrivals twice, before they ever touch the batcher:

* **token bucket** — a refill-rate / burst-capacity limiter on total tokens
  admitted per second. Arrivals that exceed the sustained rate are rejected
  immediately (shed at the door, not after they have held queue slots).
* **page headroom** — arrivals the rate admits but the pool cannot place
  *right now* go to a bounded FIFO queue; the batcher drains it as decode
  steps free pages. A full queue rejects.

Time is always passed in (``now``) rather than read from a clock, so the
benchmark can drive the controller on a virtual clock and trace replays are
deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

ADMIT = "admit"
QUEUE = "queue"
REJECT = "reject"


@dataclass
class TokenBucket:
    """Refill-rate limiter over admitted tokens. ``rate <= 0`` disables it."""

    rate: float  # tokens/s sustained
    burst: float  # bucket capacity (tokens)
    level: float = field(init=False)
    _t: float = field(init=False, default=0.0)

    def __post_init__(self):
        self.level = float(self.burst)

    def try_take(self, tokens: float, now: float) -> bool:
        if self.rate <= 0:
            return True
        self.level = min(self.burst, self.level + (now - self._t) * self.rate)
        self._t = now
        if tokens > self.level:
            return False
        self.level -= tokens
        return True


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued: int = 0
    rejected_rate: int = 0
    rejected_queue_full: int = 0

    @property
    def rejected(self) -> int:
        return self.rejected_rate + self.rejected_queue_full

    def as_dict(self) -> dict:
        return {
            "admitted": self.admitted,
            "queued": self.queued,
            "rejected": self.rejected,
            "rejected_rate": self.rejected_rate,
            "rejected_queue_full": self.rejected_queue_full,
        }


class AdmissionController:
    """Decide admit / queue / reject for an arrival.

    ``offer`` classifies one request given the pool's free pages *now*; the
    batcher owns the queue contents (it re-offers queued requests as pages
    free up via :meth:`can_place`). ``headroom_pages`` keeps a reserve the
    controller refuses to dip into — decode-time ``ensure`` growth of live
    sequences draws from that reserve instead of deadlocking.
    """

    def __init__(self, pool: Any, *, rate: float = 0.0, burst: float | None = None,
                 max_queue: int = 64, headroom_pages: int = 0):
        self.pool = pool
        self.bucket = TokenBucket(rate, burst if burst is not None else max(rate, 1.0))
        self.max_queue = int(max_queue)
        self.headroom_pages = int(headroom_pages)
        self.stats = AdmissionStats()

    def can_place(self, total_tokens: int) -> bool:
        """Pages available right now for a ``total_tokens``-lifetime request,
        leaving the headroom reserve untouched."""
        need = self.pool.pages_for(total_tokens)
        return need <= self.pool.free_pages - self.headroom_pages

    def offer(self, total_tokens: int, now: float, *, queue_depth: int) -> str:
        """Classify one arrival; updates counters. ``queue_depth`` is the
        batcher's current wait-queue length."""
        if not self.bucket.try_take(float(total_tokens), now):
            self.stats.rejected_rate += 1
            return REJECT
        if queue_depth == 0 and self.can_place(total_tokens):
            self.stats.admitted += 1
            return ADMIT
        if queue_depth >= self.max_queue:
            self.stats.rejected_queue_full += 1
            return REJECT
        self.stats.queued += 1
        return QUEUE

"""Paged KV cache: fixed-size cache pages + a free-list allocator.

The serving engine's memory problem (ROADMAP item 3) is that a dense
per-sequence KV cache must be sized for the *longest possible* context, so
heavy-tail prompt/output lengths strand most of the buffer. Paging fixes
that: the cache is one device-resident pool of ``n_pages`` fixed-size pages
per layer, sequences own *page tables* (host-side lists of page ids), and
finished sequences return their pages to the free list immediately — the
freed capacity admits the next queued prompt mid-stream.

Two layers:

* :class:`PagePool` — the pure host-side allocator. O(1) alloc/release via
  a free-list stack, atomic multi-page allocation (all-or-nothing), and an
  owner map whose invariants (no double allocation, conservation, live
  sequences keep their pages) are the hypothesis property suite in
  ``tests/test_serving_props.py``.
* :class:`PagedKVCache` — the device half: ``(L, n_pages, page_size, KV,
  hd)`` key/value arrays plus the pool. The jitted steps
  (``runtime/steps.py``) gather a sequence's logical context from its page
  table and scatter the new token's K/V back into its last page; this class
  only hands out tables and tracks ownership.

Page 0 is **reserved as a scratch page**: page tables are padded with 0, so
the prefill/decode scatters route padding-row writes into page 0 (harmless
garbage, masked by positions on read) instead of colliding with a live
sequence's pages. The allocator never hands out page 0.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np


class PageAllocError(RuntimeError):
    """A sequence asked for pages it cannot ever get (larger than the pool)."""


class PagePool:
    """Free-list allocator over ``n_pages`` pages of ``page_size`` tokens.

    Page 0 is reserved (scratch for padded table entries); ``capacity_pages``
    is therefore ``n_pages - 1``. Allocation is atomic: ``alloc`` either
    hands over all requested pages or none.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the reserved scratch page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently released (cache-warm) pages re-issue first;
        # deterministic order keeps trace replays bit-identical
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._owned: dict[Any, list[int]] = {}

    # ---- queries ----------------------------------------------------------

    @property
    def capacity_pages(self) -> int:
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.capacity_pages - self.free_pages

    @property
    def utilization(self) -> float:
        return self.used_pages / self.capacity_pages

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache entries."""
        return max(-(-int(n_tokens) // self.page_size), 0)

    def owned(self, seq: Any) -> list[int]:
        return list(self._owned.get(seq, ()))

    def capacity_tokens(self, seq: Any) -> int:
        """Cache entries ``seq``'s current pages can hold."""
        return len(self._owned.get(seq, ())) * self.page_size

    def sequences(self) -> set:
        return set(self._owned)

    # ---- allocation -------------------------------------------------------

    def alloc(self, seq: Any, n: int) -> bool:
        """Give ``seq`` ``n`` more pages; False (and no change) if the free
        list is short. Raises :class:`PageAllocError` if ``n`` exceeds the
        whole pool — that request could never succeed."""
        n = int(n)
        if n > self.capacity_pages:
            raise PageAllocError(
                f"{n} pages requested but the pool holds {self.capacity_pages}")
        if n > len(self._free):
            return False
        if n > 0:
            take = [self._free.pop() for _ in range(n)]
            self._owned.setdefault(seq, []).extend(take)
        elif seq not in self._owned:
            self._owned[seq] = []
        return True

    def ensure(self, seq: Any, n_tokens: int) -> bool:
        """Grow ``seq`` so its pages hold ``n_tokens`` entries (no-op when
        they already do). False (no change) when the pool is out of pages."""
        need = self.pages_for(n_tokens) - len(self._owned.get(seq, ()))
        if need <= 0:
            return True
        return self.alloc(seq, need)

    def release(self, seq: Any) -> int:
        """Return every page ``seq`` owns to the free list; number freed."""
        pages = self._owned.pop(seq, None)
        if not pages:
            return 0
        self._free.extend(reversed(pages))
        return len(pages)

    def reset(self) -> None:
        """Drop every owner (crash recovery: device pages are garbage)."""
        self._owned.clear()
        self._free = list(range(self.n_pages - 1, 0, -1))

    def check_invariants(self) -> None:
        """Assert allocator soundness (test hook; cheap enough for debug use)."""
        allocated = [p for pages in self._owned.values() for p in pages]
        assert 0 not in allocated, "scratch page 0 leaked into an owner"
        assert 0 not in self._free, "scratch page 0 leaked into the free list"
        assert len(set(allocated)) == len(allocated), "page double-allocated"
        assert not set(allocated) & set(self._free), "page both free and owned"
        assert len(allocated) + len(self._free) == self.capacity_pages, \
            "pages leaked or invented"


class PagedKVCache:
    """Device page pool + per-sequence page tables.

    ``k``/``v`` are the live device arrays, shape ``(L, n_pages, page_size,
    KV, hd)``; the jitted steps take and return them (donated), so callers
    re-assign after every step. Dtype/head geometry come from the *actual*
    prefill cache (``jax.eval_shape``), not ``cache_struct`` — reduced smoke
    configs run their cache in compute dtype, and a silent bf16 downcast
    here would make paged decode diverge from the dense path.
    """

    def __init__(self, n_layers: int, n_kv_heads: int, head_dim: int, *,
                 n_pages: int, page_size: int, dtype: Any = np.float32):
        import jax.numpy as jnp

        self.pool = PagePool(n_pages, page_size)
        self.page_size = self.pool.page_size
        shape = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self._shape = shape
        self._dtype = dtype

    @classmethod
    def from_model(cls, model: Any, *, n_pages: int, page_size: int) -> "PagedKVCache":
        import jax

        from repro.configs.base import ShapeConfig

        shape = ShapeConfig("paged-probe", page_size, 1, "prefill")
        struct = jax.eval_shape(
            model.prefill, model.param_struct(), model.input_specs(shape))[1]
        kv = struct["k"]  # (L, B, S, KV, hd)
        L, _, _, KV, hd = kv.shape
        return cls(L, KV, hd, n_pages=n_pages, page_size=page_size, dtype=kv.dtype)

    # ---- ownership (delegates to the pool) --------------------------------

    def admit(self, seq: Any, n_tokens: int) -> bool:
        """Allocate pages for a ``n_tokens``-entry prompt (bucket-padded
        length — the prefill scatter writes every bucket position)."""
        return self.pool.alloc(seq, self.pool.pages_for(n_tokens))

    def ensure(self, seq: Any, n_tokens: int) -> bool:
        return self.pool.ensure(seq, n_tokens)

    def release(self, seq: Any) -> int:
        return self.pool.release(seq)

    def reset(self) -> None:
        """Crash recovery: forget every owner and zero the device pages."""
        import jax.numpy as jnp

        self.pool.reset()
        self.k = jnp.zeros(self._shape, self._dtype)
        self.v = jnp.zeros(self._shape, self._dtype)

    # ---- tables -----------------------------------------------------------

    def table(self, seqs: Iterable[Any], width: int, rows: int | None = None,
              *, truncate: bool = False) -> np.ndarray:
        """``(rows, width)`` int32 page table: row i = seq i's pages, padded
        with the scratch page 0; extra rows (live-batch bucket padding) are
        all-scratch. ``truncate=True`` takes only the first ``width`` pages
        (the prefill scatter covers just the prompt-bucket prefix of a
        lifetime reservation); otherwise overflowing a row is an error."""
        seqs = list(seqs)
        rows = len(seqs) if rows is None else int(rows)
        out = np.zeros((rows, int(width)), np.int32)
        for i, s in enumerate(seqs):
            pages = self.pool._owned.get(s, ())
            if len(pages) > out.shape[1]:
                if not truncate:
                    raise ValueError(
                        f"seq {s!r} owns {len(pages)} pages > table width {width}")
                pages = pages[: out.shape[1]]
            out[i, : len(pages)] = pages
        return out

    @property
    def utilization(self) -> float:
        return self.pool.utilization

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

"""repro.serving — continuous-batching LM inference on a paged KV cache.

ROADMAP item 3: the serving-side answer to heavy-tail request loads. See
docs/serving.md for the architecture; the public surface is:

* :class:`~repro.serving.pages.PagePool` / :class:`~repro.serving.pages.PagedKVCache`
* :class:`~repro.serving.admission.AdmissionController`
* :class:`~repro.serving.batcher.ContinuousBatcher`
* :func:`~repro.serving.trace.heavy_tail_trace`
"""
from repro.serving.admission import ADMIT, QUEUE, REJECT, AdmissionController, TokenBucket
from repro.serving.batcher import ContinuousBatcher
from repro.serving.pages import PageAllocError, PagedKVCache, PagePool
from repro.serving.trace import Request, TraceConfig, heavy_tail_trace, trace_summary

__all__ = [
    "ADMIT", "QUEUE", "REJECT",
    "AdmissionController", "TokenBucket",
    "ContinuousBatcher",
    "PageAllocError", "PagedKVCache", "PagePool",
    "Request", "TraceConfig", "heavy_tail_trace", "trace_summary",
]

"""Seeded heavy-tail arrival traces for the serving benchmark.

LM serving load is famously *not* well modelled by fixed-size batches:
prompt and output lengths follow heavy-tail (approximately lognormal)
distributions, and it is exactly that variance that makes lockstep batching
slow — one p99 prompt holds the whole batch's time-to-first-token hostage.
This module generates the workload both serving modes are measured against:
Poisson arrivals with lognormal prompt/output lengths, fully determined by
a seed so lockstep and continuous runs (and replays across processes)
see byte-identical request streams.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Request:
    """One serving request: token ids to prefill, a generation budget."""

    rid: int
    arrival: float  # seconds since trace start
    prompt: tuple[int, ...]  # token ids
    out_tokens: int  # generation budget (EOS may stop earlier)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_tokens(self) -> int:
        """Cache-lifetime footprint: prompt + every generated token."""
        return len(self.prompt) + self.out_tokens


@dataclass(frozen=True)
class TraceConfig:
    """Knobs for :func:`heavy_tail_trace`; defaults give a tail where the
    p99 prompt is ~8x the median (sigma=0.8 lognormal)."""

    n_requests: int = 64
    seed: int = 0
    rate: float = 32.0  # mean arrivals/s (Poisson)
    prompt_median: int = 24
    prompt_sigma: float = 0.8
    out_median: int = 8
    out_sigma: float = 0.6
    max_prompt: int = 96
    max_output: int = 32
    vocab: int = 256


def heavy_tail_trace(cfg: TraceConfig = TraceConfig(), **overrides) -> list[Request]:
    """Generate the seeded trace. Same (cfg, overrides) -> identical list."""
    if overrides:
        cfg = TraceConfig(**{**cfg.__dict__, **overrides})
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.rate, cfg.n_requests)
    arrivals = np.cumsum(gaps)
    p_lens = np.clip(
        np.rint(rng.lognormal(np.log(cfg.prompt_median), cfg.prompt_sigma, cfg.n_requests)),
        1, cfg.max_prompt).astype(int)
    o_lens = np.clip(
        np.rint(rng.lognormal(np.log(cfg.out_median), cfg.out_sigma, cfg.n_requests)),
        1, cfg.max_output).astype(int)
    out = []
    for i in range(cfg.n_requests):
        # token 0 is reserved as EOS by the serving engine; draw from [1, vocab)
        prompt = rng.integers(1, cfg.vocab, p_lens[i]).astype(np.int32)
        out.append(Request(i, float(arrivals[i]), tuple(int(t) for t in prompt),
                           int(o_lens[i])))
    return out


def trace_summary(trace: list[Request]) -> dict:
    """Shape of the tail — recorded next to benchmark results."""
    p = np.array([r.prompt_len for r in trace])
    o = np.array([r.out_tokens for r in trace])
    return {
        "n_requests": len(trace),
        "duration_s": round(trace[-1].arrival, 3) if trace else 0.0,
        "prompt_p50": int(np.percentile(p, 50)),
        "prompt_p99": int(np.percentile(p, 99)),
        "output_p50": int(np.percentile(o, 50)),
        "output_p99": int(np.percentile(o, 99)),
        "total_tokens": int(p.sum() + o.sum()),
    }

"""Append-only partition log: offsets, retention, blocking reads, backpressure.

The in-memory equivalent of a Kafka partition. Thread-safe; producers block
(or drop/raise, per policy) when the partition's buffered bytes exceed
``max_buffer_bytes`` — this is the back-pressure mechanism whose system-level
consequences the paper is about.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.broker.records import Record


class BackpressureError(RuntimeError):
    pass


@dataclass
class PartitionStats:
    appended_records: int = 0
    appended_bytes: int = 0
    dropped_records: int = 0
    blocked_seconds: float = 0.0


class PartitionLog:
    """One partition: an append-only record log with absolute offsets."""

    def __init__(
        self,
        topic: str,
        partition: int,
        *,
        max_buffer_bytes: int = 1 << 30,
        retention_bytes: int | None = None,
        backpressure: str = "block",  # "block" | "drop" | "error"
        base_offset: int = 0,
    ):
        self.topic = topic
        self.partition = partition
        self.max_buffer_bytes = max_buffer_bytes
        self.retention_bytes = retention_bytes or max_buffer_bytes
        self.backpressure = backpressure
        self.stats = PartitionStats()
        self._records: list[Record] = []
        #: offset of _records[0]; a non-zero start keeps the offset space
        #: monotonic when a replacement log is created after data loss
        self._base_offset = base_offset
        self._bytes = 0
        self._lock = threading.Lock()
        self._data_ready = threading.Condition(self._lock)
        self._space_ready = threading.Condition(self._lock)
        self._closed = False

    # ---- producer side -----------------------------------------------------

    def _append_one_locked(self, record: Record, deadline: float | None,
                           timeout: float | None) -> int:
        """Backpressure + append for one record; caller holds the lock."""
        size = record.size()
        while self._bytes + size > self.max_buffer_bytes and not self._closed:
            if self.backpressure == "drop":
                self.stats.dropped_records += 1
                return -1
            if self.backpressure == "error":
                raise BackpressureError(
                    f"{self.topic}[{self.partition}] full ({self._bytes}B buffered)"
                )
            t0 = time.monotonic()
            remaining = None if deadline is None else deadline - t0
            if remaining is not None and remaining <= 0:
                raise BackpressureError(
                    f"{self.topic}[{self.partition}] blocked > {timeout}s"
                )
            self._space_ready.wait(timeout=remaining if remaining else 1.0)
            self.stats.blocked_seconds += time.monotonic() - t0
        if self._closed:
            raise RuntimeError("partition closed")
        offset = self._base_offset + len(self._records)
        rec = Record(record.value, record.key, record.timestamp, offset, record.headers)
        self._records.append(rec)
        self._bytes += size
        self.stats.appended_records += 1
        self.stats.appended_bytes += size
        return offset

    def append(self, record: Record, *, timeout: float | None = 30.0) -> int:
        with self._lock:
            deadline = None if timeout is None else time.monotonic() + timeout
            offset = self._append_one_locked(record, deadline, timeout)
            self._trim_locked()
            self._data_ready.notify_all()
            return offset

    def append_many(self, records: list[Record], *, timeout: float | None = 30.0,
                    total_bytes: int | None = None) -> list[int]:
        """Batch append under ONE lock acquisition with ONE ``notify_all``
        — the per-record lock/notify cost is what made a naive
        ``send_batch`` loop pointless. Offsets are contiguous (modulo
        drop-policy ``-1`` holes); backpressure policy applies per record
        against the shared deadline. ``total_bytes`` lets a caller that
        already summed record sizes (the token-bucket pass) skip the
        re-walk."""
        with self._lock:
            total = (sum(r.size() for r in records)
                     if total_bytes is None else total_bytes)
            if self._bytes + total <= self.max_buffer_bytes and not self._closed:
                # fast path: everything fits, so skip the per-record
                # backpressure machinery and bulk-assign offsets. Records
                # fresh off a producer (offset -1) are adopted in place —
                # the frozen-dataclass re-construction per record was the
                # hottest line of the batch produce path; anything already
                # offset-stamped (a replica pass) still gets a copy
                base = self._base_offset + len(self._records)
                store = self._records.append
                for i, r in enumerate(records):
                    if r.offset == -1:
                        r.offset = base + i
                        store(r)
                    else:
                        store(Record(r.value, r.key, r.timestamp,
                                     base + i, r.headers))
                self._bytes += total
                self.stats.appended_records += len(records)
                self.stats.appended_bytes += total
                offsets = list(range(base, base + len(records)))
            else:
                deadline = None if timeout is None else time.monotonic() + timeout
                offsets = [self._append_one_locked(r, deadline, timeout)
                           for r in records]
            self._trim_locked()
            self._data_ready.notify_all()
            return offsets

    def _trim_locked(self) -> None:
        while self._bytes > self.retention_bytes and len(self._records) > 1:
            victim = self._records.pop(0)
            self._bytes -= victim.size()
            self._base_offset += 1
            self._space_ready.notify_all()

    # ---- consumer side -------------------------------------------------------

    def read(self, offset: int, max_records: int = 512, timeout: float = 0.0) -> list[Record]:
        """Records with offsets >= ``offset`` (up to the high watermark)."""
        with self._lock:
            if timeout > 0:
                deadline = time.monotonic() + timeout
                while offset >= self._base_offset + len(self._records) and not self._closed:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._data_ready.wait(timeout=remaining)
            start = max(offset, self._base_offset) - self._base_offset
            if start >= len(self._records):
                return []
            return self._records[start : start + max_records]

    def ack(self, upto_offset: int) -> None:
        """Consumer-group ack: records below may be reclaimed for space."""
        with self._lock:
            cut = min(upto_offset, self._base_offset + len(self._records)) - self._base_offset
            for rec in self._records[:max(cut, 0)]:
                self._bytes -= rec.size()
            if cut > 0:
                self._records = self._records[cut:]
                self._base_offset += cut
                self._space_ready.notify_all()

    # ---- replication (follower side) ----------------------------------------

    def replicate_from(self, leader: "PartitionLog") -> None:
        """Catch this log up to an exact copy of ``leader`` (bootstrap of a
        fresh follower, or re-replication after a node loss). Records are
        immutable, so sharing them with the leader is safe; subsequent
        appends to either log do not alias the other's tail."""
        with leader._lock:
            records = list(leader._records)
            base = leader._base_offset
            nbytes = leader._bytes
        with self._lock:
            self._records = records
            self._base_offset = base
            self._bytes = nbytes
            self._data_ready.notify_all()

    # ---- introspection ----------------------------------------------------------

    @property
    def earliest(self) -> int:
        with self._lock:
            return self._base_offset

    @property
    def high_watermark(self) -> int:
        with self._lock:
            return self._base_offset + len(self._records)

    @property
    def buffered_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._data_ready.notify_all()
            self._space_ready.notify_all()

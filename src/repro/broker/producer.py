"""Producer: partition routing, serialization, rate control, metrics."""
from __future__ import annotations

import itertools
import threading
import time
import zlib
from typing import Any

import numpy as np

from repro.broker.cluster import BrokerCluster
from repro.broker.records import Record, encode_array, encode_msg


class Producer:
    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        serializer: str = "npy",  # "npy" | "msgpack" | "raw"
        compress: bool = False,
        rate_msgs_per_s: float | None = None,
    ):
        self.cluster = cluster
        self.topic = topic
        self.serializer = serializer
        self.compress = compress
        self.rate = rate_msgs_per_s
        self._rr = itertools.count()
        self._last_send = 0.0
        self._lock = threading.Lock()
        self.sent_records = 0
        self.sent_bytes = 0

    def _partition_for(self, key: bytes | None) -> int:
        n = self.cluster.topic(self.topic).n_partitions
        if key is None:
            return next(self._rr) % n
        return zlib.crc32(key) % n

    def _serialize(self, value: Any) -> bytes:
        if self.serializer == "raw":
            return value
        if self.serializer == "npy":
            return encode_array(np.asarray(value), compress=self.compress)
        return encode_msg(value, compress=self.compress)

    def send(self, value: Any, *, key: bytes | None = None, timestamp: float | None = None) -> int:
        if self.rate:
            with self._lock:
                wait = self._last_send + 1.0 / self.rate - time.monotonic()
                if wait > 0:
                    time.sleep(wait)
                self._last_send = time.monotonic()
        payload = self._serialize(value)
        rec = Record(payload, key, timestamp if timestamp is not None else time.time())
        part = self._partition_for(key)
        offset = self.cluster.append(self.topic, part, rec)
        if offset >= 0:
            self.sent_records += 1
            self.sent_bytes += rec.size()
        return offset

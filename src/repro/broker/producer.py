"""Producer: partition routing, serialization, rate control, retry, metrics.

Fault tolerance (docs/faults.md): ``send`` retries through transient
:class:`BrokerUnavailable` windows (leader election after a node loss) with
jittered exponential backoff, bounded by ``retry_timeout``; ``send_timeout``
additionally bounds the *total* time a single send may block — including a
stalled broker :class:`TokenBucket` — raising a typed
:class:`BrokerTimeout` instead of hanging. Retries are counted in
``retries`` and published as the ``broker.retries`` gauge when a metrics
bus is attached.
"""
from __future__ import annotations

import itertools
import random
import threading
import time
import zlib
from typing import Any

import numpy as np

from repro.broker.cluster import BrokerCluster
from repro.broker.errors import BrokerTimeout, BrokerUnavailable
from repro.broker.records import Record, encode_array, encode_msg
from repro.transport.frames import encode_frame
from repro.transport.plane import pack_row, slot_record_prefix
from repro.transport.ring import RingTimeout


class Producer:
    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        serializer: str = "npy",  # "npy" | "msgpack" | "raw"
        compress: bool = False,
        rate_msgs_per_s: float | None = None,
        send_timeout: float | None = None,
        retry_timeout: float = 10.0,
        metrics: Any | None = None,
        seed: int | None = None,
    ):
        self.cluster = cluster
        self.topic = topic
        self.serializer = serializer
        self.compress = compress
        self.rate = rate_msgs_per_s
        #: overall deadline for one ``send`` (token-bucket stalls included);
        #: None = block as long as it takes, the seed behavior
        self.send_timeout = send_timeout
        #: how long to keep retrying through BrokerUnavailable before
        #: giving up with BrokerTimeout
        self.retry_timeout = retry_timeout
        #: duck-typed MetricsBus: broker.retries published when set
        self.metrics = metrics
        self._rng = random.Random(seed)
        self._rr = itertools.count()
        #: start of the next unclaimed send slot on the rate schedule
        self._next_send = 0.0
        self._lock = threading.Lock()
        self.sent_records = 0
        self.sent_bytes = 0
        #: sends that hit a transient failover window and were reattempted
        self.retries = 0

    def _partition_for(self, key: bytes | None) -> int:
        n = self.cluster.topic(self.topic).n_partitions
        if key is None:
            return next(self._rr) % n
        return zlib.crc32(key) % n

    def _serialize(self, value: Any) -> bytes:
        if self.serializer == "raw":
            return value
        if self.serializer == "npy":
            return encode_array(np.asarray(value), compress=self.compress)
        return encode_msg(value, compress=self.compress)

    def _reserve_sends(self, n: int = 1) -> None:
        """Rate control without the convoy: claim the next ``n`` slots on
        the schedule *under* the lock (cheap), sleep until the claimed
        start *outside* it — concurrent sender threads each wait for their
        own slot instead of serializing behind one in-lock sleeper."""
        rate = self.rate
        if not rate:
            return
        with self._lock:
            now = time.monotonic()
            start = max(self._next_send, now)
            self._next_send = start + n / rate
        if start > now:
            time.sleep(start - now)

    def send(self, value: Any, *, key: bytes | None = None, timestamp: float | None = None) -> int:
        self._reserve_sends()
        payload = self._serialize(value)
        rec = Record(payload, key, timestamp if timestamp is not None else time.time())
        part = self._partition_for(key)
        offset = self._append_with_retry(part, rec)
        if offset >= 0:
            self.sent_records += 1
            self.sent_bytes += rec.size()
        return offset

    def send_batch(self, values, *, key: bytes | None = None,
                   timestamps: list[float] | None = None) -> list[int]:
        """Send a batch as one columnar frame. On an shm-mounted rf==1
        topic the payload is written ONCE into a ring slot and each record
        carries only an epoch-tagged slot handle; otherwise (rf>1, no
        transport, or a frame bigger than a slot) the copy-out fallback
        serializes per record through the log — same offsets-per-message
        semantics either way. The whole batch lands in one
        :meth:`BrokerCluster.append_many` (single lock/notify)."""
        if not len(values):
            return []
        n = len(values)
        self._reserve_sends(n)
        part = self._partition_for(key)
        now = time.monotonic()
        deadline = None if self.send_timeout is None else now + self.send_timeout
        ts_list = list(timestamps) if timestamps is not None else None
        base_ts = time.time()
        transport = getattr(self.cluster, "transport", None)
        ring = None
        if transport is not None:
            rf = self.cluster.topic(self.topic).replication_factor
            ring = transport.use_ring(self.topic, rf)
        if ring is not None:
            header, parts = encode_frame(values, ts_list, key)
            total = 4 + len(header) + sum(len(p) for p in parts)
            if total <= ring.slot_bytes:
                return self._send_frame(part, transport, ring, header, parts,
                                        total, n, ts_list, base_ts, key, deadline)
        records = [
            Record(self._serialize(v), key,
                   ts_list[row] if ts_list is not None else base_ts)
            for row, v in enumerate(values)
        ]
        offsets = self._append_many_with_retry(part, records, deadline)
        for rec, off in zip(records, offsets):
            if off >= 0:
                self.sent_records += 1
                self.sent_bytes += rec.size()
        return offsets

    def _send_frame(self, part, transport, ring, header, parts, total, n,
                    ts_list, base_ts, key, deadline) -> list[int]:
        try:
            slot, epoch = transport.write_frame(
                self.topic, header, parts, deadline=deadline)
        except RingTimeout as exc:
            raise BrokerTimeout(str(exc)) from None
        prefix = slot_record_prefix(ring.name, slot, epoch)
        records = [
            Record(prefix + pack_row(row), key,
                   ts_list[row] if ts_list is not None else base_ts)
            for row in range(n)
        ]
        try:
            offsets = self._append_many_with_retry(part, records, deadline)
        except Exception:
            transport.release(self.topic, slot, epoch)
            raise
        acked = [off for off in offsets if off >= 0]
        if not acked:
            transport.release(self.topic, slot, epoch)
            return offsets
        transport.track(self.topic, part, max(acked), slot, epoch)
        self.sent_records += len(acked)
        self.sent_bytes += total
        return offsets

    def _append_many_with_retry(self, part: int, records: list[Record],
                                deadline: float | None) -> list[int]:
        retry_until = time.monotonic() + self.retry_timeout
        if deadline is not None:
            retry_until = min(retry_until, deadline)
        backoff = 0.005
        while True:
            try:
                return self.cluster.append_many(self.topic, part, records,
                                                deadline=deadline)
            except BrokerUnavailable:
                self.retries += 1
                if self.metrics is not None:
                    self.metrics.publish("broker.retries", self.retries)
                now = time.monotonic()
                if now >= retry_until:
                    raise BrokerTimeout(
                        f"{self.topic}[{part}]: still unavailable after "
                        f"{self.retry_timeout:.1f}s of retries") from None
                sleep = min(backoff * (0.5 + self._rng.random()), retry_until - now)
                if sleep > 0:
                    time.sleep(sleep)
                backoff = min(backoff * 2, 0.25)

    def _append_with_retry(self, part: int, rec: Record) -> int:
        """Append, riding out failover blackouts with jittered exponential
        backoff. An offset is returned only once the record is on every
        replica (acks=all), so a retried send never loses an acked record."""
        now = time.monotonic()
        deadline = None if self.send_timeout is None else now + self.send_timeout
        retry_until = now + self.retry_timeout
        if deadline is not None:
            retry_until = min(retry_until, deadline)
        backoff = 0.005
        while True:
            try:
                return self.cluster.append(self.topic, part, rec, deadline=deadline)
            except BrokerUnavailable:
                self.retries += 1
                if self.metrics is not None:
                    self.metrics.publish("broker.retries", self.retries)
                now = time.monotonic()
                if now >= retry_until:
                    raise BrokerTimeout(
                        f"{self.topic}[{part}]: still unavailable after "
                        f"{self.retry_timeout:.1f}s of retries") from None
                sleep = min(backoff * (0.5 + self._rng.random()), retry_until - now)
                if sleep > 0:
                    time.sleep(sleep)
                backoff = min(backoff * 2, 0.25)

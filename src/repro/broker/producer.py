"""Producer: partition routing, serialization, rate control, retry, metrics.

Fault tolerance (docs/faults.md): ``send`` retries through transient
:class:`BrokerUnavailable` windows (leader election after a node loss) with
jittered exponential backoff, bounded by ``retry_timeout``; ``send_timeout``
additionally bounds the *total* time a single send may block — including a
stalled broker :class:`TokenBucket` — raising a typed
:class:`BrokerTimeout` instead of hanging. Retries are counted in
``retries`` and published as the ``broker.retries`` gauge when a metrics
bus is attached.
"""
from __future__ import annotations

import itertools
import random
import threading
import time
import zlib
from typing import Any

import numpy as np

from repro.broker.cluster import BrokerCluster
from repro.broker.errors import BrokerTimeout, BrokerUnavailable
from repro.broker.records import Record, encode_array, encode_msg


class Producer:
    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        serializer: str = "npy",  # "npy" | "msgpack" | "raw"
        compress: bool = False,
        rate_msgs_per_s: float | None = None,
        send_timeout: float | None = None,
        retry_timeout: float = 10.0,
        metrics: Any | None = None,
        seed: int | None = None,
    ):
        self.cluster = cluster
        self.topic = topic
        self.serializer = serializer
        self.compress = compress
        self.rate = rate_msgs_per_s
        #: overall deadline for one ``send`` (token-bucket stalls included);
        #: None = block as long as it takes, the seed behavior
        self.send_timeout = send_timeout
        #: how long to keep retrying through BrokerUnavailable before
        #: giving up with BrokerTimeout
        self.retry_timeout = retry_timeout
        #: duck-typed MetricsBus: broker.retries published when set
        self.metrics = metrics
        self._rng = random.Random(seed)
        self._rr = itertools.count()
        self._last_send = 0.0
        self._lock = threading.Lock()
        self.sent_records = 0
        self.sent_bytes = 0
        #: sends that hit a transient failover window and were reattempted
        self.retries = 0

    def _partition_for(self, key: bytes | None) -> int:
        n = self.cluster.topic(self.topic).n_partitions
        if key is None:
            return next(self._rr) % n
        return zlib.crc32(key) % n

    def _serialize(self, value: Any) -> bytes:
        if self.serializer == "raw":
            return value
        if self.serializer == "npy":
            return encode_array(np.asarray(value), compress=self.compress)
        return encode_msg(value, compress=self.compress)

    def send(self, value: Any, *, key: bytes | None = None, timestamp: float | None = None) -> int:
        if self.rate:
            with self._lock:
                wait = self._last_send + 1.0 / self.rate - time.monotonic()
                if wait > 0:
                    time.sleep(wait)
                self._last_send = time.monotonic()
        payload = self._serialize(value)
        rec = Record(payload, key, timestamp if timestamp is not None else time.time())
        part = self._partition_for(key)
        offset = self._append_with_retry(part, rec)
        if offset >= 0:
            self.sent_records += 1
            self.sent_bytes += rec.size()
        return offset

    def _append_with_retry(self, part: int, rec: Record) -> int:
        """Append, riding out failover blackouts with jittered exponential
        backoff. An offset is returned only once the record is on every
        replica (acks=all), so a retried send never loses an acked record."""
        now = time.monotonic()
        deadline = None if self.send_timeout is None else now + self.send_timeout
        retry_until = now + self.retry_timeout
        if deadline is not None:
            retry_until = min(retry_until, deadline)
        backoff = 0.005
        while True:
            try:
                return self.cluster.append(self.topic, part, rec, deadline=deadline)
            except BrokerUnavailable:
                self.retries += 1
                if self.metrics is not None:
                    self.metrics.publish("broker.retries", self.retries)
                now = time.monotonic()
                if now >= retry_until:
                    raise BrokerTimeout(
                        f"{self.topic}[{part}]: still unavailable after "
                        f"{self.retry_timeout:.1f}s of retries") from None
                sleep = min(backoff * (0.5 + self._rng.random()), retry_until - now)
                if sleep > 0:
                    time.sleep(sleep)
                backoff = min(backoff * 2, 0.25)

"""Records and serialization for the log-based broker.

Payloads are bytes on the wire (as in Kafka). Serializers provided:
``raw`` (bytes), ``npy`` (numpy arrays — the MASS/MASA data plane),
``msgpack`` (structured metadata). Optional zstd compression (the paper's
§5 calls out serialization formats/message sizes as first-order effects on
producer throughput).
"""
from __future__ import annotations

import io
import time
from dataclasses import dataclass, field
from typing import Any

import msgpack
import numpy as np

try:
    import zstandard as zstd

    _ZSTD = zstd.ZstdCompressor(level=1)
    _ZSTD_D = zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    _ZSTD = _ZSTD_D = None


@dataclass(slots=True)
class Record:
    """Treated as immutable once appended (logs share record objects
    across replicas); ``slots`` because producers mint one per message
    on the data-plane hot path."""

    value: bytes
    key: bytes | None = None
    timestamp: float = field(default_factory=time.time)
    offset: int = -1  # assigned by the partition log
    headers: dict = field(default_factory=dict)

    def size(self) -> int:
        return len(self.value) + (len(self.key) if self.key else 0)


# ---------------------------------------------------------------------------
# serde
# ---------------------------------------------------------------------------


def encode_array(arr: np.ndarray, *, compress: bool = False) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    data = buf.getvalue()
    if compress and _ZSTD is not None:
        return b"Z" + _ZSTD.compress(data)
    return b"N" + data


def decode_array(data: bytes) -> np.ndarray:
    tag, body = data[:1], data[1:]
    if tag == b"Z":
        body = _ZSTD_D.decompress(body)
    return np.load(io.BytesIO(body), allow_pickle=False)


def encode_msg(obj: Any, *, compress: bool = False) -> bytes:
    data = msgpack.packb(obj, use_bin_type=True)
    if compress and _ZSTD is not None:
        return b"Z" + _ZSTD.compress(data)
    return b"M" + data


def decode_msg(data: bytes) -> Any:
    tag, body = data[:1], data[1:]
    if tag == b"Z":
        body = _ZSTD_D.decompress(body)
    return msgpack.unpackb(body, raw=False)


_NPY_MAGIC = b"\x93NUMPY"


def decode_compressed(data: bytes) -> Any:
    """Decode a ``Z``-tagged payload; both serializers compress to the same
    tag, so the inner format is sniffed via the npy magic prefix."""
    if _ZSTD_D is None:
        raise RuntimeError("zstandard not available to decode compressed payload")
    body = _ZSTD_D.decompress(data[1:])
    if body[: len(_NPY_MAGIC)] == _NPY_MAGIC:
        return np.load(io.BytesIO(body), allow_pickle=False)
    return msgpack.unpackb(body, raw=False)

"""Broker cluster: topics, replicated partition placement, elastic scaling,
failures.

The unit Pilot-Streaming provisions ("a Kafka cluster on N nodes"). Each
node has a token-bucket I/O budget so broker-side contention — the
1-broker-bottleneck effect in the paper's Figs. 8/9 — is reproducible.
``add_node``/``remove_node`` rebalance partition placement at runtime
(the paper's cluster-extension capability, Listing 4).

Fault tolerance (docs/faults.md): ``create_topic(replication_factor=r)``
places each partition's log on ``r`` distinct nodes — one leader, ``r-1``
followers kept in sync by acks-all appends (an append returns only once
every replica holds the record, so an *acked* record survives any single
node loss). ``fail_node`` is a real crash now: the dead node's logs are
dropped; partitions with a surviving follower promote it (``failovers``
counts these, published as ``broker.failovers``), partitions without one
lose their retained records (``lost_records`` — the count the chaos suite
pins to zero for replicated topics). An optional ``blackout`` window keeps
the affected partitions unavailable for a moment, the leader-election gap
that exercises producer/consumer retry paths (``BrokerUnavailable``).
"""
from __future__ import annotations

import threading
import time
import weakref
from dataclasses import dataclass, field

from repro.broker.errors import BrokerTimeout, BrokerUnavailable
from repro.broker.log import PartitionLog
from repro.broker.records import Record


class TokenBucket:
    """Byte-rate limiter emulating a node's NIC/disk budget."""

    def __init__(self, rate_bytes_per_s: float | None):
        self.rate = rate_bytes_per_s
        self._tokens = float(rate_bytes_per_s or 0)
        self._last = time.monotonic()
        self._lock = threading.Lock()
        #: cumulative seconds callers spent blocked waiting for tokens —
        #: the saturation signal broker elasticity scales on
        self.stall_seconds = 0.0

    def consume(self, n: int, *, deadline: float | None = None) -> None:
        """Take ``n`` tokens, sleeping until the budget allows it. With a
        ``deadline`` (monotonic), a stall past it raises
        :class:`BrokerTimeout` instead of blocking forever."""
        if not self.rate:
            return
        with self._lock:
            while True:
                now = time.monotonic()
                self._tokens = min(self.rate, self._tokens + (now - self._last) * self.rate)
                self._last = now
                if self._tokens >= n:
                    self._tokens -= n
                    return
                if deadline is not None and now >= deadline:
                    raise BrokerTimeout(
                        f"token bucket stalled past deadline ({n}B wanted, "
                        f"{self._tokens:.0f} available at {self.rate:.0f} B/s)")
                wait = min((n - self._tokens) / self.rate, 0.1)
                if deadline is not None:
                    wait = min(wait, max(deadline - now, 0.001))
                self.stall_seconds += wait
                time.sleep(wait)


@dataclass
class BrokerNode:
    node_id: int
    io_rate: float | None = None  # bytes/s budget (None = unlimited)
    alive: bool = True
    bucket: TokenBucket = field(init=False)

    def __post_init__(self):
        self.bucket = TokenBucket(self.io_rate)


class Topic:
    """A named set of replicated partitions.

    ``replicas[p]`` maps node id -> that node's :class:`PartitionLog` copy;
    ``leaders[p]`` names the node whose copy serves reads and assigns
    offsets. ``partitions`` keeps the seed-era shape (a list of logs, one
    per partition) by resolving to the current leader copies.
    """

    def __init__(self, name: str, n_partitions: int, *,
                 replication_factor: int = 1, make_log=None):
        self.name = name
        self._n = n_partitions
        self.replication_factor = replication_factor
        self.replicas: dict[int, dict[int, PartitionLog]] = {
            p: {} for p in range(n_partitions)
        }
        self.leaders: dict[int, int] = {}
        self._make_log = make_log or (lambda p, base=0: PartitionLog(name, p, base_offset=base))

    @property
    def n_partitions(self) -> int:
        return self._n

    @property
    def partitions(self) -> list[PartitionLog]:
        return [self.replicas[p][self.leaders[p]] for p in range(self._n)]

    def leader_log(self, partition: int) -> PartitionLog:
        return self.replicas[partition][self.leaders[partition]]

    def holders(self, partition: int) -> list[int]:
        """Node ids holding a replica of ``partition`` (leader first)."""
        leader = self.leaders[partition]
        return [leader] + sorted(n for n in self.replicas[partition] if n != leader)


class BrokerCluster:
    """A set of broker nodes hosting replicated topic partitions."""

    def __init__(self, n_nodes: int = 1, *, io_rate_per_node: float | None = None,
                 metrics=None):
        self._lock = threading.RLock()
        self._nodes: dict[int, BrokerNode] = {}
        self._topics: dict[str, Topic] = {}
        self._offsets: dict[tuple[str, str, int], int] = {}  # (group, topic, part) -> committed
        self._next_node = 0
        self.io_rate_per_node = io_rate_per_node
        #: duck-typed MetricsBus: failover/loss gauges published when set
        self.metrics = metrics
        #: leader promotions after node loss (one per partition failed over)
        self.failovers = 0
        #: retained acked records dropped because a partition's only replica
        #: died — stays zero whenever replication_factor >= 2
        self.lost_records = 0
        #: injected extra latency per append/read (FaultInjector delay_io)
        self.io_delay = 0.0
        #: (topic, partition) -> monotonic instant until which the partition
        #: is leaderless (election in progress) — appends/reads raise
        #: BrokerUnavailable, producers/consumers retry through it
        self._blackout: dict[tuple[str, int], float] = {}
        #: per-partition placement epoch: bumped on any leader/holder change
        #: so an append that slept in a token bucket across a failover
        #: retries instead of landing on a stale replica set
        self._epoch: dict[tuple[str, int], int] = {}
        #: consumer groups to nudge (generation bump) after a node loss
        self._groups: list[weakref.ref] = []
        #: stall accumulated by since-removed nodes — keeps
        #: ``io_stall_seconds`` monotonic across scale-downs (a drop would
        #: read as a spurious idle tick to the saturation probe)
        self._retired_stall = 0.0
        #: optional shm data plane (repro.transport.ShmTransport); payload
        #: bytes then bypass the token buckets by design (same-host shared
        #: memory is not NIC traffic) but its allocator stall joins
        #: ``io_stall_seconds`` so saturation stays observable
        self.transport = None
        #: (group, topic, partition) -> replay horizon pinned by a
        #: checkpointing stream: slots must survive down to it, not just to
        #: the commit position, or crash recovery would replay into
        #: reclaimed frames
        self._replay_floors: dict[tuple[str, str, int], int] = {}
        for _ in range(n_nodes):
            self.add_node()

    # ---- cluster membership (elastic) -------------------------------------

    def add_node(self, io_rate: float | None = None) -> int:
        with self._lock:
            nid = self._next_node
            self._next_node += 1
            self._nodes[nid] = BrokerNode(nid, io_rate or self.io_rate_per_node)
            self._rebalance_locked()
            return nid

    def remove_node(self, node_id: int) -> None:
        """Graceful decommission: replicas are copied off before the node
        leaves, so no data is lost regardless of replication factor."""
        with self._lock:
            node = self._nodes.pop(node_id, None)
            if node is not None:
                self._retired_stall += node.bucket.stall_seconds
            self._rebalance_locked()

    def fail_node(self, node_id: int, *, blackout: float = 0.0) -> None:
        """Simulated crash: the node's replica logs are gone. Partitions it
        led promote a surviving follower (no acked-record loss — sync
        replication means followers hold everything ever acked); partitions
        whose *only* replica lived here lose their retained records, counted
        in ``lost_records``. ``blackout`` holds the affected partitions
        unavailable (``BrokerUnavailable``) for that many seconds — the
        leader-election window producer/consumer retries ride out."""
        with self._lock:
            node = self._nodes.get(node_id)
            if node is None:
                return
            node.alive = False
            self._retired_stall += node.bucket.stall_seconds
            until = time.monotonic() + blackout
            survivors = self._alive_nodes()
            for topic in self._topics.values():
                for p in range(topic.n_partitions):
                    if node_id not in topic.replicas[p]:
                        continue
                    dead_log = topic.replicas[p].pop(node_id)
                    self._epoch[(topic.name, p)] = self._epoch.get((topic.name, p), 0) + 1
                    if topic.leaders[p] != node_id:
                        continue  # follower loss: leader unaffected
                    if blackout > 0:
                        self._blackout[(topic.name, p)] = until
                    if topic.replicas[p]:
                        # promote the lowest surviving follower
                        topic.leaders[p] = min(topic.replicas[p])
                        self.failovers += 1
                        if self.metrics is not None:
                            self.metrics.publish("broker.failovers", self.failovers)
                    elif survivors:
                        # sole replica died: restart the partition empty at
                        # the old high watermark so offsets stay monotonic
                        lost = dead_log.high_watermark - dead_log.earliest
                        self.lost_records += lost
                        if self.metrics is not None:
                            self.metrics.publish("broker.lost_records", self.lost_records)
                        nid = survivors[0]
                        fresh = topic._make_log(p, base=dead_log.high_watermark)
                        topic.replicas[p][nid] = fresh
                        topic.leaders[p] = nid
            self._rebalance_locked()
            # nudge every consumer group: assignments are unchanged (the
            # partition count is), but members re-sync positions against the
            # promoted leaders on their next poll
            for ref in list(self._groups):
                group = ref()
                if group is None:
                    self._groups.remove(ref)
                else:
                    group.on_cluster_change()

    def _alive_nodes(self) -> list[int]:
        return sorted(n for n, node in self._nodes.items() if node.alive)

    def _rebalance_locked(self) -> None:
        """Re-spread leadership and restore each partition's replication
        factor over the alive node set (round-robin, deterministic). New
        holders bootstrap by copying the current leader's log — the
        in-process stand-in for follower catch-up replication."""
        nodes = self._alive_nodes()
        if not nodes:
            return
        for topic in sorted(self._topics):
            t = self._topics[topic]
            rf = min(t.replication_factor, len(nodes))
            for p in range(t.n_partitions):
                want = [nodes[(p + k) % len(nodes)] for k in range(rf)]
                want = list(dict.fromkeys(want))
                have = t.replicas[p]
                leader = t.leaders.get(p)
                src = have.get(leader)
                changed = False
                for nid in want:
                    if nid not in have:
                        log = t._make_log(p)
                        if src is not None:
                            log.replicate_from(src)
                        have[nid] = log
                        changed = True
                for nid in list(have):
                    if nid not in want:
                        del have[nid]
                        changed = True
                if t.leaders.get(p) != want[0]:
                    changed = True
                t.leaders[p] = want[0]
                if changed:
                    self._epoch[(topic, p)] = self._epoch.get((topic, p), 0) + 1

    @property
    def n_nodes(self) -> int:
        with self._lock:
            return len(self._alive_nodes())

    def io_stall_seconds(self) -> float:
        """Total time producers/consumers have spent blocked in this
        cluster's token buckets (cumulative and monotonic — removed nodes'
        stall is retained). The broker demand estimator differentiates
        this into a stall *fraction*. With an shm transport attached, slot
        allocator stall is included — a full ring is saturation too."""
        with self._lock:
            stall = self._retired_stall + sum(
                n.bucket.stall_seconds for n in self._nodes.values()
            )
            transport = self.transport
        if transport is not None:
            stall += transport.stall_seconds()
        return stall

    # ---- shm data plane (repro.transport) -----------------------------------

    def attach_transport(self, transport) -> None:
        """Mount an :class:`~repro.transport.ShmTransport` as this
        cluster's data plane. Topics the transport serves carry slot
        handles instead of payloads (rf==1 only; see docs/transport.md)."""
        with self._lock:
            self.transport = transport

    def set_replay_floor(self, group: str, topic: str,
                         positions: dict[int, int]) -> None:
        """A checkpointing stream pins its replay horizon: ring slots for
        ``topic`` stay live down to these offsets even as commits advance,
        so ``recover()`` can re-read from the checkpoint cut. Advancing
        the floor triggers a reclaim pass."""
        with self._lock:
            for p, off in positions.items():
                self._replay_floors[(group, topic, p)] = off
        for p in positions:
            self._maybe_reclaim(topic, p)

    def _reclaim_floor_locked(self, topic: str, partition: int) -> int | None:
        """min over registered consumer groups of each group's replay
        floor (when pinned) else its committed offset. None = no group is
        consuming this topic yet — nothing may be reclaimed."""
        floor = None
        for ref in self._groups:
            g = ref()
            if g is None or g.topic != topic:
                continue
            key = (g.group, topic, partition)
            pos = self._replay_floors.get(key)
            if pos is None:
                pos = self._offsets.get((g.group, topic, partition))
            if pos is None:
                return None  # registered group with no progress: hold all
            floor = pos if floor is None else min(floor, pos)
        return floor

    def _maybe_reclaim(self, topic: str, partition: int) -> None:
        with self._lock:
            transport = self.transport
            if transport is None or not transport.serves(topic):
                return
            floor = self._reclaim_floor_locked(topic, partition)
        if floor is not None:
            transport.reclaim_below(topic, partition, floor)

    # ---- fault-injection knobs (repro.faults) --------------------------------

    def set_io_delay(self, seconds: float) -> None:
        """Add ``seconds`` of latency to every append/read (the
        ``delay_io`` fault — a degraded interconnect/disk)."""
        self.io_delay = max(float(seconds), 0.0)

    def register_group(self, group) -> None:
        """Consumer groups register for post-failover generation bumps
        (held weakly; a closed group just drops out)."""
        with self._lock:
            self._groups.append(weakref.ref(group))

    # ---- topics ------------------------------------------------------------

    def create_topic(
        self,
        name: str,
        n_partitions: int,
        *,
        max_buffer_bytes: int = 1 << 30,
        backpressure: str = "block",
        replication_factor: int = 1,
    ) -> Topic:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} exists")
            if replication_factor < 1:
                raise ValueError("replication_factor must be >= 1")

            def make_log(p: int, base: int = 0) -> PartitionLog:
                return PartitionLog(name, p, max_buffer_bytes=max_buffer_bytes,
                                    backpressure=backpressure, base_offset=base)

            topic = Topic(name, n_partitions,
                          replication_factor=replication_factor,
                          make_log=make_log)
            self._topics[name] = topic
            self._rebalance_locked()
            return topic

    def topic(self, name: str) -> Topic:
        with self._lock:
            return self._topics[name]

    def delete_topic(self, name: str) -> None:
        with self._lock:
            topic = self._topics.pop(name, None)
            transport = self.transport
            if topic:
                for logs in topic.replicas.values():
                    for log in logs.values():
                        log.close()
        if topic and transport is not None:
            transport.unmount(name)  # unlinks the shm segment

    def close(self) -> None:
        """Tear the cluster down: close every log and unlink every shm
        segment (the pilot plugin's cancel path — a crashed or cancelled
        broker must not leak /dev/shm entries)."""
        for name in list(self._topics):
            self.delete_topic(name)
        with self._lock:
            transport = self.transport
            self.transport = None
        if transport is not None:
            transport.close()

    # ---- data plane (throttled by node budgets) ------------------------------

    def _check_available_locked(self, topic: str, partition: int) -> None:
        until = self._blackout.get((topic, partition))
        if until is not None:
            if time.monotonic() < until:
                raise BrokerUnavailable(
                    f"{topic}[{partition}]: leader election in progress")
            del self._blackout[(topic, partition)]

    def _resolve_locked(self, topic: str, partition: int):
        """(leader bucket | None, leader log, follower logs, epoch) — the
        placement snapshot one append/read operates on."""
        self._check_available_locked(topic, partition)
        t = self._topics[topic]
        leader = t.leaders[partition]
        node = self._nodes.get(leader)
        bucket = node.bucket if node is not None and node.alive else None
        followers = [log for nid, log in t.replicas[partition].items() if nid != leader]
        return bucket, t.replicas[partition][leader], followers, \
            self._epoch.get((topic, partition), 0)

    def append(self, topic: str, partition: int, record: Record,
               *, deadline: float | None = None) -> int:
        """Append with acks-all replication: the returned offset means every
        replica holds the record. Raises :class:`BrokerUnavailable` during a
        failover blackout (or when placement moved mid-append) — transient,
        the producer's retry loop handles it — and :class:`BrokerTimeout`
        when ``deadline`` passes inside the token bucket."""
        if self.io_delay:
            time.sleep(self.io_delay)
        with self._lock:
            bucket, _, _, epoch = self._resolve_locked(topic, partition)
        # the bucket may sleep; never hold the cluster lock across it
        if bucket is not None:
            bucket.consume(record.size(), deadline=deadline)
        with self._lock:
            self._check_available_locked(topic, partition)
            bucket2, leader, followers, epoch2 = self._resolve_locked(topic, partition)
            if epoch2 != epoch:
                raise BrokerUnavailable(
                    f"{topic}[{partition}]: placement changed mid-append")
            remaining = None if deadline is None else max(deadline - time.monotonic(), 0.001)
            offset = leader.append(record, timeout=remaining if deadline is not None else 30.0)
            if offset >= 0:
                for log in followers:  # acks=all: replicate before returning
                    log.append(record, timeout=remaining if deadline is not None else 30.0)
            return offset

    def append_many(self, topic: str, partition: int, records: list[Record],
                    *, deadline: float | None = None) -> list[int]:
        """Batch append with the same acks-all / blackout / epoch-recheck
        contract as :meth:`append`, but one token-bucket consume and one
        log lock acquisition for the whole batch."""
        if not records:
            return []
        if self.io_delay:
            time.sleep(self.io_delay)
        with self._lock:
            bucket, _, _, epoch = self._resolve_locked(topic, partition)
        total = sum(r.size() for r in records)
        if bucket is not None:
            bucket.consume(total, deadline=deadline)
        with self._lock:
            self._check_available_locked(topic, partition)
            _, leader, followers, epoch2 = self._resolve_locked(topic, partition)
            if epoch2 != epoch:
                raise BrokerUnavailable(
                    f"{topic}[{partition}]: placement changed mid-append")
            remaining = None if deadline is None else max(deadline - time.monotonic(), 0.001)
            timeout = remaining if deadline is not None else 30.0
            offsets = leader.append_many(records, timeout=timeout,
                                         total_bytes=total)
            appended = [r for r, o in zip(records, offsets) if o >= 0]
            for log in followers:  # acks=all: replicate before returning
                log.append_many(appended, timeout=timeout)
            return offsets

    def read(self, topic: str, partition: int, offset: int, max_records: int = 512,
             timeout: float = 0.0):
        if self.io_delay:
            time.sleep(self.io_delay)
        with self._lock:
            bucket, leader, _, _ = self._resolve_locked(topic, partition)
        recs = leader.read(offset, max_records, timeout)
        if recs and bucket is not None:
            bucket.consume(sum(r.size() for r in recs))
        return recs

    # ---- consumer-group offsets ------------------------------------------------

    def commit(self, group: str, topic: str, partition: int, offset: int) -> None:
        with self._lock:
            self._offsets[(group, topic, partition)] = offset
            has_transport = self.transport is not None
        if has_transport:
            # consumer progress is what frees ring slots (docs/transport.md)
            self._maybe_reclaim(topic, partition)

    def committed(self, group: str, topic: str, partition: int) -> int:
        with self._lock:
            return self._offsets.get((group, topic, partition), 0)

    def lag(self, group: str, topic: str) -> dict[int, int]:
        t = self.topic(topic)
        return {
            p.partition: p.high_watermark - self.committed(group, topic, p.partition)
            for p in t.partitions
        }

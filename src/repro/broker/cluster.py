"""Broker cluster: topics, partition->node placement, elastic scaling, failures.

The unit Pilot-Streaming provisions ("a Kafka cluster on N nodes"). Each
node has a token-bucket I/O budget so broker-side contention — the
1-broker-bottleneck effect in the paper's Figs. 8/9 — is reproducible.
``add_node``/``remove_node`` rebalance partition placement at runtime
(the paper's cluster-extension capability, Listing 4); ``fail_node``
exercises the fault-tolerance path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.broker.log import PartitionLog
from repro.broker.records import Record


class TokenBucket:
    """Byte-rate limiter emulating a node's NIC/disk budget."""

    def __init__(self, rate_bytes_per_s: float | None):
        self.rate = rate_bytes_per_s
        self._tokens = float(rate_bytes_per_s or 0)
        self._last = time.monotonic()
        self._lock = threading.Lock()
        #: cumulative seconds callers spent blocked waiting for tokens —
        #: the saturation signal broker elasticity scales on
        self.stall_seconds = 0.0

    def consume(self, n: int) -> None:
        if not self.rate:
            return
        with self._lock:
            while True:
                now = time.monotonic()
                self._tokens = min(self.rate, self._tokens + (now - self._last) * self.rate)
                self._last = now
                if self._tokens >= n:
                    self._tokens -= n
                    return
                wait = min((n - self._tokens) / self.rate, 0.1)
                self.stall_seconds += wait
                time.sleep(wait)


@dataclass
class BrokerNode:
    node_id: int
    io_rate: float | None = None  # bytes/s budget (None = unlimited)
    alive: bool = True
    bucket: TokenBucket = field(init=False)

    def __post_init__(self):
        self.bucket = TokenBucket(self.io_rate)


class Topic:
    def __init__(self, name: str, partitions: list[PartitionLog]):
        self.name = name
        self.partitions = partitions

    @property
    def n_partitions(self) -> int:
        return len(self.partitions)


class BrokerCluster:
    """A set of broker nodes hosting topic partitions."""

    def __init__(self, n_nodes: int = 1, *, io_rate_per_node: float | None = None):
        self._lock = threading.RLock()
        self._nodes: dict[int, BrokerNode] = {}
        self._topics: dict[str, Topic] = {}
        self._placement: dict[tuple[str, int], int] = {}  # (topic, part) -> node
        self._offsets: dict[tuple[str, str, int], int] = {}  # (group, topic, part) -> committed
        self._next_node = 0
        self.io_rate_per_node = io_rate_per_node
        #: stall accumulated by since-removed nodes — keeps
        #: ``io_stall_seconds`` monotonic across scale-downs (a drop would
        #: read as a spurious idle tick to the saturation probe)
        self._retired_stall = 0.0
        for _ in range(n_nodes):
            self.add_node()

    # ---- cluster membership (elastic) -------------------------------------

    def add_node(self, io_rate: float | None = None) -> int:
        with self._lock:
            nid = self._next_node
            self._next_node += 1
            self._nodes[nid] = BrokerNode(nid, io_rate or self.io_rate_per_node)
            self._rebalance_locked()
            return nid

    def remove_node(self, node_id: int) -> None:
        with self._lock:
            node = self._nodes.pop(node_id, None)
            if node is not None:
                self._retired_stall += node.bucket.stall_seconds
            self._rebalance_locked()

    def fail_node(self, node_id: int) -> None:
        """Simulated crash: partitions move to survivors (data retained —
        stand-in for replication)."""
        with self._lock:
            if node_id in self._nodes:
                self._nodes[node_id].alive = False
            self._rebalance_locked()

    def _alive_nodes(self) -> list[int]:
        return sorted(n for n, node in self._nodes.items() if node.alive)

    def _rebalance_locked(self) -> None:
        nodes = self._alive_nodes()
        if not nodes:
            return
        keys = sorted(self._placement)
        for i, key in enumerate(keys):
            self._placement[key] = nodes[i % len(nodes)]

    @property
    def n_nodes(self) -> int:
        with self._lock:
            return len(self._alive_nodes())

    def io_stall_seconds(self) -> float:
        """Total time producers/consumers have spent blocked in this
        cluster's token buckets (cumulative and monotonic — removed nodes'
        stall is retained). The broker demand estimator differentiates
        this into a stall *fraction*."""
        with self._lock:
            return self._retired_stall + sum(
                n.bucket.stall_seconds for n in self._nodes.values()
            )

    # ---- topics ------------------------------------------------------------

    def create_topic(
        self,
        name: str,
        n_partitions: int,
        *,
        max_buffer_bytes: int = 1 << 30,
        backpressure: str = "block",
    ) -> Topic:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} exists")
            parts = [
                PartitionLog(name, p, max_buffer_bytes=max_buffer_bytes, backpressure=backpressure)
                for p in range(n_partitions)
            ]
            topic = Topic(name, parts)
            self._topics[name] = topic
            nodes = self._alive_nodes()
            for p in range(n_partitions):
                self._placement[(name, p)] = nodes[p % len(nodes)]
            return topic

    def topic(self, name: str) -> Topic:
        with self._lock:
            return self._topics[name]

    def delete_topic(self, name: str) -> None:
        with self._lock:
            topic = self._topics.pop(name, None)
            if topic:
                for p in topic.partitions:
                    p.close()
                self._placement = {k: v for k, v in self._placement.items() if k[0] != name}

    # ---- data plane (throttled by node budgets) ------------------------------

    def _node_for(self, topic: str, partition: int) -> BrokerNode:
        with self._lock:
            nid = self._placement[(topic, partition)]
            return self._nodes[nid]

    def append(self, topic: str, partition: int, record: Record) -> int:
        node = self._node_for(topic, partition)
        node.bucket.consume(record.size())
        return self._topics[topic].partitions[partition].append(record)

    def read(self, topic: str, partition: int, offset: int, max_records: int = 512, timeout: float = 0.0):
        recs = self._topics[topic].partitions[partition].read(offset, max_records, timeout)
        if recs:
            node = self._node_for(topic, partition)
            node.bucket.consume(sum(r.size() for r in recs))
        return recs

    # ---- consumer-group offsets ------------------------------------------------

    def commit(self, group: str, topic: str, partition: int, offset: int) -> None:
        with self._lock:
            self._offsets[(group, topic, partition)] = offset

    def committed(self, group: str, topic: str, partition: int) -> int:
        with self._lock:
            return self._offsets.get((group, topic, partition), 0)

    def lag(self, group: str, topic: str) -> dict[int, int]:
        t = self.topic(topic)
        return {
            p.partition: p.high_watermark - self.committed(group, topic, p.partition)
            for p in t.partitions
        }

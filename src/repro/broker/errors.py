"""Typed broker errors — the vocabulary of the fault-tolerance layer.

Producers and consumers distinguish *transient* unavailability (a leader
election in progress after a node loss — retry with backoff) from a
*deadline* (the caller bounded how long it is willing to block — surface a
typed error instead of hanging). ``BackpressureError`` (repro.broker.log)
stays separate: it means the partition is full, a flow-control signal, not
a fault.
"""
from __future__ import annotations


class BrokerError(RuntimeError):
    """Base class for broker data-plane errors."""


class BrokerUnavailable(BrokerError):
    """The partition has no reachable leader right now (a failover is in
    flight, or placement changed mid-operation). Transient by contract:
    callers retry with jittered backoff; ``Producer.send`` and
    ``Consumer.poll`` do this built-in."""


class BrokerTimeout(BrokerError):
    """A bounded broker operation ran out of deadline — the token bucket
    stayed stalled past ``send_timeout``, or unavailability outlasted the
    retry budget. Raised instead of blocking forever."""

"""Consumer groups: partition assignment, rebalance, offset commits, lag.

Matches the Kafka semantics that the streaming engines rely on:
* group members share a topic's partitions (range assignment; deterministic);
* membership changes (join/leave/failure) trigger rebalance;
* offsets are explicit — commit-after-process gives at-least-once, and
  committing atomically with a state checkpoint gives exactly-once
  (engines/microbatch.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.records import Record, decode_array, decode_compressed, decode_msg


@dataclass
class Message:
    partition: int
    offset: int
    timestamp: float
    value: Any


def _deserialize(data: bytes) -> Any:
    """Explicit dispatch on the serde tag byte (records.py): ``N`` = npy,
    ``M`` = msgpack, ``Z`` = zstd-compressed either (the payload is sniffed
    after decompression). Unknown tags pass through as raw bytes; decode
    errors propagate instead of being masked by a cross-format fallback."""
    tag = data[:1]
    if tag == b"N":
        return decode_array(data)
    if tag == b"M":
        return decode_msg(data)
    if tag == b"Z":
        return decode_compressed(data)
    return data


class ConsumerGroup:
    """Coordinator for one (group, topic)."""

    def __init__(self, cluster: BrokerCluster, group: str, topic: str):
        self.cluster = cluster
        self.group = group
        self.topic = topic
        self._members: list[str] = []
        self._lock = threading.RLock()
        self._generation = 0

    def join(self, member_id: str) -> None:
        with self._lock:
            if member_id not in self._members:
                self._members.append(member_id)
                self._members.sort()
                self._generation += 1

    def leave(self, member_id: str) -> None:
        with self._lock:
            if member_id in self._members:
                self._members.remove(member_id)
                self._generation += 1

    def assignment(self, member_id: str) -> list[int]:
        """Range assignment of partitions for this member."""
        with self._lock:
            if member_id not in self._members:
                return []
            n_parts = self.cluster.topic(self.topic).n_partitions
            idx = self._members.index(member_id)
            n = len(self._members)
            per, extra = divmod(n_parts, n)
            start = idx * per + min(idx, extra)
            count = per + (1 if idx < extra else 0)
            return list(range(start, start + count))

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation


class Consumer:
    """One group member. ``poll`` round-robins its assigned partitions."""

    def __init__(
        self,
        cluster: BrokerCluster,
        group: ConsumerGroup,
        member_id: str,
        *,
        deserialize: bool = True,
        from_committed: bool = True,
        metrics: Any | None = None,
    ):
        self.cluster = cluster
        self.group = group
        self.member_id = member_id
        self.deserialize = deserialize
        #: duck-typed MetricsBus (repro.elastic.metrics): consumption
        #: counters are published per non-empty poll when set
        self.metrics = metrics
        group.join(member_id)
        self._positions: dict[int, int] = {}
        self._generation = -1
        self._from_committed = from_committed
        self.consumed_records = 0
        self.consumed_bytes = 0
        self._refresh_assignment()

    def _refresh_assignment(self) -> None:
        if self._generation == self.group.generation:
            return
        self._generation = self.group.generation
        parts = self.group.assignment(self.member_id)
        positions = {}
        for p in parts:
            if p in self._positions:
                positions[p] = self._positions[p]
            elif self._from_committed:
                positions[p] = self.cluster.committed(self.group.group, self.group.topic, p)
            else:
                positions[p] = self.cluster.topic(self.group.topic).partitions[p].high_watermark
        self._positions = positions

    @property
    def assignment(self) -> list[int]:
        self._refresh_assignment()
        return sorted(self._positions)

    def seek(self, partition: int, offset: int) -> None:
        self._positions[partition] = offset

    def poll(self, max_records: int = 512, timeout: float = 0.0) -> list[Message]:
        self._refresh_assignment()
        out: list[Message] = []
        deadline = time.monotonic() + timeout
        while not out:
            for p, pos in list(self._positions.items()):
                budget = max_records - len(out)
                if budget <= 0:
                    break
                recs = self.cluster.read(self.group.topic, p, pos, budget)
                for r in recs:
                    val = _deserialize(r.value) if self.deserialize else r.value
                    out.append(Message(p, r.offset, r.timestamp, val))
                    self.consumed_bytes += r.size()
                if recs:
                    self._positions[p] = recs[-1].offset + 1
            if out or time.monotonic() >= deadline:
                break
            time.sleep(0.002)
        self.consumed_records += len(out)
        if out and self.metrics is not None:
            self.metrics.publish("consumer.records", self.consumed_records,
                                 member=self.member_id)
            self.metrics.publish("consumer.bytes", self.consumed_bytes,
                                 member=self.member_id)
        return out

    def positions(self) -> dict[int, int]:
        return dict(self._positions)

    def commit(self, offsets: dict[int, int] | None = None) -> None:
        offsets = offsets if offsets is not None else self._positions
        for p, off in offsets.items():
            self.cluster.commit(self.group.group, self.group.topic, p, off)

    def rewind_to_committed(self) -> None:
        """Failure recovery: replay from last commit (exactly-once resume)."""
        for p in list(self._positions):
            self._positions[p] = self.cluster.committed(self.group.group, self.group.topic, p)

    def close(self) -> None:
        self.group.leave(self.member_id)

"""Consumer groups: partition assignment, rebalance, offset commits, lag.

Matches the Kafka semantics that the streaming engines rely on:
* group members share a topic's partitions (range assignment; deterministic);
* membership changes (join/leave/failure) trigger rebalance;
* offsets are explicit — commit-after-process gives at-least-once, and
  committing atomically with a state checkpoint gives exactly-once
  (engines/microbatch.py).

Fault tolerance (docs/faults.md): a group registers with its cluster so a
broker-node loss bumps the generation (members re-sync against promoted
leaders on their next poll). ``poll`` treats :class:`BrokerUnavailable`
from a failover blackout as "no data yet" — counted in ``retries``, never
raised into an engine loop. An optional ``max_lag`` turns unbounded lag
into graceful degradation: records beyond the bound are shed (skipped and
counted in ``shed_records`` / the ``broker.shed_records`` gauge) so a slow
consumer falls behind by a bounded amount instead of indefinitely.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.errors import BrokerUnavailable
from repro.broker.records import Record, decode_array, decode_compressed, decode_msg
from repro.transport.frames import FrameBatch, decode_frame
from repro.transport.plane import TAG_SLOT, FrameCache, decode_slot_record
from repro.transport.ring import SlotReclaimedError, get_ring


@dataclass(slots=True)
class Message:
    partition: int
    offset: int
    timestamp: float
    value: Any


@dataclass
class PolledBatch:
    """One frame's worth of messages from :meth:`Consumer.poll_batch` —
    values decoded once per frame (views into the ring when zero-copy),
    offsets/timestamps per element so commits stay record-granular."""

    partition: int
    offsets: list[int]
    timestamps: list[float]
    values: list
    #: the backing FrameBatch when this came off a ring slot (call
    #: ``frame.verify()`` after consuming zero-copy values); None for
    #: plain log records
    frame: FrameBatch | None = None

    def __len__(self) -> int:
        return len(self.values)


def _deserialize(data: bytes) -> Any:
    """Explicit dispatch on the serde tag byte (records.py): ``N`` = npy,
    ``M`` = msgpack, ``Z`` = zstd-compressed either (the payload is sniffed
    after decompression). ``S`` (a transport slot handle) is resolved by
    the Consumer, which holds the frame cache — here it passes through.
    Unknown tags pass through as raw bytes; decode errors propagate
    instead of being masked by a cross-format fallback."""
    tag = data[:1]
    if tag == b"N":
        return decode_array(data)
    if tag == b"M":
        return decode_msg(data)
    if tag == b"Z":
        return decode_compressed(data)
    return data


class ConsumerGroup:
    """Coordinator for one (group, topic)."""

    def __init__(self, cluster: BrokerCluster, group: str, topic: str):
        self.cluster = cluster
        self.group = group
        self.topic = topic
        self._members: list[str] = []
        self._lock = threading.RLock()
        self._generation = 0
        register = getattr(cluster, "register_group", None)
        if register is not None:
            register(self)

    def join(self, member_id: str) -> None:
        with self._lock:
            if member_id not in self._members:
                self._members.append(member_id)
                self._members.sort()
                self._generation += 1

    def leave(self, member_id: str) -> None:
        with self._lock:
            if member_id in self._members:
                self._members.remove(member_id)
                self._generation += 1

    def on_cluster_change(self) -> None:
        """Cluster callback after a node loss/failover: bump the generation
        so every member refreshes its assignment (and clamps positions
        against the promoted leaders) on its next poll."""
        with self._lock:
            self._generation += 1

    def assignment(self, member_id: str) -> list[int]:
        """Range assignment of partitions for this member."""
        with self._lock:
            if member_id not in self._members:
                return []
            n_parts = self.cluster.topic(self.topic).n_partitions
            idx = self._members.index(member_id)
            n = len(self._members)
            per, extra = divmod(n_parts, n)
            start = idx * per + min(idx, extra)
            count = per + (1 if idx < extra else 0)
            return list(range(start, start + count))

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation


class Consumer:
    """One group member. ``poll`` round-robins its assigned partitions."""

    def __init__(
        self,
        cluster: BrokerCluster,
        group: ConsumerGroup,
        member_id: str,
        *,
        deserialize: bool = True,
        from_committed: bool = True,
        max_lag: int | None = None,
        metrics: Any | None = None,
        zero_copy: bool = False,
    ):
        self.cluster = cluster
        self.group = group
        self.member_id = member_id
        self.deserialize = deserialize
        #: shm topics only: hand out frombuffer views into the ring instead
        #: of copying frames out. Safe when values are consumed before the
        #: next commit advances the reclaim floor (micro-batch, bulk
        #: loaders); buffering consumers keep the default copy-out.
        self.zero_copy = zero_copy
        self._frames = FrameCache()
        #: lag bound per partition: poll sheds (skips) records older than
        #: ``high_watermark - max_lag`` instead of falling behind unboundedly.
        #: None = consume everything, the seed behavior.
        self.max_lag = max_lag
        #: duck-typed MetricsBus (repro.elastic.metrics): consumption
        #: counters are published per non-empty poll when set
        self.metrics = metrics
        group.join(member_id)
        self._positions: dict[int, int] = {}
        self._generation = -1
        self._from_committed = from_committed
        self.consumed_records = 0
        self.consumed_bytes = 0
        #: polls that hit a failover blackout and treated it as empty
        self.retries = 0
        #: records skipped by the max_lag degraded mode
        self.shed_records = 0
        #: extra sleep before every poll — the ``slow_consumer`` fault knob
        #: (repro.faults); processing slows down, outputs stay identical
        self.injected_poll_delay = 0.0

    def _refresh_assignment(self) -> None:
        if self._generation == self.group.generation:
            return
        self._generation = self.group.generation
        parts = self.group.assignment(self.member_id)
        positions = {}
        for p in parts:
            if p in self._positions:
                positions[p] = self._positions[p]
            elif self._from_committed:
                positions[p] = self.cluster.committed(self.group.group, self.group.topic, p)
            else:
                positions[p] = self.cluster.topic(self.group.topic).partitions[p].high_watermark
        self._positions = positions

    @property
    def assignment(self) -> list[int]:
        self._refresh_assignment()
        return sorted(self._positions)

    def seek(self, partition: int, offset: int) -> None:
        self._positions[partition] = offset

    def _shed_locked(self, p: int, pos: int) -> int:
        """Degraded mode: jump the position forward when lag exceeds
        ``max_lag``, counting the skipped records as shed."""
        hw = self.cluster.topic(self.group.topic).partitions[p].high_watermark
        floor = hw - self.max_lag
        if pos < floor:
            self.shed_records += floor - pos
            if self.metrics is not None:
                self.metrics.publish("broker.shed_records", self.shed_records,
                                     member=self.member_id)
            self._positions[p] = floor
            return floor
        return pos

    def poll(self, max_records: int = 512, timeout: float = 0.0) -> list[Message]:
        if self.injected_poll_delay > 0:
            time.sleep(self.injected_poll_delay)
        self._refresh_assignment()
        out: list[Message] = []
        deadline = time.monotonic() + timeout
        while not out:
            for p, pos in list(self._positions.items()):
                budget = max_records - len(out)
                if budget <= 0:
                    break
                if self.max_lag is not None:
                    pos = self._shed_locked(p, pos)
                try:
                    recs = self.cluster.read(self.group.topic, p, pos, budget)
                except BrokerUnavailable:
                    # leader election in flight — same as "nothing yet";
                    # the next poll retries against the promoted leader
                    self.retries += 1
                    if self.metrics is not None:
                        self.metrics.publish("broker.retries", self.retries,
                                             member=self.member_id)
                    continue
                deser = self.deserialize
                frame_value = self._frame_value
                append = out.append
                consumed = 0
                for r in recs:
                    v = r.value
                    if deser and v[:1] == TAG_SLOT:
                        val = frame_value(v)
                        nb = getattr(val, "nbytes", None)
                        consumed += int(nb) if nb is not None else r.size()
                    else:
                        val = _deserialize(v) if deser else v
                        consumed += r.size()
                    append(Message(p, r.offset, r.timestamp, val))
                self.consumed_bytes += consumed
                if recs:
                    self._positions[p] = recs[-1].offset + 1
            if out or time.monotonic() >= deadline:
                break
            time.sleep(0.002)
        self.consumed_records += len(out)
        if out and self.metrics is not None:
            self.metrics.publish("consumer.records", self.consumed_records,
                                 member=self.member_id)
            self.metrics.publish("consumer.bytes", self.consumed_bytes,
                                 member=self.member_id)
        return out

    # ---- shm frames (repro.transport) ---------------------------------------

    def _decoded_frame(self, name: str, slot: int, epoch: int) -> FrameBatch:
        """Decode a slot's frame once per (slot, epoch) incarnation; every
        record of the frame resolves against the cached decode."""
        key = (name, slot, epoch, self.zero_copy)
        frame = self._frames.get(key)
        if frame is None:
            ring = get_ring(name)
            frame = decode_frame(ring.view(slot, epoch), zero_copy=self.zero_copy,
                                 source=(name, slot, epoch))
            if not self.zero_copy and not ring.is_valid(slot, epoch):
                # the copy-out raced a reclaim: the copied bytes may be torn
                raise SlotReclaimedError(
                    f"{name} slot {slot} reclaimed during copy-out")
            self._frames.put(key, frame)
        return frame

    def _frame_value(self, data: bytes):
        # the cache key is the record's raw prefix (ring name + slot +
        # epoch, everything but the trailing row) — the 15 siblings of a
        # frame's first record hit the cache without parsing anything
        key = (data[:-4], self.zero_copy)
        frame = self._frames.get(key)
        if frame is None:
            name, slot, epoch, _ = decode_slot_record(data)
            frame = self._decoded_frame(name, slot, epoch)
            self._frames.put(key, frame)
        return frame.values[int.from_bytes(data[-4:], "little")]

    def poll_batch(self, max_records: int = 512, timeout: float = 0.0,
                   *, zero_copy: bool | None = None) -> list[PolledBatch]:
        """Frame-granular poll: runs of records backed by the same ring
        slot come back as ONE :class:`PolledBatch` (decoded once, values
        as views when zero-copy), plain records as singleton batches.
        Positions advance exactly as :meth:`poll` — ``commit()`` after
        processing keeps the at-least-once contract unchanged."""
        if zero_copy is None:
            zero_copy = self.zero_copy
        if self.injected_poll_delay > 0:
            time.sleep(self.injected_poll_delay)
        self._refresh_assignment()
        out: list[PolledBatch] = []
        deadline = time.monotonic() + timeout
        while not out:
            for p, pos in list(self._positions.items()):
                if self.max_lag is not None:
                    pos = self._shed_locked(p, pos)
                try:
                    recs = self.cluster.read(self.group.topic, p, pos, max_records)
                except BrokerUnavailable:
                    self.retries += 1
                    continue
                i = 0
                while i < len(recs):
                    r = recs[i]
                    if self.deserialize and r.value[:1] == TAG_SLOT:
                        name, slot, epoch, _ = decode_slot_record(r.value)
                        rows, offsets, stamps = [], [], []
                        while i < len(recs) and recs[i].value[:1] == TAG_SLOT:
                            n2, s2, e2, row2 = decode_slot_record(recs[i].value)
                            if (n2, s2, e2) != (name, slot, epoch):
                                break
                            rows.append(row2)
                            offsets.append(recs[i].offset)
                            stamps.append(recs[i].timestamp)
                            i += 1
                        saved, self.zero_copy = self.zero_copy, zero_copy
                        try:
                            frame = self._decoded_frame(name, slot, epoch)
                        finally:
                            self.zero_copy = saved
                        values = [frame.values[row] for row in rows]
                        out.append(PolledBatch(p, offsets, stamps, values, frame))
                        self.consumed_bytes += sum(
                            int(getattr(v, "nbytes", 0)) for v in values)
                    else:
                        val = _deserialize(r.value) if self.deserialize else r.value
                        out.append(PolledBatch(p, [r.offset], [r.timestamp], [val]))
                        self.consumed_bytes += r.size()
                        i += 1
                if recs:
                    self._positions[p] = recs[-1].offset + 1
            if out or time.monotonic() >= deadline:
                break
            time.sleep(0.002)
        n = sum(len(b) for b in out)
        self.consumed_records += n
        if out and self.metrics is not None:
            self.metrics.publish("consumer.records", self.consumed_records,
                                 member=self.member_id)
        return out

    def positions(self) -> dict[int, int]:
        return dict(self._positions)

    def commit(self, offsets: dict[int, int] | None = None) -> None:
        offsets = offsets if offsets is not None else self._positions
        for p, off in offsets.items():
            self.cluster.commit(self.group.group, self.group.topic, p, off)

    def rewind_to_committed(self) -> None:
        """Failure recovery: replay from last commit (exactly-once resume)."""
        for p in list(self._positions):
            self._positions[p] = self.cluster.committed(self.group.group, self.group.topic, p)

    def release_frames(self) -> None:
        """Drop the decoded-frame cache: zero-copy frames pin ring buffers,
        and a pinned buffer blocks clean segment unlink at shutdown.
        Engines call this on stop; it does not leave the group."""
        self._frames.clear()

    def close(self) -> None:
        self.release_frames()
        self.group.leave(self.member_id)

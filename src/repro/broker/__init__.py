"""Log-based message broker (Kafka analog) — host-side data plane."""
from repro.broker.cluster import BrokerCluster, BrokerNode, Topic
from repro.broker.consumer import Consumer, ConsumerGroup, Message, PolledBatch
from repro.broker.errors import BrokerError, BrokerTimeout, BrokerUnavailable
from repro.broker.log import BackpressureError, PartitionLog
from repro.broker.producer import Producer
from repro.broker.records import Record, decode_array, decode_msg, encode_array, encode_msg

__all__ = [
    "BackpressureError",
    "BrokerCluster",
    "BrokerError",
    "BrokerNode",
    "BrokerTimeout",
    "BrokerUnavailable",
    "Consumer",
    "ConsumerGroup",
    "Message",
    "PartitionLog",
    "PolledBatch",
    "Producer",
    "Record",
    "Topic",
    "decode_array",
    "decode_msg",
    "encode_array",
    "encode_msg",
]

"""Streaming-training driver: MASS token source -> broker -> micro-batch
train loop, with checkpointing and exactly-once offsets.

This is the paper's Type-2 pipeline (simulation/corpus -> analysis) with the
assigned LM architectures as the analysis stage. On CPU use a reduced config:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 20 --seq-len 128 --batch 8
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs.registry import get_arch
from repro.checkpoint import CheckpointManager
from repro.core import PilotComputeService
from repro.elastic import MetricsBus
from repro.launch import instrumented
from repro.miniapps import LMTrainApp, SourceConfig, TokenSource
from repro.runtime.optimizer import OptimizerConfig
from repro.scheduler import ResourceRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8, help="sequences per train step")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--broker-nodes", type=int, default=2)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    bus = MetricsBus()
    svc = PilotComputeService(metrics=bus)
    kafka = svc.submit_pilot({"number_of_nodes": args.broker_nodes, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("tokens", args.partitions)
    spark = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
    ctx = spark.get_context()
    # file the training pilot's demand with the service's arbiter: a static
    # reservation today, but pipelines sharing this pool now see (and must
    # schedule around) the trainer's devices
    held = len(spark.lease.devices)
    svc.get_arbiter(bus).submit(ResourceRequest(
        "launch/train", min_devices=held, max_devices=held, target=held,
        current_fn=lambda: len(spark.lease.devices)))

    opt = OptimizerConfig(name=cfg.optimizer, learning_rate=args.lr, warmup_steps=5,
                          total_steps=max(args.steps, 10))
    app = LMTrainApp(cfg, opt_cfg=opt, seqs_per_step=args.batch, seq_len=args.seq_len)
    ckpt = CheckpointManager(args.checkpoint_dir, keep_last=2, async_save=True)

    state = None
    if args.resume and ckpt.latest_step() is not None:
        template = app.init_state()
        state, meta = ckpt.restore(template)
        print(f"[train] resumed from step {ckpt.latest_step()} (offsets {meta.get('offsets')})")

    source = TokenSource(
        cluster,
        SourceConfig("tokens", total_messages=args.steps * 2 + 8, n_producers=2),
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len,
        seqs_per_msg=args.batch,
    ).start()

    def checkpoint_fn(state, offsets):
        step = app.stats.batches
        if step % args.checkpoint_every == 0 and state is not None:
            ckpt.save(step, state, meta={"offsets": offsets, "arch": cfg.name})

    stream = ctx.stream(
        cluster, "tokens", group="trainer",
        process_fn=instrumented(app, bus, "train"), state=state,
        batch_interval=0.2, max_batch_records=1, checkpoint_fn=checkpoint_fn,
        metrics=bus, metrics_label="train",
    ).start()

    t0 = time.time()
    stream.await_batches(args.steps, timeout=3600)
    stream.stop()
    source.stop()
    ckpt.wait()
    dt = time.time() - t0
    toks = app.stats.items
    print(
        f"[train] {app.stats.batches} steps, {toks} tokens in {dt:.1f}s "
        f"({toks/dt:.0f} tok/s); loss {app.losses[0]:.3f} -> {app.losses[-1]:.3f}"
    )
    print(f"[train] bus: step_time={bus.value('train.step_time', stream='train'):.3f}s "
          f"tokens_per_sec={bus.value('train.tokens_per_sec', stream='train'):.0f}")
    svc.cancel()


if __name__ == "__main__":
    main()

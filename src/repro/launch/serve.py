"""Streaming-serving driver: request stream -> broker -> prefill/decode.

The paper's Type-1 pipeline (external instrument -> analysis): requests are
token prompts; the MASA serving app prefills and decodes a fixed budget per
request batch.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 8 --gen-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core import PilotComputeService
from repro.elastic import MetricsBus
from repro.launch import instrumented
from repro.miniapps import LMServeApp, SourceConfig, TokenSource
from repro.scheduler import ResourceRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="request batches to serve")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    bus = MetricsBus()
    svc = PilotComputeService(metrics=bus)
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("requests", 2)
    spark = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
    ctx = spark.get_context()
    held = len(spark.lease.devices)
    svc.get_arbiter(bus).submit(ResourceRequest(
        "launch/serve", min_devices=held, max_devices=held, target=held,
        current_fn=lambda: len(spark.lease.devices)))

    app = LMServeApp(cfg, prompt_len=args.prompt_len, gen_tokens=args.gen_tokens, batch=args.batch)
    params = app.model.init(jax.random.key(0))

    source = TokenSource(
        cluster,
        SourceConfig("requests", total_messages=args.requests),
        vocab_size=cfg.vocab_size,
        seq_len=args.prompt_len,
        seqs_per_msg=args.batch,
    ).start()

    stream = ctx.stream(
        cluster, "requests", group="server",
        process_fn=instrumented(app, bus, "serve"), state=params,
        batch_interval=0.1, max_batch_records=1,
        metrics=bus, metrics_label="serve",
    ).start()
    t0 = time.time()
    stream.await_batches(args.requests, timeout=3600)
    stream.stop()
    source.stop()
    dt = time.time() - t0
    print(
        f"[serve] {app.stats.messages} request batches, {app.stats.items} tokens "
        f"generated in {dt:.1f}s ({app.stats.items/dt:.1f} tok/s)"
    )
    print(f"[serve] bus: step_time={bus.value('serve.step_time', stream='serve'):.3f}s "
          f"tokens_per_sec={bus.value('serve.tokens_per_sec', stream='serve'):.0f}")
    svc.cancel()


if __name__ == "__main__":
    main()

"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (per-device; the SPMD-partitioned HLO has per-device shapes, so the
trip-count-corrected analyzer outputs are already per-chip):

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = collective_bytes / link_bw        (~50 GB/s ICI)

MODEL_FLOPS = 6*N*D (train, dense), 6*N_active*D (train, MoE), 2*N*D
(inference), D = tokens processed per step. The roofline fraction is
ideal_compute_time / max(term) — the score a perfect overlap schedule would
achieve given the compiled ops.

  PYTHONPATH=src python -m repro.launch.roofline --in benchmarks/dryrun_baseline.json
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_PARAM_CACHE: dict[str, tuple[int, int]] = {}


def param_counts(arch: str) -> tuple[int, int]:
    if arch not in _PARAM_CACHE:
        from repro.configs.registry import get_arch

        cfg = get_arch(arch)
        _PARAM_CACHE[arch] = (cfg.param_count(), cfg.active_param_count())
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape_kind: str, seq_len: int, global_batch: int, chips: int) -> float:
    n_total, n_active = param_counts(arch)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens / chips
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * global_batch / chips


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    fraction: float
    #: decode shapes are inherently memory-bound: efficiency is measured
    #: against the *memory* roofline (params + cache read once per step)
    mem_fraction: float = 0.0

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def score(self) -> float:
        """Roofline fraction on the appropriate axis for the shape kind."""
        return self.mem_fraction if self.shape.startswith(("decode", "long")) else self.fraction


_IDEAL_BYTES_CACHE: dict[tuple[str, str], float] = {}


def ideal_decode_bytes_per_chip(arch: str, shape_name: str, chips: int) -> float:
    """Minimum HBM traffic per decode step: param shard + KV/state shard,
    each read once."""
    key = (arch, shape_name)
    if key not in _IDEAL_BYTES_CACHE:
        from repro.configs.registry import get_arch, get_shape
        from repro.models import build_model
        from repro.utils.tree import tree_bytes

        cfg = get_arch(arch)
        model = build_model(cfg)
        shape = get_shape(shape_name)
        _IDEAL_BYTES_CACHE[key] = float(
            tree_bytes(model.param_struct()) + tree_bytes(model.cache_struct(shape))
        )
    return _IDEAL_BYTES_CACHE[key] / chips


_SUGGESTIONS = {
    "compute": "reduce redundant compute: selective remat / causal-skip attention / smaller capacity factor",
    "memory": "raise arithmetic intensity: larger per-chip batch, fused kernels, bf16 end-to-end",
    "collective": "cut collective volume: reduce-scatter instead of all-gather, ring attention, quantized cross-pod grads",
}


def analyze_record(rec: dict) -> RooflineRow | None:
    if "hlo" not in rec:
        return None
    from repro.configs.registry import get_shape

    shape = get_shape(rec["shape"])
    hlo = rec["hlo"]
    compute = hlo["flops_per_device"] / PEAK_FLOPS
    # fused-model bytes = TPU-realistic HBM traffic; the conservative
    # every-op model is reported alongside as the upper bound
    memory = hlo.get("bytes_fused_per_device", hlo["bytes_per_device"]) / HBM_BW
    collective = hlo["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], shape.kind, shape.seq_len, shape.global_batch, rec["chips"])
    ideal = mf / PEAK_FLOPS
    fraction = ideal / max(max(terms.values()), 1e-30)
    mem_fraction = 0.0
    if shape.kind == "decode":
        ideal_mem = ideal_decode_bytes_per_chip(rec["arch"], rec["shape"], rec["chips"]) / HBM_BW
        mem_fraction = ideal_mem / max(max(memory, collective), 1e-30)
    return RooflineRow(
        rec["arch"], rec["shape"], rec["mesh"], compute, memory, collective,
        dominant, mf, hlo["flops_per_device"], fraction, mem_fraction,
    )


def render_markdown(rows: list[RooflineRow]) -> str:
    out = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | bottleneck | MODEL/HLO flops | roofline fraction* |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        frac = f"{r.score:.1%}" + (" (mem)" if r.shape.startswith(("decode", "long")) else "")
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.dominant}** | {r.useful_ratio:.2f} | {frac} |"
        )
    out.append("")
    out.append("\\* train/prefill: fraction of the bf16 compute roofline; "
               "decode: fraction of the HBM roofline (params+cache read once per step).")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="infile", required=True)
    ap.add_argument("--out", default=None, help="write markdown here")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    with open(args.infile) as f:
        records = json.load(f)
    rows, skips = [], []
    for rec in records:
        if "skipped" in rec:
            skips.append(rec)
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    md = render_markdown(rows)
    md += "\n\nSkipped cells:\n" + "\n".join(
        f"- {s['arch']} x {s['shape']}: {s['skipped']}" for s in skips
    )
    md += "\n\nSuggested lever per bottleneck:\n" + "\n".join(
        f"- {k}: {v}" for k, v in _SUGGESTIONS.items()
    )
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
        print(f"wrote {args.out}")
    else:
        print(md)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ | {"useful_ratio": r.useful_ratio} for r in rows], f, indent=1)


if __name__ == "__main__":
    main()

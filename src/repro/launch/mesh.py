"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state; the dry-run sets
``xla_force_host_platform_device_count`` before calling it.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 ("data","model"). Multi-pod: 2x16x16 ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    # Auto axis semantics are the jax.make_mesh default; the pinned jax
    # (0.4.37) predates the explicit jax.sharding.AxisType API.
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_model: int = 1, n_data: int | None = None) -> Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if n_data is None:
        n_data = n // n_model
    return make_mesh((n_data, n_model), ("data", "model"))

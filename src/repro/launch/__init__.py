"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers,
roofline analysis. ``dryrun`` must be run as a fresh process (it forces 512
host devices before jax initializes)."""
import time


def instrumented(app, bus, label: str):
    """Wrap a MASA app's ``process`` so every train/serve step publishes
    its wall time and token throughput to the MetricsBus — the signals a
    demand estimator (or a human watching ``scheduler.*``) needs to size
    the pilot. Shared by the train and serve drivers."""

    def process(state, msgs):
        t0 = time.monotonic()
        items0 = app.stats.items
        state = app.process(state, msgs)
        dt = time.monotonic() - t0
        toks = app.stats.items - items0
        bus.publish(f"{label}.step_time", dt, stream=label)
        bus.publish(f"{label}.tokens_per_sec",
                    toks / dt if dt > 0 else 0.0, stream=label)
        return state

    return process

"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers,
roofline analysis. ``dryrun`` must be run as a fresh process (it forces 512
host devices before jax initializes)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (jit accepts in/out shardings),
  * the program compiles for 256 (single-pod) and 512 (multi-pod) devices,
  * it fits: ``compiled.memory_analysis()`` (per-device bytes),
  * the roofline terms: ``cost_analysis()`` + trip-count-corrected HLO
    analysis (flops / bytes / collective bytes) -> EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import all_cells, cell_supported, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.runtime.steps import build_step


def run_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool,
    verbose: bool = True,
    attn: str | None = None,
    overrides: dict | None = None,
) -> dict:
    """Lower+compile one cell; returns the dry-run record."""
    cfg = get_arch(arch_name)
    if attn:
        cfg = cfg.replace(attention_impl=attn)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    model = build_model(cfg)

    rec: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "kind": shape.kind,
    }
    t0 = time.time()
    bundle = build_step(model, mesh, shape)
    with mesh:
        lowered = bundle.lower()
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    rec["peak_bytes_per_device"] = (
        rec["memory"]["argument_bytes"]
        + rec["memory"]["output_bytes"]
        + rec["memory"]["temp_bytes"]
        - rec["memory"]["alias_bytes"]
    )
    ca = compiled.cost_analysis()
    rec["cost_analysis"] = {
        "flops": float(ca.get("flops", -1)),
        "bytes_accessed": float(ca.get("bytes accessed", -1)),
    }

    from repro.runtime.hlo_analysis import analyze_hlo

    # dynamic-trip loops (causal flash KV loop) run ~n_blocks/2 iterations on
    # the average shard; static loops are parsed exactly
    bkv = min(cfg.attention_block_kv, shape.seq_len)
    avg_trips = max(1, round(shape.seq_len / bkv / 2)) if shape.kind != "decode" else 1
    hlo = analyze_hlo(compiled.as_text(), dynamic_trip_default=avg_trips)
    rec["hlo"] = {
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes_moved,
        "bytes_fused_per_device": hlo.bytes_moved_fused,
        "collective_bytes_per_device": hlo.collective_bytes,
        "collectives": hlo.collective_counts,
        "cpu_upcast_artifact_bytes": hlo.cpu_upcast_artifact_bytes,
    }
    # TPU-corrected peak: XLA-CPU upcasts whole bf16 weight stacks to f32
    # (no native bf16 GEMM) and hoists them; the TPU MXU consumes bf16
    # directly, so those buffers don't exist there (DESIGN.md §6).
    rec["peak_bytes_per_device_tpu_est"] = int(
        rec["peak_bytes_per_device"] - hlo.cpu_upcast_artifact_bytes
    )
    if verbose:
        print(
            f"[dryrun] {arch_name} x {shape_name} ({rec['mesh']}): "
            f"compile {rec['compile_s']}s, "
            f"peak/device {rec['peak_bytes_per_device']/2**30:.2f} GiB "
            f"(tpu-est {rec['peak_bytes_per_device_tpu_est']/2**30:.2f}), "
            f"hlo flops/device {hlo.flops:.3e}, coll bytes/device {hlo.collective_bytes:.3e}"
        )
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--attn", default=None, help="override attention impl (blockwise|flash|ring)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records, failures = [], []
    for arch, shape in cells:
        ok, why = cell_supported(arch, shape)
        if not ok:
            records.append({"arch": arch, "shape": shape, "skipped": why})
            print(f"[dryrun] SKIP {arch} x {shape}: {why}")
            continue
        for mp in meshes:
            try:
                records.append(run_cell(arch, shape, multi_pod=mp, attn=args.attn))
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
                records.append(
                    {"arch": arch, "shape": shape, "mesh": "2x16x16" if mp else "16x16", "error": repr(e)}
                )

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        sys.exit(1)
    print(f"[dryrun] all {len(records)} cells OK")


if __name__ == "__main__":
    main()

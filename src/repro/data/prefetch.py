"""Double-buffered host->device prefetch.

Keeps ``depth`` batches in flight so host-side deserialization/assembly
overlaps device compute — the data-pipeline side of the paper's "balance
production and processing" requirement.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class DevicePrefetcher:
    def __init__(self, it: Iterator[Any], *, shardings: Any = None, depth: int = 2):
        self._it = it
        self._shardings = shardings
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._done = object()
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._it:
                if self._shardings is not None:
                    item = jax.device_put(item, self._shardings)
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._error = e
        finally:
            self._q.put(self._done)

    def __iter__(self) -> "DevicePrefetcher":
        return self

    def __next__(self) -> Any:
        item = self._q.get()
        if item is self._done:
            if self._error:
                raise self._error
            raise StopIteration
        return item

"""Assemble device batches from broker messages (shard-aware)."""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np


def batch_messages(
    msgs: Sequence, *, batch: int, seq_len: int | None = None, pad_value: int = 0
) -> np.ndarray:
    """Concatenate npy message payloads to exactly (batch, ...) rows.

    Short windows are padded by repeating the last row (streaming windows
    are size-variable; the step function is compiled for a fixed shape).
    """
    arrays = [np.asarray(m.value) for m in msgs]
    data = np.concatenate(arrays, axis=0)
    if seq_len is not None:
        data = data[:, :seq_len]
    if len(data) >= batch:
        return data[:batch]
    reps = np.repeat(data[-1:], batch - len(data), axis=0)
    return np.concatenate([data, reps], axis=0)


def shard_batch(batch: Any, shardings: Any):
    """Place a host batch tree onto its target shardings."""
    return jax.device_put(batch, shardings)

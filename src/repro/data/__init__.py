from repro.data.prefetch import DevicePrefetcher
from repro.data.batching import batch_messages

__all__ = ["DevicePrefetcher", "batch_messages"]

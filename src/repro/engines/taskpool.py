"""Task-parallel engine (Dask/RADICAL-Pilot analog) with straggler mitigation.

Executes Compute-Units on a worker pool sized by the lease. Speculative
execution: a task running longer than ``speculative_multiple`` x the median
completed runtime is re-launched on another worker; the first completion
wins (ComputeUnit.run is first-wins idempotent).
"""
from __future__ import annotations

import queue
import statistics
import threading
import time
from typing import Any

from repro.core.compute_unit import ComputeUnit, CUState
from repro.core.plugin import Lease, ManagerPlugin, register_plugin


@register_plugin("taskpool")
@register_plugin("dask")  # paper naming convenience
class TaskPoolPlugin(ManagerPlugin):
    USES_DEVICES = False

    def __init__(self, pcd):
        super().__init__(pcd)
        self._queue: "queue.Queue[ComputeUnit | None]" = queue.Queue()
        self._workers: dict[int, threading.Event] = {}
        self._inflight: dict[int, tuple[ComputeUnit, float]] = {}
        self._runtimes: list[float] = []
        self._lock = threading.Lock()
        self._ready = threading.Event()
        self._stop = threading.Event()
        self.speculative = bool(self.pcd.config.get("speculative", True))
        self.speculative_multiple = float(self.pcd.config.get("speculative_multiple", 3.0))
        self.speculated = 0
        self._spec_thread: threading.Thread | None = None

    # ---- SPI ----------------------------------------------------------------

    def submit_job(self, lease: Lease) -> None:
        workers = max(len(lease.nodes) * max(self.pcd.cores_per_node, 1), 1)
        for slot in range(workers):
            self._spawn_worker(slot)
        if self.speculative:
            self._spec_thread = threading.Thread(target=self._speculator, daemon=True)
            self._spec_thread.start()
        self._ready.set()

    def wait(self) -> None:
        self._ready.wait()

    def extend(self, lease: Lease) -> None:
        base = max(self._workers, default=-1) + 1
        for i in range(max(len(lease.nodes) * max(self.pcd.cores_per_node, 1), 1)):
            self._spawn_worker(base + i)

    def shrink(self, lease: Lease) -> None:
        n = max(len(lease.nodes) * max(self.pcd.cores_per_node, 1), 1)
        with self._lock:
            victims = sorted(self._workers)[-n:]
            for slot in victims:
                self._workers.pop(slot).set()

    def get_context(self, configuration: dict | None = None) -> "TaskPoolPlugin":
        return self

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        self._queue.put(cu)
        return cu

    def cancel(self) -> None:
        self._stop.set()
        with self._lock:
            for ev in self._workers.values():
                ev.set()
            self._workers.clear()
        self._queue.put(None)

    # ---- internals -------------------------------------------------------------

    @property
    def n_workers(self) -> int:
        with self._lock:
            return len(self._workers)

    def _spawn_worker(self, slot: int) -> None:
        stop = threading.Event()
        with self._lock:
            self._workers[slot] = stop

        def work():
            while not stop.is_set() and not self._stop.is_set():
                try:
                    cu = self._queue.get(timeout=0.1)
                except queue.Empty:
                    continue
                if cu is None:
                    self._queue.put(None)
                    return
                with self._lock:
                    self._inflight[cu.cu_id] = (cu, time.monotonic())
                cu.run()
                with self._lock:
                    self._inflight.pop(cu.cu_id, None)
                    if cu.runtime is not None and cu.state == CUState.DONE:
                        self._runtimes.append(cu.runtime)

        threading.Thread(target=work, daemon=True).start()

    def _speculator(self) -> None:
        while not self._stop.is_set():
            time.sleep(0.05)
            with self._lock:
                if len(self._runtimes) < 3:
                    continue
                median = statistics.median(self._runtimes[-100:])
                now = time.monotonic()
                slow = [
                    cu
                    for cu, started in self._inflight.values()
                    if not cu.done() and (now - started) > self.speculative_multiple * max(median, 1e-3)
                ]
            for cu in slow:
                self.speculated += 1
                self._queue.put(cu)  # duplicate attempt; first completion wins
                with self._lock:
                    self._inflight[cu.cu_id] = (cu, time.monotonic())

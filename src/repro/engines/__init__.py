"""Processing-engine plugins. Importing this package registers them all."""
from repro.engines.broker_plugin import BrokerPlugin
from repro.engines.continuous import ContinuousPlugin, ContinuousStream
from repro.engines.microbatch import MicroBatchPlugin, MicroBatchStream
from repro.engines.taskpool import TaskPoolPlugin

__all__ = [
    "BrokerPlugin",
    "ContinuousPlugin",
    "ContinuousStream",
    "MicroBatchPlugin",
    "MicroBatchStream",
    "TaskPoolPlugin",
]

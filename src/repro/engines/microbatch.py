"""Micro-batch streaming engine (Spark-Streaming analog) as a pilot plugin.

Discretized-stream semantics: the consumer drains a window of records from
the broker, assembles a batch, and applies a (usually jitted) processing
function carrying state (model params, centroids, ...). Provides:

* PID backpressure (streaming/rate_control.py) bounding per-batch ingestion;
* exactly-once: state checkpoint then offset commit, atomically ordered —
  recovery restores the checkpoint and rewinds to committed offsets;
* elastic rescale: extension pilots add devices; the processor's
  ``on_rescale`` hook re-shards live state (DESIGN.md §2 "resharding, not
  node hand-off").
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup, Message
from repro.core.compute_unit import ComputeUnit
from repro.core.plugin import Lease, ManagerPlugin, register_plugin
# stat records live on the shared elastic metrics bus now; re-exported here
# for backward compatibility
from repro.elastic.metrics import BatchMetrics, MetricsBus, StreamStats
from repro.streaming.dispatch import LatencyWindow
from repro.streaming.rate_control import PIDRateController


class MicroBatchStream:
    """One (topic -> processing fn) pipeline."""

    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        group: str,
        process_fn: Callable[[Any, list[Message]], Any],
        state: Any = None,
        batch_interval: float = 0.5,
        max_batch_records: int = 4096,
        backpressure: bool = True,
        checkpoint_fn: Callable[[Any, dict[int, int]], None] | None = None,
        checkpoint_every: int = 1,
        deserialize: bool = True,
        metrics: MetricsBus | None = None,
        sync_fn: Callable[[], None] | None = None,
        on_rescale: Callable[[Any], Any] | None = None,
        metrics_label: str | None = None,
        transport: str | None = None,
    ):
        self.cluster = cluster
        self.topic = topic
        #: "shm" opts the ingest loop into zero-copy frame views — sound
        #: for micro-batching because the batch is fully processed (and the
        #: state checkpointed) before commit advances the reclaim floor
        self.transport = transport
        self.group = ConsumerGroup(cluster, group, topic)
        self.consumer = Consumer(cluster, self.group, member_id=f"{group}-engine",
                                 deserialize=deserialize,
                                 zero_copy=(transport == "shm"))
        self.process_fn = process_fn
        self.state = state
        self.batch_interval = batch_interval
        self.max_batch_records = max_batch_records
        self.controller = PIDRateController(batch_interval) if backpressure else None
        self.checkpoint_fn = checkpoint_fn
        self.checkpoint_every = checkpoint_every
        # double-buffered processors dispatch work asynchronously; sync_fn is
        # the barrier that lands in-flight batches before state escapes the
        # loop (checkpoint, rescale, stop). Auto-wired from a bound
        # processor's ``sync`` method when not given explicitly.
        owner = getattr(process_fn, "__self__", None)
        if sync_fn is None and owner is not None:
            sync_fn = getattr(owner, "sync", None)
        self.sync_fn = sync_fn
        self.stats = StreamStats()
        self.latency = LatencyWindow()
        self._processor = owner
        self.metrics = metrics
        #: bus label for this stream's gauges. Defaults to the topic; two
        #: stages consuming one topic need distinct labels (the declarative
        #: runner passes topic/group) or they overwrite each other's gauges
        self.metrics_label = metrics_label or topic
        # the resharding hook may be given at construction or assigned to
        # the attribute afterwards (both supported)
        self.on_rescale: Callable[[Any], Any] | None = on_rescale
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._batch_id = 0
        self._error: BaseException | None = None
        self._batch_done = threading.Condition()
        self._last_publish = 0.0
        # serializes state swaps between the batch loop and rescale(): an
        # autoscaler-triggered reshard must not clobber an in-flight batch
        self._state_lock = threading.Lock()

    # ---- loop -------------------------------------------------------------

    def _run_one_batch(self) -> int:
        limit = self.max_batch_records
        if self.controller is not None and self.stats.batches > 0:
            limit = min(limit, self.controller.max_records_per_batch)
        # discretized-stream semantics: the window accumulates for the full
        # batch interval before processing fires (records wait ~window/2 on
        # average — the latency/throughput trade-off of paper Fig. 7)
        window_end = time.monotonic() + self.batch_interval
        msgs: list[Message] = []
        while len(msgs) < limit:
            remaining = window_end - time.monotonic()
            if remaining <= 0:
                break
            got = self.consumer.poll(max_records=limit - len(msgs), timeout=remaining)
            msgs.extend(got)
        if not msgs:
            return 0
        scheduling_delay = max(time.monotonic() - window_end, 0.0)
        t0 = time.monotonic()
        with self._state_lock:
            self.state = self.process_fn(self.state, msgs)
        dt = time.monotonic() - t0

        self._batch_id += 1
        if self.checkpoint_fn and self._batch_id % self.checkpoint_every == 0:
            if self.sync_fn is not None:  # land in-flight work before snapshotting
                self.sync_fn()
            self.checkpoint_fn(self.state, self.consumer.positions())
        self.consumer.commit()  # after checkpoint -> exactly-once on replay

        if self.controller is not None:
            self.controller.update(len(msgs), dt, scheduling_delay)
        now = time.time()
        self.stats.batches += 1
        self.stats.records += len(msgs)
        self.stats.processing_time += dt
        self.latency.record(dt)
        self.stats.history.append(
            BatchMetrics(
                self._batch_id, len(msgs), 0, dt, scheduling_delay,
                now - min(m.timestamp for m in msgs),
            )
        )
        if self.metrics is not None:
            self._publish_batch(len(msgs), dt, scheduling_delay)
        with self._batch_done:
            self._batch_done.notify_all()
        return len(msgs)

    def _compute_latency(self) -> LatencyWindow:
        """The latency window behind the bus gauges. An async (double-
        buffered) processor's process_fn returns before the device finishes,
        making the engine-side dt mere dispatch time — prefer the
        processor's own completion-latency window when it keeps one."""
        lat = getattr(getattr(self._processor, "stats", None), "latency", None)
        if isinstance(lat, LatencyWindow) and len(lat):
            return lat
        return self.latency

    def _publish_idle(self) -> None:
        """Zero out throughput gauges while starved — otherwise the last
        busy batch's records/sec stays latched on the bus and demand-driven
        policies never see the traffic stop."""
        now = time.monotonic()
        if now - self._last_publish < self.batch_interval:
            return
        self._last_publish = now
        labels = {"stream": self.metrics_label}
        self.metrics.publish("stream.records_per_sec", 0.0, **labels)
        self.metrics.publish("stream.busy_frac", 0.0, **labels)
        self.metrics.publish("stream.lag", sum(self.lag().values()), **labels)

    def _publish_batch(self, n: int, dt: float, scheduling_delay: float) -> None:
        bus, labels = self.metrics, {"stream": self.metrics_label}
        self._last_publish = time.monotonic()
        bus.publish("stream.records", self.stats.records, **labels)
        bus.publish("stream.records_per_sec", n / dt if dt > 0 else 0.0, **labels)
        bus.publish("stream.processing_delay", dt, **labels)
        bus.publish("stream.scheduling_delay", scheduling_delay, **labels)
        bus.publish("stream.busy_frac", dt / self.batch_interval, **labels)
        # rolling compute-latency quantiles: scaling policies can react to
        # batch latency creep before it shows up as lag
        lat = self._compute_latency()
        bus.publish("stream.latency_p50", lat.p50, **labels)
        bus.publish("stream.latency_p99", lat.p99, **labels)
        # committed offsets just advanced, so this is post-batch backlog
        bus.publish("stream.lag", sum(self.lag().values()), **labels)

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                n = self._run_one_batch()
            except BaseException as e:  # surfaced on await/stop
                self._error = e
                break
            if n == 0:
                if self.metrics is not None:
                    self._publish_idle()
                time.sleep(0.01)

    # ---- control ------------------------------------------------------------

    def start(self) -> "MicroBatchStream":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def await_batches(self, n: int, timeout: float = 60.0) -> None:
        deadline = time.monotonic() + timeout
        with self._batch_done:
            while self.stats.batches < n:
                if self._error:
                    raise self._error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"only {self.stats.batches}/{n} batches after {timeout}s")
                self._batch_done.wait(min(remaining, 0.25))

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        if self.sync_fn is not None:  # land in-flight batches: final state/stats
            self.sync_fn()
        self.consumer.release_frames()  # drop views pinning ring slots
        if self._error:
            raise self._error

    def lag(self) -> dict[int, int]:
        return self.cluster.lag(self.group.group, self.topic)

    def rescale(self, devices: list) -> None:
        """Re-shard live state onto a changed device set. Blocks until any
        in-flight batch commits its state, so the reshard never races it:
        the state lock serializes against the batch loop, and sync_fn drains
        the processor's async double-buffer before buffers move devices."""
        if self.on_rescale is None:
            return
        with self._state_lock:
            if self.sync_fn is not None:
                self.sync_fn()
            self.state = self.on_rescale(devices)

    # ---- failure recovery -----------------------------------------------------

    def recover(self, state: Any, offsets: dict[int, int] | None = None) -> None:
        """Restore from a checkpoint: state + rewind to committed offsets."""
        self.state = state
        if offsets:
            for p, off in offsets.items():
                self.consumer.seek(p, off)
        else:
            self.consumer.rewind_to_committed()


@register_plugin("microbatch")
@register_plugin("spark")  # paper naming convenience
class MicroBatchPlugin(ManagerPlugin):
    USES_DEVICES = True

    def __init__(self, pcd):
        super().__init__(pcd)
        self.devices: list = []
        self.streams: list[MicroBatchStream] = []
        self._ready = threading.Event()

    def submit_job(self, lease: Lease) -> None:
        self.devices = list(lease.devices)
        self._ready.set()

    def wait(self) -> None:
        self._ready.wait()

    def extend(self, lease: Lease) -> None:
        self.devices.extend(lease.devices)
        self._rescale()

    def shrink(self, lease: Lease) -> None:
        for d in lease.devices:
            if d in self.devices:
                self.devices.remove(d)
        self._rescale()

    def _rescale(self) -> None:
        for s in self.streams:
            s.rescale(self.devices)

    def get_context(self, configuration: dict | None = None) -> "MicroBatchPlugin":
        return self

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        threading.Thread(target=cu.run, daemon=True).start()
        return cu

    def cancel(self) -> None:
        for s in self.streams:
            try:
                s.stop()
            except Exception:
                pass

    # ---- user API (the StreamingContext analog) ------------------------------

    def stream(self, cluster: BrokerCluster, topic: str, **kw) -> MicroBatchStream:
        s = MicroBatchStream(cluster, topic, **kw)
        self.streams.append(s)
        return s

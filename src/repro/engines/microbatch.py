"""Micro-batch streaming engine (Spark-Streaming analog) as a pilot plugin.

Discretized-stream semantics: the consumer drains a window of records from
the broker, assembles a batch, and applies a (usually jitted) processing
function carrying state (model params, centroids, ...). Provides:

* PID backpressure (streaming/rate_control.py) bounding per-batch ingestion;
* exactly-once: state checkpoint then offset commit, atomically ordered —
  recovery restores the checkpoint and rewinds to committed offsets;
* elastic rescale: extension pilots add devices; the processor's
  ``on_rescale`` hook re-shards live state (DESIGN.md §2 "resharding, not
  node hand-off").
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup, Message
from repro.core.compute_unit import ComputeUnit
from repro.core.plugin import Lease, ManagerPlugin, register_plugin
from repro.streaming.rate_control import PIDRateController


@dataclass
class BatchMetrics:
    batch_id: int
    n_records: int
    bytes: int
    processing_delay: float
    scheduling_delay: float
    end_to_end_latency: float  # now - oldest record timestamp


@dataclass
class StreamStats:
    batches: int = 0
    records: int = 0
    bytes: int = 0
    processing_time: float = 0.0
    history: list = field(default_factory=list)

    @property
    def records_per_sec(self) -> float:
        return self.records / self.processing_time if self.processing_time else 0.0


class MicroBatchStream:
    """One (topic -> processing fn) pipeline."""

    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        group: str,
        process_fn: Callable[[Any, list[Message]], Any],
        state: Any = None,
        batch_interval: float = 0.5,
        max_batch_records: int = 4096,
        backpressure: bool = True,
        checkpoint_fn: Callable[[Any, dict[int, int]], None] | None = None,
        checkpoint_every: int = 1,
        deserialize: bool = True,
    ):
        self.cluster = cluster
        self.topic = topic
        self.group = ConsumerGroup(cluster, group, topic)
        self.consumer = Consumer(cluster, self.group, member_id=f"{group}-engine", deserialize=deserialize)
        self.process_fn = process_fn
        self.state = state
        self.batch_interval = batch_interval
        self.max_batch_records = max_batch_records
        self.controller = PIDRateController(batch_interval) if backpressure else None
        self.checkpoint_fn = checkpoint_fn
        self.checkpoint_every = checkpoint_every
        self.stats = StreamStats()
        self.on_rescale: Callable[[Any], Any] | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._batch_id = 0
        self._error: BaseException | None = None
        self._batch_done = threading.Condition()

    # ---- loop -------------------------------------------------------------

    def _run_one_batch(self) -> int:
        limit = self.max_batch_records
        if self.controller is not None and self.stats.batches > 0:
            limit = min(limit, self.controller.max_records_per_batch)
        # discretized-stream semantics: the window accumulates for the full
        # batch interval before processing fires (records wait ~window/2 on
        # average — the latency/throughput trade-off of paper Fig. 7)
        window_end = time.monotonic() + self.batch_interval
        msgs: list[Message] = []
        while len(msgs) < limit:
            remaining = window_end - time.monotonic()
            if remaining <= 0:
                break
            got = self.consumer.poll(max_records=limit - len(msgs), timeout=remaining)
            msgs.extend(got)
        if not msgs:
            return 0
        scheduling_delay = max(time.monotonic() - window_end, 0.0)
        t0 = time.monotonic()
        self.state = self.process_fn(self.state, msgs)
        dt = time.monotonic() - t0

        self._batch_id += 1
        if self.checkpoint_fn and self._batch_id % self.checkpoint_every == 0:
            self.checkpoint_fn(self.state, self.consumer.positions())
        self.consumer.commit()  # after checkpoint -> exactly-once on replay

        if self.controller is not None:
            self.controller.update(len(msgs), dt, scheduling_delay)
        now = time.time()
        self.stats.batches += 1
        self.stats.records += len(msgs)
        self.stats.processing_time += dt
        self.stats.history.append(
            BatchMetrics(
                self._batch_id, len(msgs), 0, dt, scheduling_delay,
                now - min(m.timestamp for m in msgs),
            )
        )
        with self._batch_done:
            self._batch_done.notify_all()
        return len(msgs)

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                n = self._run_one_batch()
            except BaseException as e:  # surfaced on await/stop
                self._error = e
                break
            if n == 0:
                time.sleep(0.01)

    # ---- control ------------------------------------------------------------

    def start(self) -> "MicroBatchStream":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def await_batches(self, n: int, timeout: float = 60.0) -> None:
        deadline = time.monotonic() + timeout
        with self._batch_done:
            while self.stats.batches < n:
                if self._error:
                    raise self._error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"only {self.stats.batches}/{n} batches after {timeout}s")
                self._batch_done.wait(min(remaining, 0.25))

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        if self._error:
            raise self._error

    def lag(self) -> dict[int, int]:
        return self.cluster.lag(self.group.group, self.topic)

    # ---- failure recovery -----------------------------------------------------

    def recover(self, state: Any, offsets: dict[int, int] | None = None) -> None:
        """Restore from a checkpoint: state + rewind to committed offsets."""
        self.state = state
        if offsets:
            for p, off in offsets.items():
                self.consumer.seek(p, off)
        else:
            self.consumer.rewind_to_committed()


@register_plugin("microbatch")
@register_plugin("spark")  # paper naming convenience
class MicroBatchPlugin(ManagerPlugin):
    USES_DEVICES = True

    def __init__(self, pcd):
        super().__init__(pcd)
        self.devices: list = []
        self.streams: list[MicroBatchStream] = []
        self._ready = threading.Event()

    def submit_job(self, lease: Lease) -> None:
        self.devices = list(lease.devices)
        self._ready.set()

    def wait(self) -> None:
        self._ready.wait()

    def extend(self, lease: Lease) -> None:
        self.devices.extend(lease.devices)
        self._rescale()

    def shrink(self, lease: Lease) -> None:
        for d in lease.devices:
            if d in self.devices:
                self.devices.remove(d)
        self._rescale()

    def _rescale(self) -> None:
        for s in self.streams:
            if s.on_rescale is not None:
                s.state = s.on_rescale(self.devices)

    def get_context(self, configuration: dict | None = None) -> "MicroBatchPlugin":
        return self

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        threading.Thread(target=cu.run, daemon=True).start()
        return cu

    def cancel(self) -> None:
        for s in self.streams:
            try:
                s.stop()
            except Exception:
                pass

    # ---- user API (the StreamingContext analog) ------------------------------

    def stream(self, cluster: BrokerCluster, topic: str, **kw) -> MicroBatchStream:
        s = MicroBatchStream(cluster, topic, **kw)
        self.streams.append(s)
        return s

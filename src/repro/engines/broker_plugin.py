"""Broker plugin: manage a Kafka-analog cluster through the Pilot API.

``pilot.get_context()`` returns the BrokerCluster (the paper's Listing 6
native-client escape hatch). ``extend``/``shrink`` add/remove broker nodes
with automatic partition rebalancing; ``on_failure`` is an involuntary
shrink.
"""
from __future__ import annotations

from repro.broker.cluster import BrokerCluster
from repro.core.plugin import Lease, ManagerPlugin, register_plugin


@register_plugin("broker")
@register_plugin("kafka")  # paper naming convenience
class BrokerPlugin(ManagerPlugin):
    USES_DEVICES = False

    def __init__(self, pcd):
        super().__init__(pcd)
        self.cluster: BrokerCluster | None = None
        self._lease_nodes: dict[int, list[int]] = {}

    def submit_job(self, lease: Lease) -> None:
        io_rate = self.pcd.config.get("io_rate_per_node")
        self.cluster = BrokerCluster(n_nodes=0, io_rate_per_node=io_rate)
        ids = [self.cluster.add_node() for _ in lease.nodes]
        self._lease_nodes[lease.lease_id] = ids

    def wait(self) -> None:
        assert self.cluster is not None

    def extend(self, lease: Lease) -> None:
        ids = [self.cluster.add_node() for _ in lease.nodes]
        self._lease_nodes[lease.lease_id] = ids

    def shrink(self, lease: Lease) -> None:
        for nid in self._lease_nodes.pop(lease.lease_id, []):
            self.cluster.remove_node(nid)

    def on_failure(self, lease: Lease) -> None:
        for nid in self._lease_nodes.pop(lease.lease_id, []):
            self.cluster.fail_node(nid)

    def cancel(self) -> None:
        """Close all logs and unlink any mounted shm transport segments —
        a cancelled (or crashed-and-cancelled) broker pilot must not leak
        /dev/shm entries."""
        if self.cluster is not None:
            self.cluster.close()

    def get_context(self, configuration: dict | None = None) -> BrokerCluster:
        return self.cluster

    def get_config_data(self) -> dict:
        return {"n_nodes": self.cluster.n_nodes if self.cluster else 0, **self.pcd.config}

"""Continuous (per-record) streaming engine — the Flink analog.

Processes records as they arrive with *event-time* windowing: records are
assigned to tumbling/sliding/session windows by their timestamps, buffered
per (key, window), and fired when the watermark (max event time − allowed
lateness) passes the window end. Late records are counted and dropped
(paper §2.1: "native stream engines ... more advanced windowing").
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup, Message
from repro.core.compute_unit import ComputeUnit
from repro.core.plugin import Lease, ManagerPlugin, register_plugin
# stat record lives on the shared elastic metrics bus now; re-exported here
# for backward compatibility
from repro.elastic.metrics import ContinuousStats, MetricsBus
from repro.streaming.windows import SessionWindow, WatermarkTracker


class ContinuousStream:
    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        group: str,
        assigner,
        window_fn: Callable[[Any, tuple, list], Any],
        key_fn: Callable[[Message], Any] = lambda m: None,
        allowed_lateness: float = 0.0,
        emit: Callable[[Any], None] | None = None,
        metrics: MetricsBus | None = None,
        on_rescale: Callable[[Any], Any] | None = None,
        metrics_label: str | None = None,
    ):
        self.cluster = cluster
        self.topic = topic
        self.group = ConsumerGroup(cluster, group, topic)
        self.consumer = Consumer(cluster, self.group, member_id=f"{group}-cont")
        self.assigner = assigner
        self.window_fn = window_fn
        self.key_fn = key_fn
        self.emit = emit or (lambda out: None)
        self.watermarks = WatermarkTracker(allowed_lateness)
        self.stats = ContinuousStats()
        self.metrics = metrics
        #: bus label (defaults to topic; see MicroBatchStream.metrics_label)
        self.metrics_label = metrics_label or topic
        # resharding hook, constructor kwarg or post-hoc attribute (both work)
        self.on_rescale: Callable[[Any], Any] | None = on_rescale
        self._buffers: dict[tuple, list] = defaultdict(list)  # (key, window) -> msgs
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._fired = threading.Condition()
        self._error: BaseException | None = None
        self._last_publish = 0.0

    def _ingest(self, msg: Message) -> None:
        ts = msg.timestamp
        if self.watermarks.is_late(ts):
            self.stats.late_records += 1
            return
        self.watermarks.observe(ts)
        key = self.key_fn(msg)
        if isinstance(self.assigner, SessionWindow):
            windows = self.assigner.assign(ts, key)
            # session merge: fold any overlapping buffered window into the merged one
            merged = windows[0]
            for (k, w) in list(self._buffers):
                if k == key and w != merged and not (w[1] <= merged[0] or w[0] >= merged[1]):
                    self._buffers[(key, merged)].extend(self._buffers.pop((k, w)))
        else:
            windows = self.assigner.assign(ts)
        for w in windows:
            self._buffers[(key, w)].append(msg)
        self.stats.records += 1
        self.stats.per_record_latency.append(time.time() - ts)

    def _fire_ready(self) -> None:
        wm = self.watermarks.watermark
        ready = [(k, w) for (k, w) in self._buffers if w[1] <= wm]
        for key, w in sorted(ready, key=lambda kw: kw[1][1]):
            msgs = self._buffers.pop((key, w))
            out = self.window_fn(key, w, msgs)
            self.emit(out)
            self.stats.fired_windows += 1
        if ready:
            with self._fired:
                self._fired.notify_all()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                msgs = self.consumer.poll(max_records=256, timeout=0.05)
                t0 = time.monotonic()
                for m in msgs:
                    self._ingest(m)
                self._fire_ready()
                if msgs:
                    self.consumer.commit()
                    if self.metrics is not None:
                        self._publish(len(msgs), time.monotonic() - t0)
                elif self.metrics is not None:
                    self._publish_idle()
            except BaseException as e:
                self._error = e
                break

    def _publish_idle(self) -> None:
        # zero the throughput gauge and refresh lag while starved so
        # burst-time values don't stay latched on the bus
        now = time.monotonic()
        if now - self._last_publish < 0.5:
            return
        self._last_publish = now
        self.metrics.publish("stream.records_per_sec", 0.0, stream=self.metrics_label)
        self.metrics.publish("stream.lag", sum(
            self.cluster.lag(self.group.group, self.topic).values()),
            stream=self.metrics_label)

    def _publish(self, n: int, dt: float) -> None:
        bus, labels = self.metrics, {"stream": self.metrics_label}
        self._last_publish = time.monotonic()
        bus.publish("stream.records", self.stats.records, **labels)
        bus.publish("stream.records_per_sec", n / dt if dt > 0 else 0.0, **labels)
        bus.publish("stream.fired_windows", self.stats.fired_windows, **labels)
        bus.publish("stream.late_records", self.stats.late_records, **labels)
        bus.publish("stream.lag", sum(
            self.cluster.lag(self.group.group, self.topic).values()), **labels)

    def start(self) -> "ContinuousStream":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def await_windows(self, n: int, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        with self._fired:
            while self.stats.fired_windows < n:
                if self._error:
                    raise self._error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"{self.stats.fired_windows}/{n} windows fired")
                self._fired.wait(min(remaining, 0.2))

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        if self._error:
            raise self._error

    def lag(self) -> dict[int, int]:
        """Records behind per partition (same shape as the micro-batch
        stream's) — what autoscaler lag probes consume."""
        return self.cluster.lag(self.group.group, self.topic)

    def rescale(self, devices: list) -> None:
        """Notify the processor of a changed device set (extension pilots
        added/removed). The continuous engine keeps window state host-side,
        so unlike the micro-batch engine there is no engine-held state to
        swap — the hook's return value is ignored."""
        if self.on_rescale is not None:
            self.on_rescale(devices)


@register_plugin("continuous")
@register_plugin("flink")  # paper naming convenience
class ContinuousPlugin(ManagerPlugin):
    USES_DEVICES = True

    def __init__(self, pcd):
        super().__init__(pcd)
        self.devices: list = []
        self.streams: list[ContinuousStream] = []
        self._ready = threading.Event()

    def submit_job(self, lease: Lease) -> None:
        self.devices = list(lease.devices)
        self._ready.set()

    def wait(self) -> None:
        self._ready.wait()

    def extend(self, lease: Lease) -> None:
        self.devices.extend(lease.devices)
        self._rescale()

    def shrink(self, lease: Lease) -> None:
        for d in lease.devices:
            if d in self.devices:
                self.devices.remove(d)
        self._rescale()

    def _rescale(self) -> None:
        for s in self.streams:
            s.rescale(self.devices)

    def get_context(self, configuration: dict | None = None) -> "ContinuousPlugin":
        return self

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        threading.Thread(target=cu.run, daemon=True).start()
        return cu

    def cancel(self) -> None:
        for s in self.streams:
            try:
                s.stop()
            except Exception:
                pass

    def stream(self, cluster: BrokerCluster, topic: str, **kw) -> ContinuousStream:
        s = ContinuousStream(cluster, topic, **kw)
        self.streams.append(s)
        return s

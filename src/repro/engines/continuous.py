"""Continuous (per-record) streaming engine — the Flink analog.

Processes records as they arrive with *event-time* windowing: records are
assigned to tumbling/sliding/session windows by their timestamps, buffered
per (key, window), and fired when the watermark (max event time − allowed
lateness) passes the window end. Late records are counted and dropped
(paper §2.1: "native stream engines ... more advanced windowing").

Keyed window state lives in a :class:`repro.state.PartitionedStateStore`
(fixed ring of state partitions, consistent key hashing), so a rescale —
extension pilots folding in or dropping out — migrates only the partitions
whose owner changed: ``rescale()`` quiesces the record loop (state lock +
``sync_fn`` barrier), runs the :class:`repro.state.StateMigrator`
(snapshot -> reassign -> restore, atomic spool on disk), then fires the
``on_rescale`` hook and resumes. See docs/state.md.
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Any, Callable

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup, Message
from repro.core.compute_unit import ComputeUnit
from repro.core.plugin import Lease, ManagerPlugin, register_plugin
# stat record lives on the shared elastic metrics bus now; re-exported here
# for backward compatibility
from repro.elastic.metrics import ContinuousStats, MetricsBus
from repro.state import DEFAULT_PARTITIONS, MigrationReport, PartitionedStateStore, StateMigrator
from repro.state.store import StatePartition, deserialize_partition, serialize_partition
from repro.streaming.dispatch import AsyncWindow
from repro.streaming.windows import SessionWindow, WatermarkTracker
from repro.workers.proto import OP_APPEND, OP_LATE, OP_MERGE, OP_OBSERVE, SNAPSHOT
from repro.workers.runtime import WorkerRuntime

EXECUTORS = ("inline", "mp")


class ContinuousStream:
    """``executor`` selects where partition state mutates and windows fire:

    * ``"inline"`` (default) — in this process, in the record-loop thread
      (the original engine; right for jax-backed processors, whose device
      runtimes are not fork-safe).
    * ``"mp"`` — each partition's ingest/firing runs in the worker process
      owning it (:class:`repro.workers.WorkerRuntime`): real parallelism
      across owners, failure isolation, and supervised restart with exact
      state recovery. Requires the fork start method (Linux); window
      outputs and message values must be picklable. Firing order and
      results are bit-identical to inline (tests/test_chaos_rescale.py).

    ``worker_options`` forwards kwargs to :class:`WorkerRuntime`
    (``snapshot_every``, ``batch_timeout``, ``heartbeat_timeout``,
    ``max_restarts``, ...).
    """

    def __init__(
        self,
        cluster: BrokerCluster,
        topic: str,
        *,
        group: str,
        assigner,
        window_fn: Callable[[Any, tuple, list], Any],
        key_fn: Callable[[Message], Any] = lambda m: None,
        allowed_lateness: float = 0.0,
        emit: Callable[[Any], None] | None = None,
        metrics: MetricsBus | None = None,
        sync_fn: Callable[[], None] | None = None,
        on_rescale: Callable[[Any], Any] | None = None,
        metrics_label: str | None = None,
        n_partitions: int = DEFAULT_PARTITIONS,
        owners: list | None = None,
        state_dir: str | None = None,
        executor: str = "inline",
        worker_options: dict | None = None,
        checkpoint_every: int = 0,
        transport: str | None = None,
        async_emit: int = 0,
    ):
        if executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {executor!r} (expected one of {EXECUTORS})")
        self.cluster = cluster
        self.topic = topic
        #: accepted for spec symmetry with MicroBatchStream; the continuous
        #: engine always copy-outs shm frames (it buffers records in window
        #: state far past the reclaim floor — views would be unsound), so
        #: "shm" changes the producer side only. See docs/transport.md.
        self.transport = transport
        self.group = ConsumerGroup(cluster, group, topic)
        self.consumer = Consumer(cluster, self.group, member_id=f"{group}-cont")
        self.assigner = assigner
        self.window_fn = window_fn
        self.key_fn = key_fn
        self.emit = emit or (lambda out: None)
        self.watermarks = WatermarkTracker(allowed_lateness)
        self.stats = ContinuousStats()
        self.metrics = metrics
        #: bus label (defaults to topic; see MicroBatchStream.metrics_label)
        self.metrics_label = metrics_label or topic
        # the barrier that lands a processor's in-flight device work before
        # state escapes the loop (rescale, stop) — auto-wired from a bound
        # window_fn's ``sync`` method, same contract as MicroBatchStream
        owner = getattr(window_fn, "__self__", None)
        if sync_fn is None and owner is not None:
            sync_fn = getattr(owner, "sync", None)
        self.sync_fn = sync_fn
        # resharding hook, constructor kwarg or post-hoc attribute (both work)
        self.on_rescale: Callable[[Any], Any] | None = on_rescale
        #: partitioned keyed window state: (key, window) buffers + counters
        self.store = PartitionedStateStore(n_partitions, owners=owners)
        self.migrator = StateMigrator(state_dir, bus=metrics, label=self.metrics_label)
        self.executor = executor
        #: the multiprocess partition runtime (mp executor only; spawned by
        #: ``start()`` so a never-started stream costs no processes)
        self.runtime: WorkerRuntime | None = None
        self._worker_options = dict(worker_options or {})
        #: report of the most recent rescale migration (None before any)
        self.last_migration: MigrationReport | None = None
        #: records between crash checkpoints (``sckpt_*`` spools holding all
        #: partitions + stream-global meta); 0 disables them. Required for
        #: :meth:`recover` to resume from mid-stream instead of offset 0.
        self.checkpoint_every = int(checkpoint_every)
        #: successful :meth:`recover` calls / latency of the last one
        self.recoveries = 0
        self.last_recovery_ms: float | None = None
        self._since_ckpt = 0
        self._ckpt_seq = 0
        # windows the pre-crash incarnation already emitted past the restored
        # checkpoint: the replay re-fires them, the emit is suppressed, and
        # fired_windows is not re-counted — zero lost, zero duplicated
        self._skip_emits = 0
        #: emit double-buffer depth: > 0 holds up to that many fired-window
        #: outputs in flight (jax dispatch pending) and delivers them once
        #: the device catches up, so downstream routing overlaps compute.
        #: ``fired_windows`` counts *deliveries*, which keeps the
        #: exactly-once replay arithmetic intact — a crash discards the
        #: buffer and the replay re-fires its windows. 0 = synchronous.
        self.async_emit = max(int(async_emit), 0)
        self._emit_window = AsyncWindow(self.async_emit) if self.async_emit else None
        # quiesce lock: the record loop holds it around ingest+fire, and
        # rescale() takes it to snapshot/migrate — an in-flight process()
        # call can never race a partition hand-off (regression-tested)
        self._state_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._fired = threading.Condition()
        self._error: BaseException | None = None
        self._last_publish = 0.0

    def _ingest(self, msg: Message) -> None:
        ts = msg.timestamp
        key = self.key_fn(msg)
        if self.watermarks.is_late(ts):
            self.stats.late_records += 1
            self.store.record_late(key)
            return
        self.watermarks.observe(ts)
        self.store.observe(key, ts)
        if isinstance(self.assigner, SessionWindow):
            windows = self.assigner.assign(ts, key)
            # session merge: fold any overlapping buffered window of this
            # key into the merged one (store-side, stays within the key's
            # partition)
            self.store.merge_session(key, windows[0])
        else:
            windows = self.assigner.assign(ts)
        for w in windows:
            self.store.append(key, w, msg)
        self.stats.records += 1
        self.stats.per_record_latency.append(time.time() - ts)

    def _deliver(self, out: Any) -> None:
        """Deliver one fired window's output — unless it is part of the
        replay prefix a recovery re-fires (already emitted pre-crash)."""
        if self._skip_emits > 0:
            self._skip_emits -= 1
            return
        self.emit(out)
        self.stats.fired_windows += 1

    def _emit_fired(self, out: Any) -> None:
        """Route one fired output: straight downstream (synchronous mode)
        or through the emit double-buffer, delivering whatever the buffer
        retires to stay within its depth."""
        if self._emit_window is None:
            self._deliver(out)
            return
        for done, _meta, _dt in self._emit_window.push(out):
            self._deliver(done)

    def _drain_emits(self) -> None:
        """Land and deliver every buffered emit (checkpoint/rescale/stop
        barrier — and the idle-poll flush, so latent outputs never sit in
        the buffer while the stream is starved). Caller holds the state
        lock or owns a quiesced stream."""
        if self._emit_window is None:
            return
        done = self._emit_window.sync()
        for out, _meta, _dt in done:
            self._deliver(out)
        if done:
            with self._fired:
                self._fired.notify_all()

    def _fire_ready(self) -> None:
        wm = self.watermarks.watermark
        fired = self.store.pop_ready(wm)
        for key, w, msgs in fired:
            out = self.window_fn(key, w, msgs)
            self._emit_fired(out)
        if fired:
            if isinstance(self.assigner, SessionWindow):
                # prune closed sessions from the assigner alongside their
                # buffers — per-key session lists would otherwise grow for
                # the lifetime of the stream
                self.assigner.close_before(wm)
            with self._fired:
                self._fired.notify_all()

    # -- mp executor: translate ingest into partition-tagged ops ---------------

    def _ingest_ops(self, msgs: list[Message]) -> list[tuple]:
        """The host half of mp ingest: watermark tracking, key routing,
        window assignment and session bookkeeping stay here (stream-global
        state); the per-partition mutations ship to the owner workers as
        ops. Mirrors :meth:`_ingest` exactly — late handling, observe-once
        semantics, merge-before-append ordering."""
        ops: list[tuple] = []
        for msg in msgs:
            ts = msg.timestamp
            key = self.key_fn(msg)
            pid = self.store.partition_of(key)
            if self.watermarks.is_late(ts):
                self.stats.late_records += 1
                ops.append((OP_LATE, pid))
                continue
            self.watermarks.observe(ts)
            ops.append((OP_OBSERVE, pid, ts))
            if isinstance(self.assigner, SessionWindow):
                windows = self.assigner.assign(ts, key)
                ops.append((OP_MERGE, pid, key, windows[0]))
            else:
                windows = self.assigner.assign(ts)
            for w in windows:
                ops.append((OP_APPEND, pid, key, w, msg))
            self.stats.records += 1
            self.stats.per_record_latency.append(time.time() - ts)
        return ops

    def _process_mp(self, msgs: list[Message]) -> None:
        ops = self._ingest_ops(msgs)
        wm = self.watermarks.watermark
        fired = self.runtime.submit(ops, wm)
        for key, w, out in fired:
            self._emit_fired(out)
        if fired:
            if isinstance(self.assigner, SessionWindow):
                self.assigner.close_before(wm)
            with self._fired:
                self._fired.notify_all()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                msgs = self.consumer.poll(max_records=256, timeout=0.05)
                t0 = time.monotonic()
                with self._state_lock:
                    if self.runtime is not None:
                        # empty poll: watermark can't have advanced, so
                        # there is nothing to fire — skip the round trip
                        if msgs:
                            self._process_mp(msgs)
                    else:
                        for m in msgs:
                            self._ingest(m)
                        self._fire_ready()
                    if not msgs:
                        # quiet round: no new firings are coming, so land
                        # anything the emit double-buffer still holds
                        self._drain_emits()
                    if msgs and self.checkpoint_every:
                        self._since_ckpt += len(msgs)
                        if self._since_ckpt >= self.checkpoint_every:
                            self._checkpoint_locked()
                if msgs:
                    self.consumer.commit()
                    if self.metrics is not None:
                        self._publish(len(msgs), time.monotonic() - t0)
                elif self.metrics is not None:
                    self._publish_idle()
            except BaseException as e:
                self._error = e
                break

    def _publish_idle(self) -> None:
        # zero the throughput gauge and refresh lag while starved so
        # burst-time values don't stay latched on the bus
        now = time.monotonic()
        if now - self._last_publish < 0.5:
            return
        self._last_publish = now
        self.metrics.publish("stream.records_per_sec", 0.0, stream=self.metrics_label)
        self.metrics.publish("stream.lag", sum(
            self.cluster.lag(self.group.group, self.topic).values()),
            stream=self.metrics_label)

    def _publish(self, n: int, dt: float) -> None:
        bus, labels = self.metrics, {"stream": self.metrics_label}
        self._last_publish = time.monotonic()
        bus.publish("stream.records", self.stats.records, **labels)
        bus.publish("stream.records_per_sec", n / dt if dt > 0 else 0.0, **labels)
        bus.publish("stream.fired_windows", self.stats.fired_windows, **labels)
        bus.publish("stream.late_records", self.stats.late_records, **labels)
        buffered = (self.runtime.buffered_windows if self.runtime is not None
                    else self.store.buffered_windows)
        bus.publish("stream.buffered_windows", buffered, **labels)
        if self._emit_window is not None:
            bus.publish("stream.emit_inflight", self._emit_window.in_flight,
                        **labels)
        bus.publish("stream.lag", sum(
            self.cluster.lag(self.group.group, self.topic).values()), **labels)
        if self.runtime is not None:
            # workers.alive / workers.restarts / per-worker latency_p50/p99
            self.runtime.publish()

    def start(self) -> "ContinuousStream":
        if self.executor == "mp" and self.runtime is None:
            self.runtime = WorkerRuntime(
                self.store, self.window_fn, migrator=self.migrator,
                bus=self.metrics, label=self.metrics_label,
                **self._worker_options).start()
        if self.checkpoint_every:
            # pin the shm reclaim floor to the replay horizon from the very
            # first record: commits advance past records a crash would
            # replay, and replaying into reclaimed ring slots is an error.
            # Prefer the consumer's live positions — after recover() they
            # hold the checkpoint cut, which sits *behind* committed — and
            # fall back to committed for a fresh start.
            n = self.cluster.topic(self.topic).n_partitions
            pos = self.consumer.positions()
            self._pin_replay_floor({
                p: pos.get(p, self.cluster.committed(
                    self.group.group, self.topic, p))
                for p in range(n)})
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _pin_replay_floor(self, positions: dict[int, int]) -> None:
        set_floor = getattr(self.cluster, "set_replay_floor", None)
        if set_floor is not None and positions:
            set_floor(self.group.group, self.topic, positions)

    def await_windows(self, n: int, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        with self._fired:
            while self.stats.fired_windows < n:
                if self._error:
                    raise self._error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"{self.stats.fired_windows}/{n} windows fired")
                self._fired.wait(min(remaining, 0.2))

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        if self.sync_fn is not None:  # land in-flight device work
            self.sync_fn()
        self.consumer.release_frames()  # drop views pinning ring slots
        # cleanup under the state lock so the spool is never yanked from
        # under an in-flight rescale — but timed, so a wedged window_fn
        # (loop thread outliving the join above) cannot hang teardown;
        # worst case the tempdir outlives us, which is the pre-cleanup
        # behavior, not a correctness loss
        if self._state_lock.acquire(timeout=5):
            try:
                self._drain_emits()  # deliver buffered outputs before teardown
                if self.runtime is not None:
                    self.runtime.shutdown()
                self.migrator.cleanup()
            finally:
                self._state_lock.release()
        elif self.runtime is not None:
            # wedged loop thread: still reap the worker processes (they are
            # daemons, but an explicit kill frees their queues now)
            self.runtime.shutdown()
        if self._error:
            raise self._error

    # -- crash / recovery (repro.faults; docs/faults.md) ------------------------

    def _checkpoint_locked(self) -> None:
        """Spool a consistent cut of the whole stream — every state
        partition plus the stream-global meta a restart cannot rederive
        (consumer positions, watermark, counters, session assigner state).
        Caller holds ``_state_lock``; positions reflect the just-processed
        batch, so restoring the spool and seeking to its positions replays
        nothing twice and skips nothing."""
        # fired-but-undelivered outputs must go downstream before the cut:
        # their windows were already popped from the store and their records
        # sit behind the checkpoint positions, so a crash after this spool
        # would otherwise lose them (they would never re-fire)
        self._drain_emits()
        if self.runtime is not None:
            payloads: dict[int, bytes] = {}
            for sup in self.runtime._sups:
                payloads.update(sup.request(
                    SNAPSHOT,
                    {"pids": self.runtime._pids_of(sup), "release": False}))
        else:
            payloads = {pid: serialize_partition(part)
                        for pid, part in self.store.partitions.items()}
        meta = pickle.dumps({
            "positions": self.consumer.positions(),
            "max_ts": self.watermarks._max_ts,
            "records": self.stats.records,
            "late": self.stats.late_records,
            "fired": self.stats.fired_windows,
            "sessions": (dict(self.assigner._sessions)
                         if isinstance(self.assigner, SessionWindow) else None),
            "assignment": dict(self.store.assignment),
        })
        self._ckpt_seq += 1
        self.migrator.write_spool(payloads, f"sckpt_{self._ckpt_seq:06d}",
                                  meta=meta)
        self.migrator._gc_spools("sckpt_")
        self._since_ckpt = 0
        # the checkpoint is the new replay horizon: ring slots below these
        # positions may now be reclaimed, slots above must survive a crash
        self._pin_replay_floor(self.consumer.positions())

    def checkpoint(self) -> bool:
        """Force an ``sckpt_*`` spool of the live stream right now — the
        checkpoint-then-kill preemption entry point (docs/scheduler.md).
        Grabs the state lock, so the cut is consistent with respect to the
        record loop exactly like a periodic checkpoint. Returns False when
        the stream doesn't checkpoint (``checkpoint_every == 0`` — the
        caller's kill will fall back to full replay from the earliest
        retained offsets) or is already stopped."""
        if not self.checkpoint_every:
            return False
        with self._state_lock:
            if self._stop.is_set():
                return False
            self._checkpoint_locked()
        return True

    def crash(self) -> None:
        """Abrupt pilot death (fault injection): the record loop stops
        wherever it is — no final commit, no checkpoint, and, unlike
        :meth:`stop`, no spool cleanup (``recover()`` needs it). An mp
        executor's worker processes die with their pilot (SIGKILL)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self._emit_window is not None:
            # buffered outputs die with the pilot; fired_windows never
            # counted them, so the replay re-fires and delivers them once
            self._emit_window.discard()
        if self.runtime is not None:
            for sup in list(self.runtime._sups):
                sup.kill()
            self.runtime.shutdown()
            self.runtime = None

    def recover(self) -> float:
        """Bring a crashed stream back: restore every partition and the
        stream-global meta from the latest ``sckpt_*`` spool, seek the
        consumer to the checkpoint's positions, and restart the loop (an mp
        executor respawns its workers, seeded from the restored store).
        Windows fired between the checkpoint and the crash re-fire during
        replay with their emit suppressed (``_skip_emits``), so downstream
        sees each firing exactly once. Without any checkpoint the stream
        restarts from the earliest retained offsets — same exactly-once
        argument, longer replay. Returns the recovery latency in ms."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("recover() on a live stream — crash() first")
        t0 = time.perf_counter()
        spool = self.migrator.latest_spool("sckpt_")
        if spool is not None:
            payloads = self.migrator.read_spool(spool)
            meta = pickle.loads(self.migrator.read_meta(spool))
            self.store.assignment = dict(meta["assignment"])
            for pid, data in payloads.items():
                part = deserialize_partition(data)
                self.store.partitions[pid] = part
            for p, off in meta["positions"].items():
                self.consumer.seek(p, off)
            self.watermarks._max_ts = meta["max_ts"]
            self._skip_emits = max(self.stats.fired_windows - meta["fired"], 0)
            self.stats.records = meta["records"]
            self.stats.late_records = meta["late"]
            if isinstance(self.assigner, SessionWindow):
                self.assigner._sessions = dict(meta["sessions"] or {})
        else:
            # nothing spooled yet: full replay from the log's earliest
            topic = self.cluster.topic(self.topic)
            for p in list(self.consumer.positions()):
                self.consumer.seek(p, topic.partitions[p].earliest)
            self.store.partitions = {
                p: StatePartition(p) for p in range(self.store.n_partitions)
            }
            self.watermarks._max_ts = float("-inf")
            self._skip_emits = self.stats.fired_windows
            self.stats.records = 0
            self.stats.late_records = 0
            if isinstance(self.assigner, SessionWindow):
                self.assigner._sessions = {}
        self._stop.clear()
        self._error = None
        self.start()  # re-creates the mp runtime (seeded from the store)
        self.recoveries += 1
        self.last_recovery_ms = (time.perf_counter() - t0) * 1e3
        if self.metrics is not None:
            self.metrics.publish("stream.recoveries", self.recoveries,
                                 stream=self.metrics_label)
            self.metrics.publish("stream.recovery_ms", self.last_recovery_ms,
                                 stream=self.metrics_label)
        return self.last_recovery_ms

    def lag(self) -> dict[int, int]:
        """Records behind per partition (same shape as the micro-batch
        stream's) — what autoscaler lag probes consume."""
        return self.cluster.lag(self.group.group, self.topic)

    def rescale(self, devices: list) -> MigrationReport | None:
        """Move keyed window state onto a changed owner set (extension
        pilots added/removed): quiesce -> snapshot -> reassign -> restore
        -> resume. No-op (returns None) once the stream is stopped.

        Blocks until any in-flight ``_ingest``/``window_fn`` call finishes
        (the state lock serializes against the record loop) and the
        processor's async double-buffer drains (``sync_fn``), so a
        partition is never serialized while a window is being appended to
        or fired from it. The ``on_rescale`` hook runs inside the quiesced
        section, after the migration, and its return value is ignored (the
        engine's state is the store; processor-held state is the hook's own
        business).
        """
        with self._state_lock:
            if self._stop.is_set():
                # dead stream (plugin.cancel + extension teardown still
                # calls in): nothing will fire again, so migrating would
                # only waste serde work and re-create the spool stop()
                # cleaned up — checked under the lock stop() cleans under
                return None
            if self.sync_fn is not None:
                self.sync_fn()
            self._drain_emits()  # no output may straddle the migration
            if self.runtime is not None:
                # mp: drain in-flight replies, quiesce workers, then move
                # partitions between processes through the migrator spool
                report = self.runtime.rescale(list(devices))
            else:
                report = self.migrator.migrate(self.store, list(devices))
            self.last_migration = report
            if self.on_rescale is not None:
                self.on_rescale(devices)
        return report


@register_plugin("continuous")
@register_plugin("flink")  # paper naming convenience
class ContinuousPlugin(ManagerPlugin):
    USES_DEVICES = True

    def __init__(self, pcd):
        super().__init__(pcd)
        self.devices: list = []
        self.streams: list[ContinuousStream] = []
        self._ready = threading.Event()

    def submit_job(self, lease: Lease) -> None:
        self.devices = list(lease.devices)
        self._ready.set()

    def wait(self) -> None:
        self._ready.wait()

    def extend(self, lease: Lease) -> None:
        self.devices.extend(lease.devices)
        self._rescale()

    def shrink(self, lease: Lease) -> None:
        for d in lease.devices:
            if d in self.devices:
                self.devices.remove(d)
        self._rescale()

    def _rescale(self) -> None:
        for s in self.streams:
            s.rescale(self.devices)

    def get_context(self, configuration: dict | None = None) -> "ContinuousPlugin":
        return self

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        threading.Thread(target=cu.run, daemon=True).start()
        return cu

    def cancel(self) -> None:
        for s in self.streams:
            try:
                s.stop()
            except Exception:
                pass

    def stream(self, cluster: BrokerCluster, topic: str, **kw) -> ContinuousStream:
        # seed the store's owner set with the pilot's current devices so the
        # first extension only moves the partitions that actually re-home
        kw.setdefault("owners", list(self.devices) or None)
        s = ContinuousStream(cluster, topic, **kw)
        self.streams.append(s)
        return s

"""Pilot-Compute-Description — the paper's Listing 2 key/value spec.

All SAGA-style attributes map 1:1 onto this dataclass; ``resource`` selects
the backend ("local://localhost" = in-process devices; a real deployment
would register slurm://... adaptors the same way).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass
class PilotComputeDescription:
    resource: str = "local://localhost"
    working_directory: str = "/tmp/pilot-streaming"
    number_of_nodes: int = 1
    cores_per_node: int = 1
    framework: str = "taskpool"  # registered plugin name (paper: "type")
    walltime: int = 3600
    queue: str = "normal"
    project: str = ""
    #: extension (paper Listing 4): lease is added to the parent's cluster
    parent: Optional[Any] = None
    #: framework-native configuration (paper §4.2 "custom configurations")
    config: dict = field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: dict) -> "PilotComputeDescription":
        """Accept the paper's key style (``pilot_compute_description`` dict)."""
        known = {f for f in cls.__dataclass_fields__}
        kw = {}
        extra = {}
        for k, v in d.items():
            k2 = k.lower()
            if k2 == "type":  # paper uses "type": "spark" | "kafka" | "dask"
                k2 = "framework"
            if k2 in known:
                kw[k2] = v
            else:
                extra[k] = v
        pcd = cls(**kw)
        pcd.config.update(extra)
        return pcd

"""Pilot abstraction (paper §4): service, pilots, compute units, plugin SPI."""
from repro.core.compute_unit import ComputeUnit, CUState
from repro.core.description import PilotComputeDescription
from repro.core.plugin import Lease, ManagerPlugin, plugin_class, register_plugin, registered_plugins
from repro.core.service import DevicePool, Pilot, PilotComputeService, PilotState

# importing engines registers the built-in plugins (kafka/spark/flink/dask analogs)
import repro.engines  # noqa: E402,F401

__all__ = [
    "CUState",
    "ComputeUnit",
    "DevicePool",
    "Lease",
    "ManagerPlugin",
    "Pilot",
    "PilotComputeDescription",
    "PilotComputeService",
    "PilotState",
    "plugin_class",
    "register_plugin",
    "registered_plugins",
]

"""Compute-Units: framework-agnostic tasks with future semantics (Listing 5)."""
from __future__ import annotations

import enum
import threading
import time
import traceback
from typing import Any, Callable


class CUState(str, enum.Enum):
    NEW = "New"
    RUNNING = "Running"
    DONE = "Done"
    FAILED = "Failed"
    CANCELED = "Canceled"


class ComputeUnit:
    """A unit of work submitted to a pilot; ``wait()`` blocks for the result."""

    _ids = iter(range(1, 1 << 62))
    _ids_lock = threading.Lock()

    def __init__(self, fn: Callable, args: tuple = (), kwargs: dict | None = None):
        with ComputeUnit._ids_lock:
            self.cu_id = next(ComputeUnit._ids)
        self.fn = fn
        self.args = args
        self.kwargs = kwargs or {}
        self.state = CUState.NEW
        self.submitted_at = time.monotonic()
        self.started_at: float | None = None
        self.finished_at: float | None = None
        self.attempts = 0
        self._result: Any = None
        self._error: BaseException | None = None
        self._done = threading.Event()

    # -- executor side -------------------------------------------------------

    def run(self) -> None:
        """Execute (idempotent completion: first finisher wins — speculative
        duplicates call this concurrently)."""
        self.attempts += 1
        if self._done.is_set():
            return
        self.state = CUState.RUNNING
        self.started_at = self.started_at or time.monotonic()
        try:
            result = self.fn(*self.args, **self.kwargs)
        except BaseException as e:  # noqa: BLE001 - reported via wait()
            if not self._done.is_set():
                self._error = e
                self.state = CUState.FAILED
                self.finished_at = time.monotonic()
                self._done.set()
            return
        if not self._done.is_set():
            self._result = result
            self.state = CUState.DONE
            self.finished_at = time.monotonic()
            self._done.set()

    def cancel(self) -> None:
        if not self._done.is_set():
            self.state = CUState.CANCELED
            self._done.set()

    # -- caller side ------------------------------------------------------------

    def wait(self, timeout: float | None = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(f"CU {self.cu_id} not done after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def done(self) -> bool:
        return self._done.is_set()

    @property
    def runtime(self) -> float | None:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

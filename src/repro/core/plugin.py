"""ManagerPlugin SPI — the paper's Listing 1, verbatim method set (+shrink).

A framework plugin encapsulates everything Pilot-Streaming needs to manage
one kind of cluster (Kafka-analog broker, micro-batch engine, continuous
engine, task pool): provisioning, readiness, elastic extension and the
native-context escape hatch (Listing 6).
"""
from __future__ import annotations

import abc
from typing import Any, Callable

from repro.core.compute_unit import ComputeUnit
from repro.core.description import PilotComputeDescription

_REGISTRY: dict[str, type["ManagerPlugin"]] = {}


def register_plugin(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls
        return cls

    return deco


def plugin_class(name: str) -> type["ManagerPlugin"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no plugin {name!r}; registered: {sorted(_REGISTRY)}") from None


def registered_plugins() -> list[str]:
    return sorted(_REGISTRY)


class ManagerPlugin(abc.ABC):
    """Paper Listing 1 interface."""

    def __init__(self, pilot_compute_description: PilotComputeDescription):
        self.pcd = pilot_compute_description

    @abc.abstractmethod
    def submit_job(self, lease: "Lease") -> None:
        """Provision the framework on the lease (bootstrap script analog)."""

    @abc.abstractmethod
    def wait(self) -> None:
        """Block until the framework is ready to accept work."""

    @abc.abstractmethod
    def extend(self, lease: "Lease") -> None:
        """Add resources to the running cluster (paper Listing 4)."""

    def shrink(self, lease: "Lease") -> None:
        """Remove previously-extended resources (voluntary or failure)."""
        raise NotImplementedError(f"{type(self).__name__} cannot shrink")

    @abc.abstractmethod
    def get_context(self, configuration: dict | None = None) -> Any:
        """Native framework handle (paper Listing 6)."""

    def get_config_data(self) -> dict:
        return dict(self.pcd.config)

    # -- compute units (Listing 5) -----------------------------------------

    def run_cu(self, cu: ComputeUnit) -> ComputeUnit:
        raise NotImplementedError(f"{type(self).__name__} does not execute CUs")

    # -- lifecycle ----------------------------------------------------------

    def cancel(self) -> None:
        pass

    def on_failure(self, lease: "Lease") -> None:
        """Resources died involuntarily; rebalance/recover."""
        self.shrink(lease)


class Lease:
    """A slice of the resource pool held by one pilot."""

    def __init__(self, lease_id: int, devices: list, nodes: list[int]):
        self.lease_id = lease_id
        self.devices = devices  # jax devices (compute plugins)
        self.nodes = nodes  # logical host slots (broker plugin)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Lease({self.lease_id}, devices={len(self.devices)}, nodes={self.nodes})"

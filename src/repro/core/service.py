"""PilotComputeService: device pool, leases, pilot lifecycle, failure injection.

The TPU-native rendering of the paper's Pilot-Job machinery (DESIGN.md §2):
a *pilot* is a lease over a slice of the device pool plus a framework plugin
provisioned on it. ``submit_pilot`` is the paper's Listing 2;
``parent=`` in the description is the extension mechanism of Listing 4.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
from typing import Any

import jax

from repro.core.compute_unit import ComputeUnit
from repro.core.description import PilotComputeDescription
from repro.core.failure import HeartbeatMonitor
from repro.core.plugin import Lease, ManagerPlugin, plugin_class


class PilotState(str, enum.Enum):
    NEW = "New"
    PROVISIONING = "Provisioning"
    RUNNING = "Running"
    EXTENDED = "Extended"
    STOPPED = "Stopped"
    FAILED = "Failed"


class DevicePool:
    """Tracks free/leased devices and logical host slots.

    Host slots (for the broker) are unbounded-logical; devices are the real
    ``jax.devices()`` (or an explicit list for dry-runs/tests).
    """

    def __init__(self, devices: list | None = None, n_host_slots: int = 1 << 16):
        self._devices = list(devices if devices is not None else jax.devices())
        self._free = list(self._devices)
        self._leased: set = set()  # membership in O(1); guards double-release
        self._host_slots = iter(itertools.count())
        self._lease_ids = iter(itertools.count(1))
        self._lock = threading.Lock()

    @property
    def total_devices(self) -> int:
        return len(self._devices)

    @property
    def free_devices(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def leased_devices(self) -> int:
        with self._lock:
            return len(self._leased)

    @property
    def utilization(self) -> float:
        """Fraction of the pool currently leased (the autoscaler's headroom
        signal)."""
        with self._lock:
            return len(self._leased) / len(self._devices) if self._devices else 0.0

    def acquire(self, n_devices: int, n_nodes: int) -> Lease:
        with self._lock:
            if n_devices > len(self._free):
                raise RuntimeError(
                    f"requested {n_devices} devices, only {len(self._free)} free"
                )
            devs = self._free[:n_devices]
            del self._free[:n_devices]
            self._leased.update(devs)
            nodes = [next(self._host_slots) for _ in range(n_nodes)]
            return Lease(next(self._lease_ids), devs, nodes)

    def release(self, lease: Lease) -> None:
        """Idempotent: devices not currently leased (double release) are
        ignored rather than duplicated into the free list."""
        with self._lock:
            for d in lease.devices:
                if d in self._leased:
                    self._leased.remove(d)
                    self._free.append(d)
            lease.devices = []
            lease.nodes = []


class Pilot:
    """A placeholder allocation running one framework (paper §4.1)."""

    def __init__(self, service: "PilotComputeService", pcd: PilotComputeDescription,
                 plugin: ManagerPlugin, lease: Lease, parent: "Pilot | None" = None):
        self.service = service
        self.pcd = pcd
        self.plugin = plugin
        self.lease = lease
        self.parent = parent
        self.state = PilotState.NEW
        self.submitted_at = time.monotonic()
        self.running_at: float | None = None
        self.children: list[Pilot] = []

    # -- lifecycle -----------------------------------------------------------

    def wait(self) -> "Pilot":
        self.plugin.wait()
        if self.state == PilotState.PROVISIONING:
            self.state = PilotState.RUNNING
            self.running_at = time.monotonic()
        return self

    def cancel(self) -> None:
        if self.parent is not None:
            # extension pilot: shrink the parent's cluster (paper §4.2)
            self.parent.plugin.shrink(self.lease)
            self.parent.children.remove(self)
        else:
            for child in list(self.children):
                child.cancel()
            self.plugin.cancel()
        self.service._release(self)
        self.state = PilotState.STOPPED

    @property
    def startup_time(self) -> float | None:
        if self.running_at is None:
            return None
        return self.running_at - self.submitted_at

    # -- work (Listings 5/6) ---------------------------------------------------

    def submit(self, fn, *args, **kwargs) -> ComputeUnit:
        root = self.parent if self.parent is not None else self
        return root.plugin.run_cu(ComputeUnit(fn, args, kwargs))

    def get_context(self, configuration: dict | None = None) -> Any:
        root = self.parent if self.parent is not None else self
        return root.plugin.get_context(configuration)

    def get_config_data(self) -> dict:
        return self.plugin.get_config_data()


class PilotComputeService:
    """Entry point (paper Listing 2): ``PilotComputeService().submit_pilot(pcd)``."""

    def __init__(self, devices: list | None = None, *, provision_delay_per_node: float = 0.0,
                 heartbeat_interval: float = 0.2, heartbeat_timeout: float = 2.0,
                 metrics: Any | None = None):
        self.pool = DevicePool(devices)
        self.pilots: list[Pilot] = []
        #: heartbeat kwargs are tunable so chaos tests / reconcilers can run
        #: with sub-second failure detection instead of the 2s default
        self.monitor = HeartbeatMonitor(heartbeat_interval, heartbeat_timeout)
        #: emulates the scheduler/bootstrap latency of real clusters (Fig. 6)
        self.provision_delay_per_node = provision_delay_per_node
        #: duck-typed MetricsBus (repro.elastic.metrics); pool gauges are
        #: published on every lease change when set
        self.metrics = metrics
        #: lazily-created ResourceArbiter (repro.scheduler) — one per
        #: service, shared by every pipeline/consumer on this pool
        self.arbiter = None
        self._lock = threading.Lock()

    def get_arbiter(self, bus: Any | None = None, **kw):
        """The service's single :class:`repro.scheduler.ResourceArbiter`,
        created on first use. All pipelines sharing this service (and thus
        its DevicePool) arbitrate through this one instance — that is what
        makes multi-tenant fairness possible at all.

        The first caller's ``bus`` wins: ``scheduler.*`` telemetry has one
        home (prefer one shared MetricsBus across runs on a shared
        service). Later callers passing a *different* bus get a warning so
        the absence of scheduler gauges on their bus is explicable.
        """
        with self._lock:
            if self.arbiter is None:
                from repro.scheduler import ResourceArbiter

                self.arbiter = ResourceArbiter(self, bus=bus or self.metrics, **kw)
            elif bus is not None and bus is not self.arbiter.bus:
                import warnings

                warnings.warn(
                    "service already has an arbiter bound to a different "
                    "MetricsBus; scheduler.* telemetry stays on the first "
                    "bus — share one bus across runs on a shared service",
                    stacklevel=2,
                )
            return self.arbiter

    def pool_stats(self) -> dict:
        return {
            "devices_total": self.pool.total_devices,
            "devices_leased": self.pool.leased_devices,
            "devices_free": self.pool.free_devices,
            "utilization": self.pool.utilization,
        }

    def _publish_pool(self) -> None:
        if self.metrics is not None:
            for k, v in self.pool_stats().items():
                self.metrics.publish(f"pool.{k}", v)

    def submit_pilot(self, pcd: PilotComputeDescription | dict) -> Pilot:
        if isinstance(pcd, dict):
            pcd = PilotComputeDescription.from_dict(pcd)
        cls = plugin_class(pcd.framework)
        needs_devices = getattr(cls, "USES_DEVICES", False)
        n_devices = pcd.number_of_nodes * pcd.cores_per_node if needs_devices else 0
        n_devices = min(n_devices, self.pool.free_devices)
        lease = self.pool.acquire(n_devices, pcd.number_of_nodes)

        if pcd.parent is not None:
            parent: Pilot = pcd.parent
            pilot = Pilot(self, pcd, parent.plugin, lease, parent=parent)
            pilot.state = PilotState.PROVISIONING
            self._provision_delay(pcd)
            parent.plugin.extend(lease)
            parent.children.append(pilot)
            parent.state = PilotState.EXTENDED
        else:
            plugin = cls(pcd)
            pilot = Pilot(self, pcd, plugin, lease)
            pilot.state = PilotState.PROVISIONING
            self._provision_delay(pcd)
            plugin.submit_job(lease)
        with self._lock:
            self.pilots.append(pilot)
        self.monitor.watch(pilot)
        self._publish_pool()
        return pilot.wait()

    def _provision_delay(self, pcd: PilotComputeDescription) -> None:
        if self.provision_delay_per_node:
            time.sleep(self.provision_delay_per_node * pcd.number_of_nodes)

    def _release(self, pilot: Pilot, *, unwatch: bool = True) -> None:
        if unwatch:
            self.monitor.unwatch(pilot)
        self.pool.release(pilot.lease)
        with self._lock:
            if pilot in self.pilots:
                self.pilots.remove(pilot)
        self._publish_pool()

    # -- fault injection / recovery (tests + FT benchmarks) --------------------

    def inject_failure(self, pilot: Pilot) -> None:
        """Simulate an agent crash: heartbeats stop, plugin is notified.

        The lease is released, but the pilot stays *watched*: the monitor
        detects the stale heartbeat after ``heartbeat_timeout``, fires its
        ``on_failure`` callbacks (how a :class:`repro.pipeline.runner.
        StageReconciler` learns a stage pilot died), then unwatches it.
        Releasing used to unwatch immediately, which silently disabled
        every monitor callback for injected failures."""
        self.monitor.mark_dead(pilot)
        pilot.state = PilotState.FAILED
        root = pilot.parent if pilot.parent is not None else pilot
        try:
            root.plugin.on_failure(pilot.lease)
        finally:
            self._release(pilot, unwatch=False)

    def cancel(self) -> None:
        if self.arbiter is not None:
            self.arbiter.stop()
        for p in list(self.pilots):
            try:
                p.cancel()
            except Exception:
                pass
        self.monitor.stop()

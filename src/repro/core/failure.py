"""Heartbeats + failure detection for pilot agents (paper §4: "continuously
monitors the framework adding a level of fault tolerance")."""
from __future__ import annotations

import threading
import time
from typing import Any, Callable


class HeartbeatMonitor:
    """Each watched pilot gets an agent thread emitting heartbeats; a monitor
    thread flags pilots whose heartbeat is older than ``timeout``."""

    def __init__(self, interval: float = 0.2, timeout: float = 2.0):
        self.interval = interval
        self.timeout = timeout
        self._beats: dict[int, float] = {}
        self._dead: set[int] = set()
        self._agents: dict[int, threading.Event] = {}
        self._callbacks: list[Callable[[Any], None]] = []
        self._watched: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._monitor = threading.Thread(target=self._run, daemon=True)
        self._monitor.start()

    def on_failure(self, cb: Callable[[Any], None]) -> None:
        self._callbacks.append(cb)

    def watch(self, pilot: Any) -> None:
        stop = threading.Event()
        key = id(pilot)
        with self._lock:
            self._beats[key] = time.monotonic()
            self._agents[key] = stop
            self._watched[key] = pilot

        def agent():
            while not stop.is_set() and not self._stop.is_set():
                with self._lock:
                    if key not in self._dead:
                        self._beats[key] = time.monotonic()
                stop.wait(self.interval)

        threading.Thread(target=agent, daemon=True).start()

    def unwatch(self, pilot: Any) -> None:
        key = id(pilot)
        with self._lock:
            ev = self._agents.pop(key, None)
            self._beats.pop(key, None)
            self._watched.pop(key, None)
            self._dead.discard(key)
        if ev:
            ev.set()

    def mark_dead(self, pilot: Any) -> None:
        """Failure injection: the agent stops heartbeating."""
        with self._lock:
            self._dead.add(id(pilot))

    def is_alive(self, pilot: Any) -> bool:
        with self._lock:
            beat = self._beats.get(id(pilot))
        return beat is not None and (time.monotonic() - beat) < self.timeout

    def _run(self) -> None:
        while not self._stop.is_set():
            now = time.monotonic()
            stale = []
            with self._lock:
                for key, beat in list(self._beats.items()):
                    if key in self._dead and now - beat > self.timeout:
                        stale.append(self._watched.get(key))
            for pilot in stale:
                for cb in self._callbacks:
                    try:
                        cb(pilot)
                    except Exception:
                        pass
                if pilot is not None:
                    self.unwatch(pilot)
            self._stop.wait(self.interval)

    def stop(self) -> None:
        self._stop.set()
        for ev in list(self._agents.values()):
            ev.set()

"""Heartbeats + failure detection for pilot agents (paper §4: "continuously
monitors the framework adding a level of fault tolerance").

Two watch styles share one monitor:

* **self-beating** (``watch(pilot)``) — an agent thread stamps a fresh beat
  every ``interval`` on the watched object's behalf. Beats only go stale
  when :meth:`mark_dead` stops the agent (failure *injection*) — the mode
  the pilot service has always used.
* **pull-based** (``watch(obj, beat_fn=...)``) — the agent thread *samples*
  ``beat_fn()`` (a monotonic timestamp the watched thing maintains itself,
  e.g. a worker process stamping a shared ``mp.Value``). Beats go stale
  whenever the real heartbeat source stops advancing, so crashes and hangs
  of out-of-process workers are detected for real (repro.workers).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable


class HeartbeatMonitor:
    """Each watched pilot gets an agent thread emitting heartbeats; a monitor
    thread flags pilots whose heartbeat is older than ``timeout``."""

    def __init__(self, interval: float = 0.2, timeout: float = 2.0):
        self.interval = interval
        self.timeout = timeout
        self._beats: dict[int, float] = {}
        self._dead: set[int] = set()
        self._agents: dict[int, threading.Event] = {}
        self._agent_threads: dict[int, threading.Thread] = {}
        self._callbacks: list[Callable[[Any], None]] = []
        self._watched: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = False
        self._monitor = threading.Thread(target=self._run, daemon=True)
        self._monitor.start()

    def on_failure(self, cb: Callable[[Any], None]) -> None:
        self._callbacks.append(cb)

    def watch(self, pilot: Any, beat_fn: Callable[[], float] | None = None) -> None:
        """Start monitoring ``pilot``. Without ``beat_fn`` the agent thread
        self-beats (stale only via :meth:`mark_dead`); with it, the agent
        samples the external heartbeat source each interval and staleness
        means the source genuinely stopped."""
        stop = threading.Event()
        key = id(pilot)
        now = beat_fn() if beat_fn is not None else time.monotonic()
        with self._lock:
            self._beats[key] = now
            self._agents[key] = stop
            self._watched[key] = pilot

        def agent():
            while not stop.is_set() and not self._stop.is_set():
                with self._lock:
                    if key not in self._dead:
                        self._beats[key] = (
                            beat_fn() if beat_fn is not None else time.monotonic()
                        )
                stop.wait(self.interval)

        t = threading.Thread(target=agent, daemon=True)
        with self._lock:
            self._agent_threads[key] = t
        t.start()

    def unwatch(self, pilot: Any) -> None:
        key = id(pilot)
        with self._lock:
            ev = self._agents.pop(key, None)
            self._agent_threads.pop(key, None)
            self._beats.pop(key, None)
            self._watched.pop(key, None)
            self._dead.discard(key)
        if ev:
            ev.set()

    def mark_dead(self, pilot: Any) -> None:
        """Failure injection: the agent stops heartbeating."""
        with self._lock:
            self._dead.add(id(pilot))

    def is_alive(self, pilot: Any) -> bool:
        with self._lock:
            beat = self._beats.get(id(pilot))
        return beat is not None and (time.monotonic() - beat) < self.timeout

    def _run(self) -> None:
        while not self._stop.is_set():
            now = time.monotonic()
            stale = []
            with self._lock:
                for key, beat in list(self._beats.items()):
                    if now - beat > self.timeout:
                        stale.append(self._watched.get(key))
            for pilot in stale:
                for cb in self._callbacks:
                    try:
                        cb(pilot)
                    except Exception:
                        pass
                if pilot is not None:
                    self.unwatch(pilot)
            self._stop.wait(self.interval)

    def close(self) -> None:
        """Idempotently stop the monitor thread and every agent thread,
        joining them so nothing leaks past the owner's lifetime. Every
        constructor of a monitor must pair it with a ``close()`` (the pilot
        service does in ``cancel()``; the worker runtime in ``shutdown()``)
        — before this existed, each ``watch()`` leaked a daemon agent
        thread for the life of the process."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            agents = list(self._agents.values())
            threads = list(self._agent_threads.values())
            self._agents.clear()
            self._agent_threads.clear()
        self._stop.set()
        for ev in agents:
            ev.set()
        for t in threads:
            if t is not threading.current_thread():
                t.join(timeout=2)
        if self._monitor is not threading.current_thread():
            self._monitor.join(timeout=2)

    def stop(self) -> None:
        """Backwards-compatible alias for :meth:`close`."""
        self.close()

"""repro.faults — deterministic seeded fault injection (docs/faults.md).

The chaos harness for the robustness layer: declare *what* breaks and
*when* (in the stream's logical coordinates — record counts or
watermarks) in a :class:`FaultSchedule`, bind it to a live pipeline with a
:class:`FaultInjector`, and assert the run's outputs are bit-identical to
a fault-free baseline (tests/test_chaos_faults.py).
"""
from repro.faults.injector import FaultEvent, FaultInjector
from repro.faults.schedule import KINDS, FaultSchedule, FaultSpec

__all__ = [
    "KINDS",
    "FaultEvent",
    "FaultInjector",
    "FaultSchedule",
    "FaultSpec",
]

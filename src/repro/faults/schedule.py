"""Fault-schedule DSL — declarative, deterministic chaos plans.

A schedule is an ordered list of :class:`FaultSpec`s, each naming a fault
kind, a *trigger* (a record count or an event-time watermark the stream
must reach), and kind-specific params. Triggers are expressed in the
stream's own progress coordinates, not wall-clock time, which is what
makes a chaos run reproducible: the same schedule + seed injects the same
faults at the same logical points on every machine and every run.

Text form (one fault per ``;`` or newline)::

    kill_broker_node @records=500 node=leader blackout=0.2
    kill_pilot       @records=900
    slow_consumer    @watermark=1003.5 delay=0.01 until_records=1200

Grammar: ``<kind> @records=<int> | @watermark=<float> [key=value ...]``.
Values parse as int, then float, then bare string. The same schedules are
built programmatically via the fluent methods (``FaultSchedule().
kill_broker_node(at_records=500, node="leader")``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

#: the fault vocabulary — keys of FaultInjector._ACTIONS
KINDS = (
    "kill_broker_node",
    "kill_pilot",
    "slow_consumer",
    "drop_heartbeats",
    "delay_io",
)


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault: what, when (logical trigger), and how."""

    kind: str
    at_records: int | None = None
    at_watermark: float | None = None
    params: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if (self.at_records is None) == (self.at_watermark is None):
            raise ValueError(
                f"{self.kind}: exactly one of at_records/at_watermark "
                "must be set (the injection trigger)")

    def due(self, records: int, watermark: float) -> bool:
        if self.at_records is not None:
            return records >= self.at_records
        return watermark >= self.at_watermark

    @property
    def trigger(self) -> str:
        if self.at_records is not None:
            return f"records>={self.at_records}"
        return f"watermark>={self.at_watermark}"


def _parse_value(text: str) -> Any:
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


class FaultSchedule:
    """An ordered fault plan; iterable, parseable, composable."""

    def __init__(self, specs: list[FaultSpec] | None = None):
        self.specs: list[FaultSpec] = list(specs or [])

    # -- construction -----------------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "FaultSchedule":
        sched = cls()
        for line in text.replace(";", "\n").splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            tokens = line.split()
            kind, at_records, at_watermark, params = tokens[0], None, None, {}
            for tok in tokens[1:]:
                if tok.startswith("@records="):
                    at_records = int(tok.split("=", 1)[1])
                elif tok.startswith("@watermark="):
                    at_watermark = float(tok.split("=", 1)[1])
                elif "=" in tok:
                    k, v = tok.split("=", 1)
                    params[k] = _parse_value(v)
                else:
                    raise ValueError(f"cannot parse token {tok!r} in {line!r}")
            sched.add(FaultSpec(kind, at_records, at_watermark, params))
        return sched

    def add(self, spec: FaultSpec) -> "FaultSchedule":
        self.specs.append(spec)
        return self

    def _fluent(self, kind: str, at_records: int | None,
                at_watermark: float | None, params: dict) -> "FaultSchedule":
        clean = {k: v for k, v in params.items() if v is not None}
        return self.add(FaultSpec(kind, at_records, at_watermark, clean))

    def kill_broker_node(self, *, at_records: int | None = None,
                         at_watermark: float | None = None,
                         node: int | str | None = None,
                         blackout: float | None = None) -> "FaultSchedule":
        return self._fluent("kill_broker_node", at_records, at_watermark,
                            {"node": node, "blackout": blackout})

    def kill_pilot(self, *, at_records: int | None = None,
                   at_watermark: float | None = None) -> "FaultSchedule":
        return self._fluent("kill_pilot", at_records, at_watermark, {})

    def slow_consumer(self, *, at_records: int | None = None,
                      at_watermark: float | None = None,
                      delay: float | None = None,
                      until_records: int | None = None) -> "FaultSchedule":
        return self._fluent("slow_consumer", at_records, at_watermark,
                            {"delay": delay, "until_records": until_records})

    def drop_heartbeats(self, *, at_records: int | None = None,
                        at_watermark: float | None = None) -> "FaultSchedule":
        return self._fluent("drop_heartbeats", at_records, at_watermark, {})

    def delay_io(self, *, at_records: int | None = None,
                 at_watermark: float | None = None,
                 delay: float | None = None,
                 until_records: int | None = None) -> "FaultSchedule":
        return self._fluent("delay_io", at_records, at_watermark,
                            {"delay": delay, "until_records": until_records})

    # -- introspection ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __repr__(self) -> str:
        body = "; ".join(f"{s.kind} @{s.trigger}" for s in self.specs)
        return f"FaultSchedule({body})"

"""FaultInjector — executes a FaultSchedule against a live pipeline.

The injector polls the stream's *logical* progress (record count and
watermark) and fires each scheduled fault exactly once when its trigger is
reached. What each fault does:

``kill_broker_node``
    ``cluster.fail_node`` on the chosen node — ``node=<id>``, ``node=
    "leader"`` (the node leading broker partition 0 of the bound topic, so
    a failover is guaranteed), or seeded-random among alive nodes.
    ``blackout=<s>`` holds the affected partitions unavailable, exercising
    producer/consumer retries.
``kill_pilot``
    ``stream.crash()`` (the loop dies where it is, mp workers are
    SIGKILLed) and, when a service+pilot are bound,
    ``service.inject_failure(pilot)`` — the heartbeat monitor then notices
    and a :class:`repro.pipeline.runner.StageReconciler` reprovisions +
    ``recover()``s. The stream is crashed *before* the service call so the
    plugin's shrink-path ``rescale`` no-ops on the dead stream.
``slow_consumer``
    sets ``consumer.injected_poll_delay`` (reverted at ``until_records``)
    — processing slows, lag grows, outputs stay identical; pair with
    ``Consumer(max_lag=...)`` to exercise shedding instead.
``drop_heartbeats``
    ``service.monitor.mark_dead(pilot)`` — heartbeats stop while the pilot
    is actually healthy: the false-positive case. The reconciler's
    crash-before-recover fencing makes recovery correct anyway.
``delay_io``
    ``cluster.set_io_delay`` (reverted at ``until_records``) — a degraded
    interconnect.

Determinism: target choices come from ``random.Random(seed)``; triggers
are logical. ``events`` is the audit trail (fault kind, trigger, detail,
the record count at injection) a chaos test asserts against.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.faults.schedule import FaultSchedule, FaultSpec


@dataclass(frozen=True)
class FaultEvent:
    """One injected (or reverted) fault, for the audit log."""

    kind: str
    trigger: str
    records: int
    detail: str


class FaultInjector:
    """Binds a schedule to the moving parts it attacks.

    All bindings are optional — a schedule that only kills broker nodes
    needs only ``cluster``. ``records_fn``/``watermark_fn`` default to
    reading the bound stream's stats.
    """

    def __init__(
        self,
        schedule: FaultSchedule,
        *,
        seed: int = 0,
        cluster: Any = None,
        topic: str | None = None,
        stream: Any = None,
        consumer: Any = None,
        service: Any = None,
        pilot: Any = None,
        records_fn: Callable[[], int] | None = None,
        watermark_fn: Callable[[], float] | None = None,
        actions: dict[str, Callable[["FaultInjector", FaultSpec], str]] | None = None,
        poll_interval: float = 0.002,
    ):
        self.schedule = schedule
        self.rng = random.Random(seed)
        self.cluster = cluster
        self.topic = topic
        self.stream = stream
        self.consumer = consumer if consumer is not None else (
            getattr(stream, "consumer", None))
        self.service = service
        self.pilot = pilot
        self._records_fn = records_fn or (
            (lambda: stream.stats.records) if stream is not None else (lambda: 0))
        self._watermark_fn = watermark_fn or (
            (lambda: stream.watermarks.watermark)
            if stream is not None else (lambda: float("-inf")))
        #: per-kind action overrides (chaos tests hook recovery in here)
        self.actions = dict(actions or {})
        self.poll_interval = poll_interval
        self.events: list[FaultEvent] = []
        self._pending: list[FaultSpec] = list(schedule)
        #: (expiry_record_count, revert_fn, spec) for until_records faults
        self._expiries: list[tuple[int, Callable[[], None], FaultSpec]] = []
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._done = threading.Event()

    # -- lifecycle ---------------------------------------------------------------

    def start(self) -> "FaultInjector":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)

    def wait(self, timeout: float = 30.0) -> bool:
        """Block until every scheduled fault fired (and every timed fault
        reverted). False on timeout."""
        return self._done.wait(timeout)

    @property
    def fired(self) -> int:
        return sum(1 for e in self.events if not e.detail.startswith("revert"))

    def _run(self) -> None:
        while not self._stop.is_set():
            records = self._records_fn()
            watermark = self._watermark_fn()
            still = []
            for spec in self._pending:
                if spec.due(records, watermark):
                    self._fire(spec, records)
                else:
                    still.append(spec)
            self._pending = still
            live = []
            for expiry, revert, spec in self._expiries:
                if records >= expiry:
                    revert()
                    self.events.append(FaultEvent(
                        spec.kind, f"records>={expiry}", records, "reverted"))
                else:
                    live.append((expiry, revert, spec))
            self._expiries = live
            if not self._pending and not self._expiries:
                self._done.set()
                return
            time.sleep(self.poll_interval)

    def _fire(self, spec: FaultSpec, records: int) -> None:
        action = self.actions.get(spec.kind) or getattr(self, f"_do_{spec.kind}")
        try:
            detail = action(self, spec) if spec.kind in self.actions \
                else action(spec)
        except Exception as e:  # a broken action must not kill the poller
            detail = f"action failed: {e!r}"
        self.events.append(FaultEvent(spec.kind, spec.trigger, records,
                                      detail or ""))

    # -- default actions ---------------------------------------------------------

    def _pick_node(self, spec: FaultSpec) -> int:
        node = spec.params.get("node")
        if node == "leader":
            topic = self.topic or next(iter(self.cluster._topics))
            return self.cluster.topic(topic).leaders[0]
        if node is not None:
            return int(node)
        return self.rng.choice(self.cluster._alive_nodes())

    def _do_kill_broker_node(self, spec: FaultSpec) -> str:
        node = self._pick_node(spec)
        blackout = float(spec.params.get("blackout", 0.0))
        self.cluster.fail_node(node, blackout=blackout)
        return f"failed node {node} (blackout={blackout})"

    def _do_kill_pilot(self, spec: FaultSpec) -> str:
        if self.stream is not None:
            self.stream.crash()
        if self.service is not None and self.pilot is not None:
            self.service.inject_failure(self.pilot)
            return "crashed stream + injected pilot failure"
        return "crashed stream"

    def _do_slow_consumer(self, spec: FaultSpec) -> str:
        delay = float(spec.params.get("delay", 0.01))
        consumer = self.consumer
        consumer.injected_poll_delay = delay
        until = spec.params.get("until_records")
        if until is not None:
            def revert():
                consumer.injected_poll_delay = 0.0
            self._expiries.append((int(until), revert, spec))
        return f"poll delay {delay}s" + (f" until records>={until}" if until else "")

    def _do_drop_heartbeats(self, spec: FaultSpec) -> str:
        self.service.monitor.mark_dead(self.pilot)
        return "heartbeats stopped (pilot still healthy)"

    def _do_delay_io(self, spec: FaultSpec) -> str:
        delay = float(spec.params.get("delay", 0.005))
        self.cluster.set_io_delay(delay)
        until = spec.params.get("until_records")
        if until is not None:
            cluster = self.cluster
            self._expiries.append(
                (int(until), lambda: cluster.set_io_delay(0.0), spec))
        return f"io delay {delay}s" + (f" until records>={until}" if until else "")

"""PartitionWorker — the child-process side of the runtime.

One worker owns a subset of the stream's state partitions and runs their
entire mutate-and-fire path: ingest ops are applied to real
:class:`~repro.state.store.StatePartition` objects living in *this*
process, and closed windows fire through the same module-level helpers
(:func:`ready_buffers`, :func:`merge_session_into`) the in-process store
uses — so a worker fires its partitions in exactly the order the inline
executor would, restricted to its own pids. The host merges workers'
outputs back into the global canonical order.

The worker stamps a shared heartbeat (``mp.Value('d')``) once per loop
iteration *and once per window_fn call*: a slow-but-alive worker keeps
beating mid-batch, while one genuinely wedged inside user code goes stale
and is flagged by the supervisor's HeartbeatMonitor.

Workers are forked, not spawned: window_fn/key_fn closures arrive by
inheritance (no pickling), which is why the engine documents that
``executor="mp"`` requires the fork start method (Linux). Queue *messages*
are still pickled — ops, serialized partitions, and window outputs must be
picklable.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.state.store import (
    StatePartition,
    deserialize_partition,
    merge_session_into,
    ready_buffers,
    serialize_partition,
)
from repro.workers.proto import (
    CONFIGURE,
    OP_APPEND,
    OP_LATE,
    OP_MERGE,
    OP_OBSERVE,
    PROCESS_BATCH,
    QUIESCE,
    RESTORE,
    SNAPSHOT,
    STATS,
    STOP,
    BatchResult,
    Reply,
    Request,
)


class PartitionWorker:
    """Run loop + command handlers; constructed in the parent, executed in
    the child (``run`` is the Process target)."""

    def __init__(self, worker_id: int, requests, replies, beat,
                 window_fn: Callable[[Any, tuple, list], Any],
                 poll_interval: float = 0.05):
        self.worker_id = worker_id
        self.requests = requests
        self.replies = replies
        self.beat = beat
        self.window_fn = window_fn
        self.poll_interval = poll_interval
        self.parts: dict[int, StatePartition] = {}
        # same auto-wiring as ContinuousStream: a bound window_fn's owner
        # may expose a sync() barrier for in-flight device work
        owner = getattr(window_fn, "__self__", None)
        self.sync_fn = getattr(owner, "sync", None) if owner is not None else None

    # -- child main loop ------------------------------------------------------

    def run(self) -> None:
        import queue as _queue
        while True:
            self.beat.value = time.monotonic()
            try:
                req: Request = self.requests.get(timeout=self.poll_interval)
            except _queue.Empty:
                continue
            except (EOFError, OSError):  # parent went away: nothing to serve
                return
            self.beat.value = time.monotonic()
            try:
                result = self._dispatch(req)
                self.replies.put(Reply(req.seq, True, result))
            except BaseException as e:  # user-code error -> host raises WorkerError
                self.replies.put(Reply(req.seq, False, None,
                                       f"{type(e).__name__}: {e}"))
            if req.cmd == STOP:
                return

    def _dispatch(self, req: Request):
        cmd, p = req.cmd, req.payload
        if cmd == PROCESS_BATCH:
            return self._process_batch(p["ops"], p["watermark"])
        if cmd == CONFIGURE:
            self.parts = {pid: StatePartition(pid) for pid in p["pids"]}
            return sorted(self.parts)
        if cmd == QUIESCE:
            if self.sync_fn is not None:
                self.sync_fn()
            return "idle"
        if cmd == SNAPSHOT:
            return self._snapshot(p.get("pids"), p.get("release", False))
        if cmd == RESTORE:
            return self._restore(p)
        if cmd == STATS:
            return self._stats()
        if cmd == STOP:
            return "bye"
        raise ValueError(f"unknown command {cmd!r}")

    # -- handlers -------------------------------------------------------------

    def _process_batch(self, ops: list, watermark: float) -> BatchResult:
        t0 = time.perf_counter()
        for op in ops:
            tag, pid = op[0], op[1]
            part = self.parts[pid]
            if tag == OP_APPEND:
                _, _, key, w, msg = op
                part.buffers.setdefault((key, w), []).append(msg)
            elif tag == OP_OBSERVE:
                part.records += 1
                if op[2] > part.max_event_time:
                    part.max_event_time = op[2]
            elif tag == OP_MERGE:
                merge_session_into(part, op[2], op[3])
            elif tag == OP_LATE:
                part.late_records += 1
            else:
                raise ValueError(f"unknown op tag {tag!r}")
        # fire in the canonical order, restricted to this worker's pids —
        # the host's global merge then reproduces the inline firing order
        fired = []
        for key, w, pid in ready_buffers(self.parts.values(), watermark):
            msgs = self.parts[pid].buffers.pop((key, w))
            self.beat.value = time.monotonic()  # beat per window: slow != wedged
            out = self.window_fn(key, w, msgs)
            fired.append((pid, key, w, out))
        buffered = sum(len(part.buffers) for part in self.parts.values())
        return BatchResult(fired, buffered, (time.perf_counter() - t0) * 1e3)

    def _snapshot(self, pids, release: bool) -> dict[int, bytes]:
        if pids is None:
            pids = sorted(self.parts)
        out = {pid: serialize_partition(self.parts[pid])
               for pid in pids if pid in self.parts}
        if release:  # migration-out: the partition now lives elsewhere
            for pid in out:
                del self.parts[pid]
        return out

    def _restore(self, payloads: dict[int, bytes]) -> dict[int, int]:
        counts = {}
        for pid, data in payloads.items():
            part = deserialize_partition(data)
            assert part.pid == pid
            self.parts[pid] = part
            counts[pid] = part.buffered_records
        return counts

    def _stats(self) -> dict:
        return {
            "worker_id": self.worker_id,
            "pids": sorted(self.parts),
            "records": sum(p.records for p in self.parts.values()),
            "late_records": sum(p.late_records for p in self.parts.values()),
            "buffered_windows": sum(len(p.buffers) for p in self.parts.values()),
            "buffered_records": sum(p.buffered_records for p in self.parts.values()),
        }

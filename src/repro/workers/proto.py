"""Control protocol of the multiprocess partition execution runtime.

One :class:`Request`/:class:`Reply` pair per command, correlated by a
monotonically increasing sequence number per channel (stale replies from a
pre-restart incarnation or an abandoned batch are dropped by sequence, not
by guesswork). Everything that crosses the process boundary is plain
picklable data; partition *state* crosses only as the columnar serde bytes
of ``repro.state.store.serialize_partition`` — the exact wire format a
cross-host hand-off would use.

Commands
--------
``CONFIGURE``      {"pids": [int]} — own these partitions (empty state
                   created for pids not later RESTOREd)
``PROCESS_BATCH``  {"ops": [op], "watermark": float} — apply ingest ops,
                   then fire every window closed at the watermark; replies
                   with a :class:`BatchResult`
``QUIESCE``        run the processor's sync barrier; ack when idle
``SNAPSHOT``       {"pids": [int], "release": bool} — serialize partitions
                   (dropping them when ``release``, the migration-out path)
``RESTORE``        {pid: bytes} — install deserialized partitions; replies
                   with per-pid buffered record counts
``STATS``          aggregate counters for gauges/debugging
``STOP``           ack, then exit the worker loop

Ingest ops (tuples, first element is the tag)
---------------------------------------------
``(OP_OBSERVE, pid, ts)``           per-record counters + max event time
``(OP_APPEND, pid, key, w, msg)``   buffer one message into one window
``(OP_LATE, pid)``                  count a late-dropped record
``(OP_MERGE, pid, key, w)``         session merge: fold overlapping buffers
                                    of ``key`` into the merged window ``w``
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

CONFIGURE = "CONFIGURE"
PROCESS_BATCH = "PROCESS_BATCH"
QUIESCE = "QUIESCE"
SNAPSHOT = "SNAPSHOT"
RESTORE = "RESTORE"
STATS = "STATS"
STOP = "STOP"

OP_OBSERVE = "o"
OP_APPEND = "a"
OP_LATE = "l"
OP_MERGE = "m"


@dataclass(frozen=True)
class Request:
    seq: int
    cmd: str
    payload: Any = None


@dataclass(frozen=True)
class Reply:
    seq: int
    ok: bool
    payload: Any = None
    error: str | None = None


@dataclass(frozen=True)
class BatchResult:
    """One PROCESS_BATCH's outcome: windows fired by this worker in its
    canonical order (the global order restricted to its partitions — what
    makes the host's merge, and crash-replay output counting, exact)."""

    fired: list  # [(pid, key, window, out), ...]
    buffered_windows: int
    elapsed_ms: float


class WorkerError(RuntimeError):
    """The worker executed the command and it raised (user-code error —
    deterministic, so restarts would not help; it propagates like an
    inline-executor exception would)."""


class WorkerCrash(RuntimeError):
    """The worker process died (or its channel was torn mid-message) — the
    supervisor's restart-with-recovery path, not the user's problem."""


class WorkerUnresponsive(WorkerCrash):
    """Heartbeats stale / batch deadline exceeded: the worker is wedged.
    Treated like a crash (kill + restart + replay)."""

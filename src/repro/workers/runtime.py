"""WorkerRuntime — host-side orchestration of the partition workers.

The continuous engine (``executor="mp"``) keeps all *stream-global*
bookkeeping — watermarks, window assignment, session tracking, consumer
offsets — and translates each poll into partition-tagged ingest ops. This
runtime routes those ops to the worker process owning each partition, runs
one PROCESS_BATCH round trip per worker per poll (pipelined: send to all,
then collect), and merges the workers' fired windows back into the global
canonical order ``(window_end, window_start, pid, key_bytes)`` — the same
total order the inline executor fires in, which is what makes the two
executors bit-identical.

Failure model (exact, not at-least-once):

* every batch is journaled (per-worker ops + the watermark) before it is
  sent;
* every ``snapshot_every`` batches, all partitions are snapshotted through
  the StateMigrator spool (``wckpt_*`` atomic dirs) and the journal resets;
* when a worker crashes (SIGKILL, OOM) or hangs (stale heartbeat, batch
  deadline), its supervisor respawns it and the runtime replays: RESTORE
  from the latest checkpoint, re-run every journaled batch, then drop the
  first ``emitted`` outputs — the prefix the host already delivered.
  Per-worker firing is deterministic, so the replayed tail is exactly the
  current batch's contribution: zero lost, zero duplicated firings.

Rescale reuses the same spool: drain reply queues (in-flight batch
leftovers), QUIESCE everyone, then ``StateMigrator.handoff`` with
fetch = SNAPSHOT(release=True) from old owners and install = RESTORE into
(possibly freshly spawned) new owners, followed by a fresh checkpoint —
ownership changed, so the previous checkpoint is no longer a valid
restore target.
"""
from __future__ import annotations

import multiprocessing as mp
from typing import Any, Callable, Mapping, Sequence

from repro.core.failure import HeartbeatMonitor
from repro.elastic.metrics import MetricsBus
from repro.state.migrator import MigrationReport, StateMigrator
from repro.state.partition import key_bytes
from repro.state.store import PartitionedStateStore, serialize_partition
from repro.streaming.dispatch import LatencyWindow
from repro.workers.proto import (
    CONFIGURE,
    PROCESS_BATCH,
    QUIESCE,
    RESTORE,
    SNAPSHOT,
    BatchResult,
    WorkerCrash,
)
from repro.workers.supervisor import WorkerSupervisor


class WorkerRuntime:
    def __init__(
        self,
        store: PartitionedStateStore,
        window_fn: Callable[[Any, tuple, list], Any],
        *,
        migrator: StateMigrator,
        bus: MetricsBus | None = None,
        label: str | None = None,
        snapshot_every: int = 32,
        batch_timeout: float = 30.0,
        heartbeat_interval: float = 0.1,
        heartbeat_timeout: float = 2.0,
        max_restarts: int = 3,
        restart_backoff: float = 0.05,
        restart_backoff_cap: float = 2.0,
    ):
        self.store = store
        self.window_fn = window_fn
        self.migrator = migrator
        self.bus = bus
        self.label = label
        self.snapshot_every = max(int(snapshot_every), 1)
        self.batch_timeout = batch_timeout
        self.heartbeat_interval = heartbeat_interval
        #: a single window_fn call longer than this reads as a hang — size
        #: it above the worst-case per-window compute time
        self.heartbeat_timeout = heartbeat_timeout
        self.max_restarts = max(int(max_restarts), 1)
        #: supervisor respawn backoff (see WorkerSupervisor.respawn)
        self.restart_backoff = restart_backoff
        self.restart_backoff_cap = restart_backoff_cap
        self.monitor: HeartbeatMonitor | None = None
        self.buffered_windows = 0
        self._ctx = None
        self._sups: list[WorkerSupervisor] = []
        self._next_wid = 0
        #: batches since the last checkpoint: [(watermark, {wid: [op]})]
        self._journal: list[tuple[float, dict[int, list]]] = []
        #: outputs already delivered to the host since the last checkpoint,
        #: per worker — the replay-skip prefix
        self._emitted: dict[int, int] = {}
        self._ckpt: str | None = None
        self._ckpt_seq = 0
        self._since_ckpt = 0
        self._lat: dict[int, LatencyWindow] = {}
        self._lat_all = LatencyWindow()
        self._retired_restarts = 0  # from workers stopped at rescale/shutdown
        self._started = False

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "WorkerRuntime":
        if "fork" not in mp.get_all_start_methods():
            raise RuntimeError(
                'executor="mp" requires the fork start method (Linux): '
                "window_fn/key_fn closures reach workers by inheritance")
        self._ctx = mp.get_context("fork")
        self.monitor = HeartbeatMonitor(self.heartbeat_interval,
                                        self.heartbeat_timeout)
        for owner in self.store.owners:
            self._spawn_for(owner)
        for sup in self._sups:
            sup.request(CONFIGURE, {"pids": self._pids_of(sup)})
            # seed: hand any pre-existing host-side state to its worker (a
            # fresh stream's store is empty, so this is usually a no-op)
            seed = {
                pid: serialize_partition(self.store.partitions[pid])
                for pid in self._pids_of(sup)
                if self.store.partitions[pid].buffers
                or self.store.partitions[pid].records
            }
            if seed:
                sup.request(RESTORE, seed)
        self.checkpoint()  # wckpt_000001: RESTORE always has a target
        self._started = True
        self._publish_health()
        return self

    def shutdown(self) -> None:
        """Stop every worker (graceful STOP, then kill) and release the
        monitor's threads. Idempotent."""
        for sup in self._sups:
            sup.stop()
            self._retired_restarts += sup.restarts
        self._sups = []
        if self.monitor is not None:
            self.monitor.close()
        self._started = False
        if self.bus is not None:
            self.bus.publish("workers.alive", 0, **self._labels())

    def _spawn_for(self, owner: Any) -> WorkerSupervisor:
        sup = WorkerSupervisor(self._next_wid, owner, self.window_fn,
                               monitor=self.monitor, ctx=self._ctx,
                               batch_timeout=self.batch_timeout,
                               restart_backoff=self.restart_backoff,
                               restart_backoff_cap=self.restart_backoff_cap)
        self._next_wid += 1
        sup.spawn()
        self._sups.append(sup)
        self._emitted[sup.worker_id] = 0
        self._lat[sup.worker_id] = LatencyWindow()
        return sup

    def _sup_for(self, owner: Any) -> WorkerSupervisor | None:
        for sup in self._sups:
            if sup.owner == owner:
                return sup
        return None

    def _pids_of(self, sup: WorkerSupervisor) -> list[int]:
        return [pid for pid, o in self.store.assignment.items()
                if o == sup.owner]

    @property
    def n_workers(self) -> int:
        return len(self._sups)

    @property
    def restarts(self) -> int:
        return self._retired_restarts + sum(sup.restarts for sup in self._sups)

    # -- the per-poll data path ------------------------------------------------

    def submit(self, ops: Sequence[tuple], watermark: float) -> list[tuple]:
        """Apply one poll's ingest ops and fire everything the watermark
        closed. Returns ``[(key, window, output), ...]`` in the global
        canonical order. Crashed/hung workers are recovered transparently;
        a deterministic user-code error (WorkerError) propagates like an
        inline window_fn raise would.
        """
        by_wid: dict[int, list] = {sup.worker_id: [] for sup in self._sups}
        sup_of_pid: dict[int, WorkerSupervisor] = {}
        for op in ops:
            pid = op[1]
            sup = sup_of_pid.get(pid)
            if sup is None:
                sup = sup_of_pid[pid] = self._sup_for(self.store.assignment[pid])
            by_wid[sup.worker_id].append(op)
        # journal BEFORE sending: a crash mid-batch replays this entry too
        self._journal.append((watermark, by_wid))

        # pipelined round: every worker gets every batch (a watermark-only
        # batch still fires its buffered windows), then collect in order
        seqs = [
            (sup, sup.send(PROCESS_BATCH,
                           {"ops": by_wid[sup.worker_id],
                            "watermark": watermark}))
            for sup in self._sups
        ]
        fired: list[tuple] = []  # (pid, key, w, out) across workers
        buffered = 0
        for sup, seq in seqs:
            try:
                result: BatchResult = sup.recv(seq)
                outs = result.fired
                buffered += result.buffered_windows
                self._record_latency(sup.worker_id, result.elapsed_ms)
                self._emitted[sup.worker_id] += len(outs)
            except WorkerCrash:
                outs, bw = self._recover(sup)
                buffered += bw
            fired.extend(outs)
        self.buffered_windows = buffered
        self._since_ckpt += 1
        if self._since_ckpt >= self.snapshot_every:
            self.checkpoint()
        # merge back into the inline executor's firing order: each worker
        # fired its pids in canonical order, the global sort unifies them
        fired.sort(key=lambda f: (f[2][1], f[2][0], f[0], key_bytes(f[1])))
        return [(key, w, out) for _pid, key, w, out in fired]

    def _record_latency(self, wid: int, elapsed_ms: float) -> None:
        dt = elapsed_ms / 1e3  # seconds, same unit as stream.latency_*
        self._lat[wid].record(dt)
        self._lat_all.record(dt)

    # -- crash / hang recovery -------------------------------------------------

    def _recover(self, sup: WorkerSupervisor) -> tuple[list, int]:
        """Respawn ``sup`` and rebuild its partitions exactly: checkpoint
        RESTORE + full journal replay, then skip the output prefix the host
        already delivered. Returns (undelivered tail, buffered windows) —
        the tail is precisely the in-flight batch's contribution, because
        every earlier journaled batch was fully delivered before the next
        was submitted. ``max_restarts`` bounds attempts *per recovery* (a
        worker that also dies during replay)."""
        last: WorkerCrash | None = None
        for _attempt in range(self.max_restarts):
            sup.respawn()
            self._publish_health()
            try:
                sup.request(CONFIGURE, {"pids": self._pids_of(sup)})
                payloads = self._checkpoint_for(sup)
                if payloads:
                    sup.request(RESTORE, payloads)
                replay: list = []
                buffered = 0
                for wm, by_wid in self._journal:
                    r: BatchResult = sup.request(
                        PROCESS_BATCH,
                        {"ops": by_wid.get(sup.worker_id, []),
                         "watermark": wm})
                    replay.extend(r.fired)
                    buffered = r.buffered_windows
                tail = replay[self._emitted[sup.worker_id]:]
                self._emitted[sup.worker_id] = len(replay)
                return tail, buffered
            except WorkerCrash as e:  # died again mid-recovery: retry
                last = e
        raise WorkerCrash(
            f"worker {sup.worker_id} failed to recover after "
            f"{self.max_restarts} restarts") from last

    def _checkpoint_for(self, sup: WorkerSupervisor) -> dict[int, bytes]:
        if self._ckpt is None:
            return {}
        return self.migrator.read_spool(self._ckpt, self._pids_of(sup))

    # -- checkpoints -----------------------------------------------------------

    def checkpoint(self) -> str:
        """Spool a consistent cut of *all* partitions (runs between
        batches, so per-worker snapshots compose into one global state),
        then reset the journal and the emitted counters."""
        payloads: dict[int, bytes] = {}
        for sup in self._sups:
            req = {"pids": self._pids_of(sup), "release": False}
            try:
                snap = sup.request(SNAPSHOT, req)
            except WorkerCrash:
                # rebuild from the previous checkpoint + journal, then the
                # snapshot reflects the same post-batch state
                self._recover(sup)
                snap = sup.request(SNAPSHOT, req)
            payloads.update(snap)
        self._ckpt_seq += 1
        self._ckpt = self.migrator.write_spool(
            payloads, f"wckpt_{self._ckpt_seq:06d}")
        self.migrator.gc_checkpoints()
        self._journal.clear()
        self._emitted = {sup.worker_id: 0 for sup in self._sups}
        self._since_ckpt = 0
        return self._ckpt

    # -- rescale ---------------------------------------------------------------

    def rescale(self, new_owners: Sequence[Any]) -> MigrationReport:
        """Re-home partitions onto a changed owner set, moving state
        *between worker processes* through the migrator's spool. The caller
        (ContinuousStream.rescale) holds the stream's state lock, so no
        batch is concurrently in flight — but reply queues may still hold
        leftovers of an abandoned batch, hence the drain before QUIESCE."""
        for sup in self._sups:
            sup.channel.drain()
        for sup in self._sups:
            try:
                sup.request(QUIESCE)
            except WorkerCrash:
                self._recover(sup)
                sup.request(QUIESCE)

        def fetch(pids: Sequence[int]) -> dict[int, bytes]:
            out: dict[int, bytes] = {}
            by_sup: dict[int, list[int]] = {}
            for pid in pids:  # group by *current* owner
                sup = self._sup_for(self.store.assignment[pid])
                by_sup.setdefault(sup.worker_id, []).append(pid)
            for sup in self._sups:
                pids_here = by_sup.get(sup.worker_id)
                if pids_here:
                    out.update(sup.request(
                        SNAPSHOT, {"pids": pids_here, "release": True}))
            return out

        def install(assignment: Mapping[int, Any],
                    payloads: Mapping[int, bytes]) -> int:
            self.store.assignment = dict(assignment)
            live_owners = self.store.owners
            keep: list[WorkerSupervisor] = []
            for sup in self._sups:  # owners that dropped out take nothing with them
                if any(o == sup.owner for o in live_owners):
                    keep.append(sup)
                else:
                    sup.stop()
                    self._retired_restarts += sup.restarts
                    self._emitted.pop(sup.worker_id, None)
            self._sups = keep
            for owner in live_owners:  # new owners get fresh processes
                if self._sup_for(owner) is None:
                    sup = self._spawn_for(owner)
                    sup.request(CONFIGURE, {"pids": []})
            moved_records = 0
            by_sup: dict[int, tuple[WorkerSupervisor, dict]] = {}
            for pid, data in payloads.items():
                sup = self._sup_for(self.store.assignment[pid])
                by_sup.setdefault(sup.worker_id, (sup, {}))[1][pid] = data
            for sup, chunk in by_sup.values():
                counts = sup.request(RESTORE, chunk)
                moved_records += sum(counts.values())
            return moved_records

        report = self.migrator.handoff(self.store, new_owners, fetch, install)
        # ownership changed: the previous checkpoint no longer matches the
        # assignment, so cut a fresh one before any batch runs
        self.checkpoint()
        self._publish_health()
        return report

    # -- gauges ----------------------------------------------------------------

    def _labels(self) -> dict:
        return {} if self.label is None else {"stream": self.label}

    def _publish_health(self) -> None:
        if self.bus is None:
            return
        labels = self._labels()
        self.bus.publish("workers.alive",
                         sum(1 for sup in self._sups if sup.alive()), **labels)
        self.bus.publish("workers.restarts", self.restarts, **labels)
        if self._sups:
            self.bus.publish(
                "workers.restart_backoff_ms",
                max(sup.last_backoff_s for sup in self._sups) * 1e3, **labels)

    def publish(self) -> None:
        """Per-worker + aggregate latency quantiles and worker health —
        called from the engine's publish path. Per-worker samples go first
        so ``latest_by_label(..., "stream")`` resolves to the aggregate."""
        if self.bus is None:
            return
        labels = self._labels()
        for sup in self._sups:
            lw = self._lat.get(sup.worker_id)
            if lw is None or len(lw) == 0:
                continue
            wl = {**labels, "worker": str(sup.worker_id)}
            self.bus.publish("stream.latency_p50", lw.p50, **wl)
            self.bus.publish("stream.latency_p99", lw.p99, **wl)
        if len(self._lat_all):
            self.bus.publish("stream.latency_p50", self._lat_all.p50, **labels)
            self.bus.publish("stream.latency_p99", self._lat_all.p99, **labels)
        self._publish_health()

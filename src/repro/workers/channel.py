"""WorkerChannel — the request/reply queue pair between the host runtime
and one worker process.

Correlation is by sequence number: the host allocates a fresh ``seq`` per
request, and :meth:`recv` silently drops any reply with an older ``seq`` —
replies abandoned by a batch timeout, or left over from before a restart,
can never be mistaken for the answer to the current command. That stale
drop (plus an explicit :meth:`drain` before quiesce) is what makes rescale
safe while worker batches are in flight.
"""
from __future__ import annotations

import queue
import time
from typing import Any, Callable

from repro.workers.proto import Reply, Request, WorkerCrash, WorkerUnresponsive

_POLL = 0.05  # reply poll granularity: bounds crash-detection latency


class WorkerChannel:
    """One requests + one replies :class:`multiprocessing.Queue`, created
    fresh per worker incarnation (a respawn abandons the old pair, so a
    late write from a dying process lands nowhere the host still reads)."""

    def __init__(self, ctx):
        self.requests = ctx.Queue()
        self.replies = ctx.Queue()
        self._seq = 0
        self._closed = False

    def send(self, cmd: str, payload: Any = None) -> int:
        self._seq += 1
        self.requests.put(Request(self._seq, cmd, payload))
        return self._seq

    def recv(self, seq: int, timeout: float,
             alive_fn: Callable[[], bool] | None = None,
             responsive_fn: Callable[[], bool] | None = None) -> Reply:
        """Wait for the reply to ``seq``.

        Raises :class:`WorkerCrash` when ``alive_fn`` reports the process
        dead (or the queue tears mid-unpickle), :class:`WorkerUnresponsive`
        when ``responsive_fn`` reports stale heartbeats or ``timeout``
        elapses. Replies with ``reply.seq < seq`` are stale and dropped;
        a *newer* seq is a protocol bug and raises.
        """
        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise WorkerUnresponsive(
                    f"no reply to seq={seq} within {timeout:.1f}s")
            try:
                reply = self.replies.get(timeout=min(remaining, _POLL))
            except queue.Empty:
                # no reply yet: distinguish dead / wedged / merely slow
                if alive_fn is not None and not alive_fn():
                    raise WorkerCrash(f"worker died awaiting seq={seq}")
                if responsive_fn is not None and not responsive_fn():
                    raise WorkerUnresponsive(
                        f"worker heartbeat went stale awaiting seq={seq}")
                continue
            except (EOFError, OSError) as e:  # torn queue (killed mid-write)
                raise WorkerCrash(f"reply channel torn awaiting seq={seq}: {e}")
            if reply.seq < seq:
                continue  # stale: abandoned batch or pre-drain leftover
            if reply.seq > seq:
                raise WorkerCrash(
                    f"protocol error: got seq={reply.seq}, expected {seq}")
            return reply

    def request(self, cmd: str, payload: Any = None, *, timeout: float = 30.0,
                alive_fn: Callable[[], bool] | None = None,
                responsive_fn: Callable[[], bool] | None = None) -> Reply:
        return self.recv(self.send(cmd, payload), timeout,
                         alive_fn=alive_fn, responsive_fn=responsive_fn)

    def drain(self) -> int:
        """Discard every reply currently buffered (returns how many). Run
        before QUIESCE/rescale so no in-flight batch result can alias a
        later command's reply."""
        n = 0
        while True:
            try:
                self.replies.get_nowait()
                n += 1
            except (queue.Empty, EOFError, OSError):
                return n

    def close(self) -> None:
        """Release both queues without joining their feeder threads (the
        worker side may already be dead; blocking here could hang
        teardown). Idempotent."""
        if self._closed:
            return
        self._closed = True
        for q in (self.requests, self.replies):
            try:
                q.cancel_join_thread()
                q.close()
            except Exception:
                pass

"""WorkerSupervisor — one per worker process: lifecycle + liveness.

The supervisor owns everything incarnation-scoped: the process handle, the
channel (queue pair), and the shared heartbeat cell. A respawn replaces
all three — late writes from a killed incarnation land in abandoned
queues, and the fresh heartbeat cell starts un-stale.

Liveness is two signals with different latencies:

* **crash** — ``Process.is_alive()`` goes false the moment the child dies
  (SIGKILL, OOM, unhandled exit); the channel's reply poll notices within
  ~50 ms.
* **hang** — the process is alive but stopped stamping its heartbeat (a
  wedged window_fn). The supervisor registers with the shared
  :class:`~repro.core.failure.HeartbeatMonitor` using a pull-based
  ``beat_fn`` that samples the worker's ``mp.Value``; once the sampled
  beat is older than the monitor's timeout, :meth:`responsive` flips and
  in-flight ``recv`` calls raise :class:`WorkerUnresponsive`.

Both surface as a :class:`WorkerCrash` subclass to the runtime, which
answers with kill + respawn + restore-from-checkpoint + journal replay.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.core.failure import HeartbeatMonitor
from repro.workers.channel import WorkerChannel
from repro.workers.proto import STOP, Reply, WorkerError
from repro.workers.worker import PartitionWorker


class WorkerSupervisor:
    def __init__(self, worker_id: int, owner: Any,
                 window_fn: Callable[[Any, tuple, list], Any], *,
                 monitor: HeartbeatMonitor, ctx,
                 batch_timeout: float = 30.0,
                 restart_backoff: float = 0.05,
                 restart_backoff_cap: float = 2.0):
        self.worker_id = worker_id
        self.owner = owner  # the pilot device whose partitions this worker runs
        self.window_fn = window_fn
        self.monitor = monitor
        self.ctx = ctx
        self.batch_timeout = batch_timeout
        #: base/cap of the exponential respawn backoff: a worker that keeps
        #: dying (a crash *storm* — e.g. OOM on the first batch every time)
        #: respawns at most every ``restart_backoff_cap`` seconds instead of
        #: in a tight fork loop; the first restart of a streak is immediate
        self.restart_backoff = restart_backoff
        self.restart_backoff_cap = restart_backoff_cap
        self.restarts = 0
        #: the delay the most recent respawn waited (the
        #: ``workers.restart_backoff_ms`` gauge source)
        self.last_backoff_s = 0.0
        self._streak = 0
        self._last_respawn = 0.0
        self.channel: WorkerChannel | None = None
        self.process = None
        self._beat = None

    # -- lifecycle ------------------------------------------------------------

    def spawn(self) -> "WorkerSupervisor":
        self.channel = WorkerChannel(self.ctx)
        self._beat = self.ctx.Value("d", time.monotonic())
        worker = PartitionWorker(self.worker_id, self.channel.requests,
                                 self.channel.replies, self._beat,
                                 self.window_fn)
        self.process = self.ctx.Process(
            target=worker.run, daemon=True,
            name=f"repro-worker-{self.worker_id}")
        self.process.start()
        beat = self._beat  # bind this incarnation's cell, not the attribute
        self.monitor.watch(self, beat_fn=lambda: beat.value)
        return self

    def kill(self) -> None:
        """Hard-stop this incarnation (no goodbye): unwatch, SIGKILL, reap,
        release the channel. Safe on an already-dead worker."""
        self.monitor.unwatch(self)
        if self.process is not None:
            try:
                self.process.kill()
            except Exception:
                pass
            self.process.join(timeout=5)
        if self.channel is not None:
            self.channel.close()

    def respawn(self) -> "WorkerSupervisor":
        """Replace the incarnation: fresh process, fresh queues, fresh
        heartbeat. The caller (runtime) re-CONFIGUREs, RESTOREs from the
        last checkpoint and replays the journal.

        Back-to-back respawns back off exponentially (capped): the streak
        resets once the previous incarnation survived a while, so an
        isolated crash still recovers immediately while a restart storm is
        throttled (regression-tested in tests/test_faults.py)."""
        now = time.monotonic()
        if now - self._last_respawn > self.restart_backoff_cap * 2:
            self._streak = 0
        delay = 0.0 if self._streak == 0 else min(
            self.restart_backoff_cap,
            self.restart_backoff * (2 ** (self._streak - 1)))
        self._streak += 1
        self._last_respawn = now
        self.last_backoff_s = delay
        self.restarts += 1
        self.kill()
        if delay > 0:
            time.sleep(delay)
        return self.spawn()

    def stop(self, timeout: float = 2.0) -> None:
        """Graceful STOP (lets the worker ack and exit its loop), falling
        back to :meth:`kill` — which also runs after a clean exit to reap
        the process and close the channel."""
        try:
            if self.alive():
                self.channel.request(STOP, timeout=timeout,
                                     alive_fn=self.alive)
        except Exception:
            pass
        self.kill()

    # -- liveness -------------------------------------------------------------

    def alive(self) -> bool:
        return self.process is not None and self.process.is_alive()

    def responsive(self) -> bool:
        """False once the sampled heartbeat goes stale (wedged worker)."""
        return self.monitor.is_alive(self)

    # -- protocol -------------------------------------------------------------

    def send(self, cmd: str, payload: Any = None) -> int:
        """Fire a command without waiting (the runtime pipelines
        PROCESS_BATCH across all workers, then collects)."""
        return self.channel.send(cmd, payload)

    def recv(self, seq: int, timeout: float | None = None):
        reply: Reply = self.channel.recv(
            seq, self.batch_timeout if timeout is None else timeout,
            alive_fn=self.alive, responsive_fn=self.responsive)
        if not reply.ok:
            raise WorkerError(f"worker {self.worker_id}: {reply.error}")
        return reply.payload

    def request(self, cmd: str, payload: Any = None,
                timeout: float | None = None):
        return self.recv(self.send(cmd, payload), timeout)

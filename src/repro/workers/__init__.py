"""repro.workers — multiprocess partition execution runtime.

Real process-level parallelism and failure isolation for the continuous
engine's keyed window state: each state partition's ingest/firing runs in
the worker process owning it (``ContinuousStream(executor="mp")``), with a
supervisor per worker detecting crash/hang and restarting with exact state
recovery from the StateMigrator spool. See docs/workers.md.
"""
from repro.workers.channel import WorkerChannel
from repro.workers.proto import (
    CONFIGURE,
    PROCESS_BATCH,
    QUIESCE,
    RESTORE,
    SNAPSHOT,
    STATS,
    STOP,
    BatchResult,
    Reply,
    Request,
    WorkerCrash,
    WorkerError,
    WorkerUnresponsive,
)
from repro.workers.runtime import WorkerRuntime
from repro.workers.supervisor import WorkerSupervisor
from repro.workers.worker import PartitionWorker

__all__ = [
    "BatchResult",
    "CONFIGURE",
    "PROCESS_BATCH",
    "PartitionWorker",
    "QUIESCE",
    "RESTORE",
    "Reply",
    "Request",
    "SNAPSHOT",
    "STATS",
    "STOP",
    "WorkerChannel",
    "WorkerCrash",
    "WorkerError",
    "WorkerRuntime",
    "WorkerSupervisor",
    "WorkerUnresponsive",
]

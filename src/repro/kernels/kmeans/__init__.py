from repro.kernels.kmeans.ops import assign, minibatch_update
from repro.kernels.kmeans.ref import assign_ref, update_ref

__all__ = ["assign", "assign_ref", "minibatch_update", "update_ref"]

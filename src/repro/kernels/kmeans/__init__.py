from repro.kernels.kmeans.ops import assign, minibatch_update, minibatch_update_masked
from repro.kernels.kmeans.ref import assign_ref, update_ref, update_scatter

__all__ = ["assign", "assign_ref", "minibatch_update", "minibatch_update_masked", "update_ref", "update_scatter"]

"""Jitted wrapper: padding + kernel/ref dispatch for K-Means assignment."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans.kernel import assign_pallas
from repro.kernels.kmeans.ref import assign_ref, update_ref, update_scatter


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def assign(points, centroids, *, use_kernel: bool = False, block_n: int = 1024, interpret: bool = True):
    """K-Means assignment. ``use_kernel`` selects the Pallas TPU kernel
    (``interpret=True`` executes it on CPU for validation); otherwise the
    jnp reference (which XLA also fuses well)."""
    n, d = points.shape
    k = centroids.shape[0]
    if not use_kernel:
        return assign_ref(points, centroids)
    # pad: lanes want multiples of 128 on D and K; block on N
    pp = _pad_to(_pad_to(points, 128, 1), min(block_n, 1024), 0)
    cp = _pad_to(_pad_to(centroids, 128, 1), 8, 0)
    kp = cp.shape[0]
    if kp > k:  # padded centroids must never win the argmin
        cp = cp.at[k:].set(1e30)
    labels, dist = assign_pallas(pp, cp, block_n=min(block_n, pp.shape[0]), interpret=interpret)
    return labels[:n], dist[:n]


def minibatch_update(points, centroids, *, decay: float = 0.9, use_kernel: bool = False, interpret: bool = True):
    """One streaming K-Means step: assign + decayed centroid update
    (paper §3.2.1 "averaging using a decay factor")."""
    k = centroids.shape[0]
    labels, dist = assign(points, centroids, use_kernel=use_kernel, interpret=interpret)
    sums, counts = update_scatter(points, labels, k)
    batch_means = sums / jnp.maximum(counts[:, None], 1.0)
    seen = (counts > 0)[:, None]
    new_centroids = jnp.where(
        seen, decay * centroids + (1.0 - decay) * batch_means, centroids
    )
    inertia = dist.sum()
    return new_centroids.astype(centroids.dtype), labels, inertia


def minibatch_update_masked(points, centroids, n_valid, *, decay: float = 0.9,
                            use_kernel: bool = False, interpret: bool = True):
    """Bucket-padded streaming step: rows ``>= n_valid`` are zero padding and
    contribute nothing to the update or the inertia.

    This is the shape-bucketed hot-path entry: a jitted wrapper compiles once
    per *bucket* shape while ``n_valid`` stays a dynamic scalar, so variable
    batch sizes reuse the same executable. Centroids are bit-identical to
    :func:`minibatch_update` on the unpadded batch (padding rows carry exact
    zero weight in every accumulation). Padding rows get label ``-1``.
    """
    k = centroids.shape[0]
    labels, dist = assign(points, centroids, use_kernel=use_kernel, interpret=interpret)
    mask = jnp.arange(points.shape[0]) < n_valid
    sums, counts = update_scatter(points, labels, k, mask=mask)
    batch_means = sums / jnp.maximum(counts[:, None], 1.0)
    seen = (counts > 0)[:, None]
    new_centroids = jnp.where(
        seen, decay * centroids + (1.0 - decay) * batch_means, centroids
    )
    inertia = jnp.where(mask, dist, 0.0).sum()
    labels = jnp.where(mask, labels, -1)
    return new_centroids.astype(centroids.dtype), labels, inertia

"""Pure-jnp oracle for the K-Means assignment step (MASA scoring hot loop).

Paper Table 1: "Model score: assign incoming data to centroids,
O(num_points * num_clusters)".
"""
from __future__ import annotations

import jax.numpy as jnp


def assign_ref(points: jnp.ndarray, centroids: jnp.ndarray):
    """points: (N, D); centroids: (K, D) -> (labels (N,) int32, dist2 (N,) f32)."""
    p2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (N,1)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)  # (K,)
    cross = points.astype(jnp.float32) @ centroids.astype(jnp.float32).T  # (N,K)
    d2 = p2 - 2.0 * cross + c2[None, :]
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return labels, jnp.min(d2, axis=1)


def update_ref(points: jnp.ndarray, labels: jnp.ndarray, k: int):
    """Mini-batch centroid sums + counts (the model-update step)."""
    onehot = jnp.zeros((points.shape[0], k), jnp.float32).at[jnp.arange(points.shape[0]), labels].set(1.0)
    sums = onehot.T @ points.astype(jnp.float32)  # (K, D)
    counts = onehot.sum(axis=0)  # (K,)
    return sums, counts

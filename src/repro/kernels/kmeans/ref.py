"""Pure-jnp oracle for the K-Means assignment step (MASA scoring hot loop).

Paper Table 1: "Model score: assign incoming data to centroids,
O(num_points * num_clusters)".
"""
from __future__ import annotations

import jax.numpy as jnp


def assign_ref(points: jnp.ndarray, centroids: jnp.ndarray):
    """points: (N, D); centroids: (K, D) -> (labels (N,) int32, dist2 (N,) f32)."""
    p2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (N,1)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)  # (K,)
    cross = points.astype(jnp.float32) @ centroids.astype(jnp.float32).T  # (N,K)
    d2 = p2 - 2.0 * cross + c2[None, :]
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return labels, jnp.min(d2, axis=1)


def update_ref(points: jnp.ndarray, labels: jnp.ndarray, k: int, mask: jnp.ndarray | None = None):
    """Mini-batch centroid sums + counts (the model-update step).

    ``mask`` (N,) bool zeroes out padding rows from a bucket-padded batch.
    One-hot matmul formulation — MXU-friendly, but its reduction tree over N
    depends on the padded length, so results are only *approximately* equal
    across bucket sizes (use :func:`update_scatter` when bucketed batches
    must be bit-identical to the unpadded computation).
    """
    onehot = jnp.zeros((points.shape[0], k), jnp.float32).at[jnp.arange(points.shape[0]), labels].set(1.0)
    if mask is not None:
        onehot = onehot * mask[:, None].astype(jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)  # (K, D)
    counts = onehot.sum(axis=0)  # (K,)
    return sums, counts


def update_scatter(points: jnp.ndarray, labels: jnp.ndarray, k: int,
                   mask: jnp.ndarray | None = None):
    """Centroid sums + counts via an order-preserving scatter-add.

    Scatter applies updates in row order, so appending zero-weight padding
    rows (the shape-bucketed hot path) leaves every accumulator bit-identical
    to the unpadded batch — adding IEEE +0.0 is exact and the live rows keep
    their accumulation order. This is the streaming update path; the one-hot
    matmul (:func:`update_ref`) stays as the MXU-friendly oracle.
    """
    pts = points.astype(jnp.float32)
    if mask is not None:
        w = mask.astype(jnp.float32)
        labels = jnp.where(mask, labels, 0)  # keep indices in range; weight 0
        pts = pts * w[:, None]
    else:
        w = jnp.ones((points.shape[0],), jnp.float32)
    sums = jnp.zeros((k, points.shape[1]), jnp.float32).at[labels].add(pts)
    counts = jnp.zeros((k,), jnp.float32).at[labels].add(w)
    return sums, counts

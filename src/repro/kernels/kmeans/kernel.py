"""Pallas TPU kernel: K-Means assignment (tiled distance matrix + argmin).

Tiling: grid over point blocks; each program loads a (BN, D) point tile and
the full (K, D) centroid set into VMEM, computes the distance tile with an
MXU matmul (-2 * P @ C^T) and reduces the argmin in-register (VPU). K and D
are padded to lane multiples by ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(p_ref, c_ref, c2_ref, labels_ref, dist_ref):
    p = p_ref[...].astype(jnp.float32)  # (BN, D)
    c = c_ref[...].astype(jnp.float32)  # (K, D)
    c2 = c2_ref[...]  # (1, K)
    cross = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BN, K) on the MXU
    p2 = jnp.sum(p * p, axis=1, keepdims=True)  # (BN, 1)
    d2 = p2 - 2.0 * cross + c2  # (BN, K)
    labels_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def assign_pallas(
    points: jax.Array,
    centroids: jax.Array,
    *,
    block_n: int = 1024,
    interpret: bool = False,
):
    """points: (N, D); centroids: (K, D). N % block_n == 0 (ops.py pads)."""
    n, d = points.shape
    k = centroids.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)[None, :]  # (1, K)

    return pl.pallas_call(
        _assign_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),  # point tile -> VMEM
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids -> VMEM (all tiles)
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(points, centroids, c2)

"""Pallas TPU kernels for the perf-critical compute hot-spots:

* ``kmeans``    — MASA streaming K-Means assignment (paper Table 1)
* ``tomo``      — forward/back projectors for GridRec & ML-EM (paper §3.2.2)
* ``attention`` — blocked flash attention for LM serving prefill

Each has ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py`` (jit'd
wrapper with ref/kernel dispatch) and ``ref.py`` (pure-jnp oracle). Kernels
are validated on CPU in ``interpret=True`` mode (tests/test_kernels.py).
"""

"""Pure-jnp oracle for the flash-attention kernel (GQA, causal optional)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd); H % KV == 0."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(v.dtype)

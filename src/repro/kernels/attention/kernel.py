"""Pallas TPU kernel: blocked flash attention (GQA, causal) — the serving
prefill hot loop.

Grid: (batch, q-head, q-block). Each program holds a (bq, hd) query tile and
its KV head's full (Skv, hd) K/V panels in VMEM (ops.py enforces the VMEM
budget), and runs the online-softmax recurrence over KV chunks on the MXU.
Causal programs early-exit KV chunks beyond their last query row — the same
schedule as runtime/sharded_attention.py, which is what runs per shard on
the production mesh.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bkv, skv, hd, causal, scale):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
    n_blocks = skv // bkv
    if causal:
        last_row = iq * bq + bq - 1
        n_needed = jnp.minimum(last_row // bkv + 1, n_blocks)
    else:
        n_needed = n_blocks

    def body(j, carry):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice(k_ref[0, 0], (j * bkv, 0), (bkv, hd)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(v_ref[0, 0], (j * bkv, 0), (bkv, hd)).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc = acc * alpha[:, None] + pv
        return acc, m_new, l

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_needed, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bkv=bkv, skv=Skv, hd=hd, causal=causal, scale=1.0 / math.sqrt(hd)
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, Skv, hd), lambda b, h, iq: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Skv, hd), lambda b, h, iq: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)

"""Jitted wrapper for the flash-attention kernel ((B,S,H,hd) layout in/out)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref

#: VMEM budget guard: K+V panels per program must fit comfortably
_VMEM_PANEL_LIMIT = 8 * 1024 * 1024


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_kv: int = 512,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    qt = q.swapaxes(1, 2)  # (B, H, Sq, hd)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    if use_kernel:
        panel = kt.shape[2] * kt.shape[3] * kt.dtype.itemsize * 2
        if panel > _VMEM_PANEL_LIMIT:
            raise ValueError(
                f"KV panel {panel}B exceeds VMEM budget; shard the sequence "
                "(runtime/sharded_attention.py) before calling the kernel"
            )
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, block_q=block_q, block_kv=block_kv, interpret=interpret
        )
    else:
        out = attention_ref(qt, kt, vt, causal=causal)
    return out.swapaxes(1, 2)

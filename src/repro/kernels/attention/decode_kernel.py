"""Pallas TPU kernel: single-token (decode) attention — the serving hot loop.

Grid: (batch, kv-head). Each program holds one sequence's (G, hd) grouped
query tile and its KV head's full (S, hd) cache panels in VMEM, and runs the
online-softmax recurrence over ``block_kv``-sized cache chunks, early-exiting
chunks past the sequence's live length (``positions``). With the paged KV
cache (repro.serving) the gathered context length is a small multiple of the
page size, so ``block_kv = page_size`` makes chunks line up with pages and
the early exit skips scratch/unwritten pages entirely.

Numerics mirror ``models.attention.decode_attention``: f32 accumulation and
NEG_INF masking of entries beyond ``positions`` (exact softmax zeros), so
greedy decode emits the same tokens as the jnp path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, bkv, skv, hd, g):
    pos = pos_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / math.sqrt(hd))  # (G, hd)
    kv = k_ref[0][:, 0]  # (S, hd)
    vv = v_ref[0][:, 0]
    n_blocks = skv // bkv
    n_needed = jnp.minimum(pos // bkv + 1, n_blocks)

    def body(j, carry):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice(kv, (j * bkv, 0), (bkv, hd)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(vv, (j * bkv, 0), (bkv, hd)).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bkv)
        k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (g, bkv), 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc = acc * alpha[:, None] + pv
        return acc, m_new, l

    acc0 = jnp.zeros((g, hd), jnp.float32)
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_needed, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,
    positions: jax.Array,  # (B,) int32: live length = write index of the new token
    *,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bkv = min(block_kv, S)
    assert S % bkv == 0, (S, bkv)
    qg = q.reshape(B, KV, G, hd)  # Sq=1 squeezed into the group axis
    pos2d = positions.astype(jnp.int32).reshape(B, 1)
    kernel = functools.partial(_decode_kernel, bkv=bkv, skv=S, hd=hd, g=G)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(pos2d, qg, k_cache, v_cache)
    return out.reshape(B, 1, H, hd).astype(v_cache.dtype)

"""Jitted reconstruction ops: GridRec + ML-EM over either backend.

``use_kernel=True`` runs the Pallas TPU projectors (``interpret=True`` on
CPU); otherwise the jnp reference. GridRec's ramp filter always runs in XLA
(FFT is already optimal there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tomo import ref as R
from repro.kernels.tomo.kernel import backproject_pallas, project_pallas


def _trig(angles):
    a = angles.astype(jnp.float32)
    return jnp.cos(a), jnp.sin(a)


def backproject(sino, angles, n, *, use_kernel=False, interpret=True):
    if not use_kernel:
        return R.backproject_ref(sino, angles, n)
    cos_t, sin_t = _trig(angles)
    return backproject_pallas(sino, cos_t, sin_t, n=n, interpret=interpret)


def project(img, angles, n_det, *, use_kernel=False, interpret=True):
    if not use_kernel:
        return R.project_ref(img, angles, n_det)
    cos_t, sin_t = _trig(angles)
    return project_pallas(img, cos_t, sin_t, n_det=n_det, interpret=interpret)


def gridrec(sino, angles, n, *, window="ramlak", use_kernel=False, interpret=True):
    """FFT filtered backprojection (paper's fast reconstruction)."""
    filtered = R.ramp_filter(sino, window=window)
    bp = backproject(filtered, angles, n, use_kernel=use_kernel, interpret=interpret)
    return bp * (jnp.pi / (2.0 * angles.shape[0]))


def mlem(sino, angles, n, *, iters=8, use_kernel=False, interpret=True):
    """Iterative ML-EM (paper's high-fidelity reconstruction)."""
    n_det = sino.shape[1]
    eps = 1e-6
    norm = backproject(jnp.ones_like(sino), angles, n, use_kernel=use_kernel, interpret=interpret) + eps

    def body(x, _):
        fp = project(x, angles, n_det, use_kernel=use_kernel, interpret=interpret)
        ratio = sino / jnp.maximum(fp, eps)
        bp = backproject(ratio, angles, n, use_kernel=use_kernel, interpret=interpret)
        return x * bp / norm, None

    x0 = jnp.ones((n, n), jnp.float32)
    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return x


def _backproject_batch(sinos, angles, n, *, use_kernel, interpret):
    if not use_kernel:
        # hand-batched ref (ref.py): vmapping the scalar path de-fuses the
        # per-angle weight construction and runs ~4x slower
        return R.backproject_ref_batch(sinos, angles, n)
    fn = functools.partial(backproject, n=n, use_kernel=True, interpret=interpret)
    return jax.vmap(fn, in_axes=(0, None))(sinos, angles)


def _project_batch(imgs, angles, n_det, *, use_kernel, interpret):
    if not use_kernel:
        return R.project_ref_batch(imgs, angles, n_det)
    fn = functools.partial(project, n_det=n_det, use_kernel=True, interpret=interpret)
    return jax.vmap(fn, in_axes=(0, None))(imgs, angles)


def gridrec_batch(sinos, angles, n, *, window="ramlak", use_kernel=False, interpret=True):
    """Stacked GridRec over a (B, A, n_det) sinogram micro-batch — one fused
    call instead of a per-message Python loop (the streaming hot path)."""
    filtered = R.ramp_filter(sinos, window=window)  # filters along axis -1
    bp = _backproject_batch(filtered, angles, n, use_kernel=use_kernel, interpret=interpret)
    return bp * (jnp.pi / (2.0 * angles.shape[0]))


def mlem_batch(sinos, angles, n, *, iters=8, use_kernel=False, interpret=True):
    """Stacked ML-EM over a (B, A, n_det) sinogram micro-batch."""
    b, _, n_det = sinos.shape
    eps = 1e-6
    norm = _backproject_batch(jnp.ones_like(sinos), angles, n,
                              use_kernel=use_kernel, interpret=interpret) + eps

    def body(x, _):
        fp = _project_batch(x, angles, n_det, use_kernel=use_kernel, interpret=interpret)
        ratio = sinos / jnp.maximum(fp, eps)
        bp = _backproject_batch(ratio, angles, n, use_kernel=use_kernel, interpret=interpret)
        return x * bp / norm, None

    x0 = jnp.ones((b, n, n), jnp.float32)
    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return x


def shepp_logan(n: int) -> jnp.ndarray:
    """Tiny synthetic phantom (sum of ellipses) for tests/benchmarks."""
    y, x = jnp.mgrid[0:n, 0:n]
    cx = cy = (n - 1) / 2.0
    xn, yn = (x - cx) / (n / 2), (y - cy) / (n / 2)
    img = jnp.zeros((n, n), jnp.float32)
    for (a, b, x0, y0, val) in [
        (0.69, 0.92, 0.0, 0.0, 1.0),
        (0.66, 0.87, 0.0, -0.02, -0.8),
        (0.11, 0.31, 0.22, 0.0, -0.2),
        (0.16, 0.41, -0.22, 0.0, -0.2),
        (0.21, 0.25, 0.0, 0.35, 0.1),
        (0.046, 0.046, 0.0, 0.1, 0.1),
    ]:
        mask = ((xn - x0) / a) ** 2 + ((yn - y0) / b) ** 2 <= 1.0
        img = img + val * mask
    return jnp.clip(img, 0.0, None)

"""Pure-jnp oracle: parallel-beam forward/back projection + GridRec + ML-EM.

Discretization: image (n, n), pixel centers at integer offsets from the
image center; detector with ``n_det`` bins, 1-pixel pitch, centered. For
angle theta, a pixel at (x, y) projects to detector coordinate

    s = (x - cx) * cos(theta) + (y - cy) * sin(theta) + (n_det - 1) / 2

with linear interpolation between the two neighbouring bins. Forward
projection uses the exact adjoint weights of backprojection, which is what
ML-EM convergence requires.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _weights(n: int, n_det: int, theta: jax.Array):
    """Interpolation weight matrix W (n*n, n_det) for one angle."""
    c = (n - 1) / 2.0
    y, x = jnp.mgrid[0:n, 0:n]
    s = (x - c) * jnp.cos(theta) + (y - c) * jnp.sin(theta) + (n_det - 1) / 2.0
    s = s.reshape(-1)  # (P,)
    s0 = jnp.floor(s)
    f = s - s0
    det = jnp.arange(n_det, dtype=jnp.float32)
    w = (
        jnp.where(det[None, :] == s0[:, None], (1.0 - f)[:, None], 0.0)
        + jnp.where(det[None, :] == (s0 + 1.0)[:, None], f[:, None], 0.0)
    )
    return w  # (P, n_det)


def project_ref(img: jax.Array, angles: jax.Array, n_det: int) -> jax.Array:
    """img (n, n) -> sinogram (A, n_det)."""
    n = img.shape[0]
    flat = img.reshape(-1).astype(jnp.float32)

    def one(theta):
        return _weights(n, n_det, theta).T @ flat  # (n_det,)

    return jax.lax.map(one, angles.astype(jnp.float32))


def backproject_ref(sino: jax.Array, angles: jax.Array, n: int) -> jax.Array:
    """sinogram (A, n_det) -> image (n, n) (unfiltered adjoint)."""
    n_det = sino.shape[1]

    def one(carry, inp):
        theta, row = inp
        return carry + _weights(n, n_det, theta) @ row.astype(jnp.float32), None

    acc0 = jnp.zeros((n * n,), jnp.float32)
    acc, _ = jax.lax.scan(one, acc0, (angles.astype(jnp.float32), sino))
    return acc.reshape(n, n)


def project_ref_batch(imgs: jax.Array, angles: jax.Array, n_det: int) -> jax.Array:
    """imgs (B, n, n) -> sinograms (B, A, n_det).

    Hand-batched rather than vmapped: the angle weight matrix W is built once
    per angle and contracted against the whole batch (matvec -> matmul), which
    keeps the W-construction fused — vmapping the scalar path instead makes
    XLA materialize W per batch element and runs ~4x slower.
    """
    n = imgs.shape[-1]
    flats = imgs.reshape(imgs.shape[0], -1).astype(jnp.float32)  # (B, P)

    def one(theta):
        return flats @ _weights(n, n_det, theta)  # (B, n_det)

    out = jax.lax.map(one, angles.astype(jnp.float32))  # (A, B, n_det)
    return jnp.swapaxes(out, 0, 1)


def backproject_ref_batch(sinos: jax.Array, angles: jax.Array, n: int) -> jax.Array:
    """sinograms (B, A, n_det) -> images (B, n, n); see project_ref_batch."""
    n_det = sinos.shape[-1]

    def one(carry, inp):
        theta, rows = inp  # rows (B, n_det)
        return carry + _weights(n, n_det, theta) @ rows.astype(jnp.float32).T, None

    acc0 = jnp.zeros((n * n, sinos.shape[0]), jnp.float32)
    acc, _ = jax.lax.scan(
        one, acc0, (angles.astype(jnp.float32), jnp.swapaxes(sinos, 0, 1)))
    return jnp.moveaxis(acc, -1, 0).reshape(sinos.shape[0], n, n)


# ---------------------------------------------------------------------------
# reconstruction algorithms (paper §3.2.2 / §5)
# ---------------------------------------------------------------------------


def ramp_filter(sino: jax.Array, *, window: str = "ramlak") -> jax.Array:
    """Frequency-domain ramp filter along the detector axis (GridRec's FFT
    step; XLA's FFT is already TPU-optimal so this stays jnp)."""
    n_det = sino.shape[-1]
    freqs = jnp.fft.fftfreq(n_det)
    filt = jnp.abs(freqs)
    if window == "shepp":
        filt = filt * jnp.sinc(freqs)
    spec = jnp.fft.fft(sino.astype(jnp.float32), axis=-1)
    return jnp.real(jnp.fft.ifft(spec * filt[None, :], axis=-1))


def gridrec_ref(sino: jax.Array, angles: jax.Array, n: int, *, window: str = "ramlak") -> jax.Array:
    """Filtered backprojection (the fast, FFT-based reconstruction)."""
    filtered = ramp_filter(sino, window=window)
    a = angles.shape[0]
    return backproject_ref(filtered, angles, n) * (jnp.pi / (2.0 * a))


def mlem_ref(sino: jax.Array, angles: jax.Array, n: int, *, iters: int = 8) -> jax.Array:
    """Maximum-likelihood EM (the slow, iterative reconstruction)."""
    n_det = sino.shape[1]
    eps = 1e-6
    norm = backproject_ref(jnp.ones_like(sino), angles, n) + eps  # A^T 1

    def body(x, _):
        fp = project_ref(x, angles, n_det)
        ratio = sino / jnp.maximum(fp, eps)
        x = x * backproject_ref(ratio, angles, n) / norm
        return x, None

    x0 = jnp.ones((n, n), jnp.float32)
    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return x

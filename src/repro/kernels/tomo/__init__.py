from repro.kernels.tomo.ops import backproject, gridrec, mlem, project, shepp_logan
from repro.kernels.tomo.ref import backproject_ref, gridrec_ref, mlem_ref, project_ref, ramp_filter

__all__ = [
    "backproject",
    "backproject_ref",
    "gridrec",
    "gridrec_ref",
    "mlem",
    "mlem_ref",
    "project",
    "project_ref",
    "ramp_filter",
    "shepp_logan",
]

from repro.kernels.tomo.ops import (
    backproject,
    gridrec,
    gridrec_batch,
    mlem,
    mlem_batch,
    project,
    shepp_logan,
)
from repro.kernels.tomo.ref import backproject_ref, gridrec_ref, mlem_ref, project_ref, ramp_filter

__all__ = [
    "backproject",
    "backproject_ref",
    "gridrec",
    "gridrec_batch",
    "gridrec_ref",
    "mlem",
    "mlem_batch",
    "mlem_ref",
    "project",
    "project_ref",
    "ramp_filter",
    "shepp_logan",
]

"""Pallas TPU kernels: parallel-beam forward/back projection.

Hardware adaptation (DESIGN.md §2): GPU tomography codes scatter/gather per
ray; TPUs hate scatter. Both projectors are reformulated as *one-hot
interpolation matmuls*: for one angle, the (pixel-block x detector) linear
interpolation weights form a 2-nonzero-per-row matrix built on the fly from
iota comparisons (VPU) and contracted on the MXU:

    backproject:  img_block  += W (P x n_det) @ sino_row (n_det)
    project:      sino_row   += W^T @ img_block_flat

Grids iterate (row-block, angle-block) with the output block revisited
across the angle dimension and initialized at the first visit — the
sequential TPU grid makes the accumulation race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interp_weights(n: int, n_det: int, by: int, row0, cos_t, sin_t):
    """W (by*n, n_det) for one angle and a block of ``by`` image rows."""
    c = (n - 1) / 2.0
    y = (row0 + jax.lax.broadcasted_iota(jnp.float32, (by, n), 0)) - c
    x = jax.lax.broadcasted_iota(jnp.float32, (by, n), 1) - c
    s = (x * cos_t + y * sin_t + (n_det - 1) / 2.0).reshape(-1)  # (P,)
    s0 = jnp.floor(s)
    f = s - s0
    det = jax.lax.broadcasted_iota(jnp.float32, (by * n, n_det), 1)
    w = jnp.where(det == s0[:, None], (1.0 - f)[:, None], 0.0)
    w = w + jnp.where(det == (s0 + 1.0)[:, None], f[:, None], 0.0)
    return w


def _bp_kernel(sino_ref, cos_ref, sin_ref, out_ref, *, n, n_det, by, ba):
    rb = pl.program_id(0)  # row block
    ab = pl.program_id(1)  # angle block

    @pl.when(ab == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def angle(i, acc):
        w = _interp_weights(n, n_det, by, rb * by, cos_ref[i], sin_ref[i])
        row = sino_ref[i, :].astype(jnp.float32)  # (n_det,)
        contrib = jax.lax.dot_general(
            w, row[:, None], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (P, 1)
        return acc + contrib[:, 0].reshape(by, n)

    acc = jax.lax.fori_loop(0, ba, angle, jnp.zeros((by, n), jnp.float32))
    out_ref[...] += acc


def _fp_kernel(img_ref, cos_ref, sin_ref, out_ref, *, n, n_det, by, ba):
    ab = pl.program_id(0)  # angle block
    rb = pl.program_id(1)  # row block

    @pl.when(rb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    img_flat = img_ref[...].astype(jnp.float32).reshape(-1, 1)  # (P, 1)

    def angle(i, acc):
        w = _interp_weights(n, n_det, by, rb * by, cos_ref[i], sin_ref[i])
        row = jax.lax.dot_general(
            w, img_flat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (n_det, 1)
        return acc.at[i, :].add(row[:, 0])

    acc = jax.lax.fori_loop(0, ba, angle, jnp.zeros((ba, n_det), jnp.float32))
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("n", "by", "ba", "interpret"))
def backproject_pallas(sino, cos_t, sin_t, *, n: int, by: int = 16, ba: int = 8, interpret: bool = False):
    """sino (A, n_det), cos/sin (A,) -> image (n, n)."""
    a, n_det = sino.shape
    assert a % ba == 0 and n % by == 0, (a, ba, n, by)
    kernel = functools.partial(_bp_kernel, n=n, n_det=n_det, by=by, ba=ba)
    return pl.pallas_call(
        kernel,
        grid=(n // by, a // ba),
        in_specs=[
            pl.BlockSpec((ba, n_det), lambda rb, ab: (ab, 0)),
            pl.BlockSpec((ba,), lambda rb, ab: (ab,)),
            pl.BlockSpec((ba,), lambda rb, ab: (ab,)),
        ],
        out_specs=pl.BlockSpec((by, n), lambda rb, ab: (rb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(sino, cos_t, sin_t)


@functools.partial(jax.jit, static_argnames=("n_det", "by", "ba", "interpret"))
def project_pallas(img, cos_t, sin_t, *, n_det: int, by: int = 16, ba: int = 8, interpret: bool = False):
    """img (n, n), cos/sin (A,) -> sinogram (A, n_det)."""
    n = img.shape[0]
    a = cos_t.shape[0]
    assert a % ba == 0 and n % by == 0, (a, ba, n, by)
    kernel = functools.partial(_fp_kernel, n=n, n_det=n_det, by=by, ba=ba)
    return pl.pallas_call(
        kernel,
        grid=(a // ba, n // by),
        in_specs=[
            pl.BlockSpec((by, n), lambda ab, rb: (rb, 0)),
            pl.BlockSpec((ba,), lambda ab, rb: (ab,)),
            pl.BlockSpec((ba,), lambda ab, rb: (ab,)),
        ],
        out_specs=pl.BlockSpec((ba, n_det), lambda ab, rb: (ab, 0)),
        out_shape=jax.ShapeDtypeStruct((a, n_det), jnp.float32),
        interpret=interpret,
    )(img, cos_t, sin_t)

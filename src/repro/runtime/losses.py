"""Vocab-parallel embedding + cross-entropy (Megatron-style) via shard_map.

Problem: final hidden states are sequence-sharded on "model" while the output
head is vocab-sharded on "model" — full (B,S,V) logits cannot exist, and a
GSPMD seq-chunk scan over a sharded dim serializes. Solution: each shard
all-gathers the (small) hidden states for its batch shard, computes logits
against its local vocab slice in sequence chunks, and the softmax reductions
run as pmax/psum over "model". Collective volume per step: one hidden
all-gather (B_l*S*d) + O(B*S) scalars — independent of vocab size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import shard_map
from jax.sharding import PartitionSpec as P


def vocab_parallel_embed(tokens: jax.Array, embed: jax.Array, rules) -> jax.Array:
    """Embedding lookup with a vocab-sharded table.

    GSPMD lowers a plain ``embed[tokens]`` by all-gathering the full table
    (measured: 4.4 GiB f32 per step for the 1T config). Instead: each shard
    gathers from its local vocab slice (out-of-range rows -> 0) and a psum
    over "model" assembles the result — collective volume is one activation,
    independent of vocab size. Output is sequence-sharded like the tokens.
    """
    mesh = rules.mesh
    n_model = mesh.shape["model"]
    Vp = embed.shape[0]
    vshard = Vp // n_model
    bspec = rules.batch_axes if rules.batch_axes else None
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]
    seq_axis = "model" if tokens.shape[1] % n_model == 0 and tokens.shape[1] > 1 else None

    def local(tl, el):
        i = jax.lax.axis_index("model")
        if seq_axis is not None:
            # every vocab shard needs the *full* token slice of this batch
            # shard: gather the (cheap, int32) tokens, embed against the
            # local vocab slice, reduce-scatter back to sequence shards
            tl = jax.lax.all_gather(tl, "model", axis=1, tiled=True)  # (B_l, S)
        t_loc = tl - i * vshard
        in_range = (t_loc >= 0) & (t_loc < vshard)
        safe = jnp.clip(t_loc, 0, vshard - 1)
        x = el[safe]  # (B_l, S, d) partial (only local-vocab hits)
        x = jnp.where(in_range[..., None], x, jnp.zeros((), x.dtype))
        if seq_axis is not None:
            return jax.lax.psum_scatter(x, "model", scatter_dimension=1, tiled=True)
        return jax.lax.psum(x, "model")

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, seq_axis), P("model", None)),
        out_specs=P(bspec, seq_axis, None),
        check_vma=False,
    )
    return fn(tokens, embed)


def vocab_parallel_cross_entropy(
    x: jax.Array,          # (B, S, D) seq-sharded on "model"
    head: jax.Array,       # (Vp, D) vocab-sharded on "model"
    targets: jax.Array,    # (B, S) int32
    mask: jax.Array,       # (B, S) float
    rules,
    *,
    chunk: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Returns (sum_nll, sum_mask) as replicated scalars."""
    mesh = rules.mesh
    n_model = mesh.shape["model"]
    B, S, D = x.shape
    Vp = head.shape[0]
    vshard = Vp // n_model
    bspec = rules.batch_axes if rules.batch_axes else None
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]

    cs = min(chunk, S)
    while S % cs:
        cs -= 1
    n_chunks = S // cs

    def local(xl, hl, tl, ml):
        i = jax.lax.axis_index("model")
        xg = jax.lax.all_gather(xl, "model", axis=1, tiled=True)  # (B_l, S, D)
        tg = jax.lax.all_gather(tl, "model", axis=1, tiled=True)  # (B_l, S)
        mg = jax.lax.all_gather(ml, "model", axis=1, tiled=True)
        B_l = xg.shape[0]
        xc = xg.reshape(B_l, n_chunks, cs, D).swapaxes(0, 1)
        tc = tg.reshape(B_l, n_chunks, cs).swapaxes(0, 1)
        mc = mg.reshape(B_l, n_chunks, cs).swapaxes(0, 1)
        hT = hl.astype(xl.dtype).T  # (D, vshard)

        def step(xi, ti, mi):
            logits = (xi @ hT).astype(jnp.float32)  # (B_l, cs, vshard)
            # stabilization constant only -> gradients cancel exactly
            lmax = jax.lax.stop_gradient(
                jax.lax.pmax(jax.lax.stop_gradient(logits).max(axis=-1), "model")
            )
            sumexp = jax.lax.psum(jnp.exp(logits - lmax[..., None]).sum(axis=-1), "model")
            lse = jnp.log(sumexp) + lmax
            t_loc = ti - i * vshard
            in_range = (t_loc >= 0) & (t_loc < vshard)
            safe = jnp.clip(t_loc, 0, vshard - 1)
            picked_loc = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            picked = jax.lax.psum(jnp.where(in_range, picked_loc, 0.0), "model")
            return ((lse - picked) * mi).sum()

        # chunk count is static, so a Python loop works where lax.scan does
        # not: the pre-promotion shard_map cannot transpose a scan inside the
        # mapped body (its scalar carry residual breaks the spec check)
        tot = jnp.float32(0.0)
        for c in range(n_chunks):
            tot = tot + step(xc[c], tc[c], mc[c])
        # reduce over batch shards -> replicated scalar
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if axes:
            tot = jax.lax.psum(tot, axes)
        return tot

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, "model", None), P("model", None), P(bspec, "model"), P(bspec, "model")),
        out_specs=P(),
        check_vma=False,
    )
    # the mask count needs no sharded compute, and keeping the mapped fn
    # single-output sidesteps a pre-promotion shard_map transpose bug when
    # several outputs carry nonzero cotangents (e.g. loss = tot / cnt)
    return fn(x, head, targets, mask), mask.astype(jnp.float32).sum()

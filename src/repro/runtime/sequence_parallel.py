"""Sequence-parallel linear-recurrence cores (WKV6 / Mamba2-SSD).

Problem (measured, EXPERIMENTS.md §Perf): a chunked scan over a sequence-
sharded chunk dim serializes across shards under GSPMD (each step lives on
one shard) and AD materializes per-chunk decay tensors — rwkv6 train_4k
showed 4.8e14 B/device traffic and a 113 GiB peak.

Fix — the distributed linear-attention decomposition. Linear recurrences
compose associatively:

    S_shard_i = D_i * S_start_i + S_i^local,   D_i = prod of decays in shard i

so each "model" shard (1) runs its local chunked core with S0 = 0, (2)
all-gathers the tiny per-shard (S_i^local, D_i) summaries, (3) computes its
exclusive prefix S_start_i locally, and (4) adds the closed-form correction
``out_t += (r_t * decay_from_shard_start(t)) @ S_start_i``. One collective of
O(H*N*N) bytes per layer replaces the serialized global scan. Chunk bodies
are jax.checkpoint-ed so backward recomputes the decay tensors instead of
saving them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import shard_map
from jax.sharding import PartitionSpec as P


def _bspec(rules):
    b = rules.batch_axes if rules.batch_axes else None
    if isinstance(b, tuple) and len(b) == 1:
        b = b[0]
    return b


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------


def wkv6_sharded(r, k, v, w, u, rules, *, chunk: int = 32):
    """Sequence-parallel WKV6. r,k,v,w: (B,H,T,N) with T sharded on "model";
    initial state is zeros (train/prefill from scratch). Returns (out, state)
    with state replicated."""
    from repro.models.rwkv6 import wkv6_chunked

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    bspec = _bspec(rules)
    spec = P(bspec, None, "model", None)

    def local(r_l, k_l, v_l, w_l, u_l):
        B, H, T_l, N = r_l.shape
        i = jax.lax.axis_index("model")
        S0 = jnp.zeros((B, H, N, N), jnp.float32)
        out_local, S_local = wkv6_chunked(
            r_l, k_l, v_l, w_l, u_l, S0, chunk=chunk, checkpoint_chunks=True
        )
        # per-shard total decay and within-shard exclusive cumulative decay
        lw = jnp.log(jnp.maximum(w_l, 1e-38))  # (B,H,T,N)
        clog = jnp.cumsum(lw, axis=2)
        D_local = jnp.exp(clog[:, :, -1])  # (B,H,N)
        cprev = jnp.exp(clog - lw)  # decay from shard start, exclusive

        # gather the tiny summaries and fold the exclusive prefix
        S_all = jax.lax.all_gather(S_local, "model")  # (n, B,H,N,N)
        D_all = jax.lax.all_gather(D_local, "model")  # (n, B,H,N)
        S_start = jnp.zeros_like(S_local)
        for j in range(n_model):
            take = j < i
            S_start = jnp.where(take, S_start * D_all[j][..., :, None] + S_all[j], S_start)
        # correction: contributions of earlier shards to local outputs
        out = out_local + jnp.einsum("bhtn,bhnm->bhtm", r_l * cprev, S_start)
        # final global state (identical on every shard after folding all)
        S_final = S_start * D_all[i][..., :, None] + S_local
        last = jnp.where(i == n_model - 1, 1.0, 0.0)
        S_final = jax.lax.psum(S_final * last, "model")
        return out, S_final

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, P(None, None)),
        out_specs=(spec, P(bspec, None, None, None)),
        check_vma=False,
    )
    return fn(r, k, v, w, u)


# ---------------------------------------------------------------------------
# causal depthwise conv with halo exchange
# ---------------------------------------------------------------------------


def conv1d_sharded(x, w, b, rules):
    """Depthwise causal conv over a sequence-sharded ``x`` (B,T,Ch).

    Under GSPMD, the K shifted copies of a sharded dim each force a reshard;
    instead each shard ppermutes its last K-1 rows to its right neighbour
    (the halo) and convolves locally — one tiny collective-permute per layer.
    """
    import jax.nn

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    K = w.shape[0]
    bspec = _bspec(rules)
    spec = P(bspec, "model", None)

    def local(xl, wl, bl):
        i = jax.lax.axis_index("model")
        halo = jax.lax.ppermute(
            xl[:, -(K - 1) :], "model", [(s, (s + 1) % n_model) for s in range(n_model)]
        )
        halo = jnp.where(i == 0, jnp.zeros_like(halo), halo)  # causal start
        xp = jnp.concatenate([halo, xl], axis=1)
        T_l = xl.shape[1]
        out = sum(xp[:, j : j + T_l] * wl[j][None, None] for j in range(K)) + bl[None, None]
        return jax.nn.silu(out)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, P(None, None), P(None)),
        out_specs=spec,
        check_vma=False,
    )
    return fn(x, w, b)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd_sharded(x, dt, A, B, C, D, rules, *, chunk: int = 64):
    """Sequence-parallel SSD. x: (Bt,T,H,P), dt: (Bt,T,H), B,C: (Bt,T,G,N);
    T sharded on "model"; zero initial state."""
    from repro.models.mamba2 import ssd_chunked

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    bspec = _bspec(rules)
    x_spec = P(bspec, "model", None, None)
    dt_spec = P(bspec, "model", None)
    bc_spec = P(bspec, "model", None, None)

    def local(x_l, dt_l, B_l, C_l):
        Bt, T_l, H, Pd = x_l.shape
        N = B_l.shape[-1]
        i = jax.lax.axis_index("model")
        S0 = jnp.zeros((Bt, H, Pd, N), jnp.float32)
        y_local, S_local = ssd_chunked(
            x_l, dt_l, A, B_l, C_l, D, S0, chunk=chunk, checkpoint_chunks=True
        )
        dA = dt_l * A[None, None]  # (Bt,T,H), <= 0
        cum = jnp.cumsum(dA, axis=1)
        D_local = jnp.exp(cum[:, -1])  # (Bt,H) per-shard decay
        cincl = jnp.exp(cum)  # y_t reads S_t (inclusive decay from shard start)

        S_all = jax.lax.all_gather(S_local, "model")  # (n,Bt,H,P,N)
        D_all = jax.lax.all_gather(D_local, "model")  # (n,Bt,H)
        S_start = jnp.zeros_like(S_local)
        for j in range(n_model):
            take = j < i
            S_start = jnp.where(take, S_start * D_all[j][..., None, None] + S_all[j], S_start)
        # correction: y_t += (C_t * decay_from_start) . S_start
        y = y_local + jnp.einsum(
            "btn,bth,bhpn->bthp", C_l[:, :, 0], cincl, S_start
        )
        S_final = S_start * D_all[i][..., None, None] + S_local
        last = jnp.where(i == n_model - 1, 1.0, 0.0)
        S_final = jax.lax.psum(S_final * last, "model")
        return y, S_final

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, dt_spec, bc_spec, bc_spec),
        out_specs=(x_spec, P(bspec, None, None, None)),
        check_vma=False,
    )
    return fn(x, dt, B, C)

"""Static analysis of compiled HLO text: flops, HBM traffic, collective bytes.

Why this exists: XLA's ``compiled.cost_analysis()`` counts while-loop bodies
exactly once (measured — see EXPERIMENTS.md §Dry-run), so scanned-layer
models under-report flops by ~n_layers. This module parses
``compiled.as_text()`` and walks the call graph, multiplying loop bodies by
their parsed trip counts.

Cost model:
  * flops: 2 * prod(out_dims) * prod(contracted lhs dims) per ``dot``.
  * bytes (HBM-traffic estimate): every *top-level* op (fusions = one op;
    their intermediates stay in registers/VMEM) writes its output once and
    that output is read ~once downstream -> 2 x sum(output bytes), plus the
    entry parameters read once. This avoids the gross overcount of charging
    a dynamic-slice fusion for its full (unsliced) operand. Pure-metadata
    ops (parameter/tuple/gte/constant/bitcast) are free.
  * collective bytes: sum of operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute / ragged-all-to-all.
  * while: trip_count x (body + cond); conditional: max over branches;
    fusion/call: dot flops + collectives recursed (bytes are not).

Trip counts are parsed from the canonical jax scan condition
(``compare(iv, constant(N)), direction=LT`` with iv starting at 0); a
``trip_hints`` override is available for non-canonical loops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclass
class OpInfo:
    name: str
    out_type: str
    opcode: str
    rest: str  # operand list + attributes


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # op name -> out_type


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_moved: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    while_trip_counts: dict = field(default_factory=dict)
    #: bytes of hoisted bf16->f32 whole-tensor upcasts at entry level.
    #: XLA's *CPU* dot emitter cannot consume bf16 operands natively, so it
    #: converts entire (stacked) bf16 weight arrays to f32 and LICM hoists
    #: those converts out of the layer loops — buffers that do not exist on
    #: TPU (native bf16 MXU). Subtract from peak for the TPU estimate.
    cpu_upcast_artifact_bytes: float = 0.0
    #: TPU-fusion-modeled HBM traffic: dot operands+outputs, collective
    #: payloads, while-loop carries (read+write per iteration) and entry
    #: parameters. Elementwise/norm chains are assumed fused into their
    #: consumers (which is what the TPU compiler does); ``bytes_moved`` is
    #: the conservative every-op model and upper-bounds this.
    bytes_moved_fused: float = 0.0


_FREE_OPS = {
    "parameter", "tuple", "get-tuple-element", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "domain",
    # loop-carry copies are CPU-backend artifacts (elided on TPU, which
    # updates buffers in place); real layout changes appear as transpose/fusion
    "copy", "copy-start", "copy-done",
}


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            op = OpInfo(om.group(1), om.group(2), om.group(3), om.group(4))
            cur.ops.append(op)
            cur.symbols[op.name] = op.out_type
    return comps


def _operand_names(rest: str) -> list[str]:
    """Names inside the top-level operand parens of ``rest``."""
    depth, out, cur = 0, [], ""
    for ch in rest:
        if ch == "(":
            depth += 1
            continue
        if ch == ")":
            depth -= 1
            if depth <= 0:
                out.append(cur)
                break
            continue
        if depth >= 1:
            cur += ch
    # split on commas outside []/{} — operands may carry an inline type
    # ("f32[32,128]{1,0} %copy.10", older HLO text) whose dims also use commas
    parts, cur, bdepth = [], "", 0
    for ch in "".join(out):
        if ch in "[{":
            bdepth += 1
        elif ch in "]}":
            bdepth -= 1
        if ch == "," and bdepth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    parts.append(cur)
    names = []
    for part in parts:
        part = part.strip()
        # with an inline type the name is the last token; bare names stand alone
        pm = re.search(r"%?([\w\.\-]+)$", part)
        if pm:
            names.append(pm.group(1))
    return names


def _called_comp(rest: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out_dims, _ = _shape_dims(op.out_type)
    names = _operand_names(op.opcode + "(" + op.rest)
    # lhs operand type
    lhs_type = comp.symbols.get(names[0], "") if names else ""
    lhs_dims, _ = _shape_dims(lhs_type)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contracted = 1
    if cm and cm.group(1):
        for i in cm.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contracted


def _trip_count(cond: Computation) -> int | None:
    """Canonical jax scan cond: compare(iv, constant(N)) LT, iv from 0."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"\(?(-?\d+)\)?", op.rest)
            if m and ("s32" in op.out_type or "u32" in op.out_type or "s64" in op.out_type):
                consts[op.name] = int(m.group(1))
    best = None
    for op in cond.ops:
        if "compare" in op.opcode or op.opcode == "fusion":
            names = _operand_names(op.opcode + "(" + op.rest)
            for n in names:
                if n in consts:
                    best = max(best or 0, consts[n])
    if best is None and consts:
        best = max(consts.values())
    return best


def analyze_hlo(
    text: str,
    trip_hints: dict[str, int] | None = None,
    *,
    dynamic_trip_default: int = 1,
) -> HloCost:
    """``dynamic_trip_default``: trip count assumed for while loops whose
    bound is data-dependent (e.g. the causal flash KV loop, whose trips vary
    per shard — pass the *average* block count)."""
    comps = parse_computations(text)
    trip_hints = trip_hints or {}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: computation named main-ish
        entry = max(comps, key=lambda c: len(comps[c].ops))

    total = HloCost()
    memo: dict[tuple[str, bool], tuple[float, float, float, float]] = {}

    def comp_cost(name: str, top_level: bool) -> tuple[float, float, float, float]:
        """Returns (flops, bytes, collective_bytes, fused_bytes)."""
        key = (name, top_level)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0, 0.0)
        fl = by = cb = fb = 0.0
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                fl += _dot_flops(op, comp)
                fb += _operand_bytes(op, comp) + shape_bytes(op.out_type)
                if top_level:
                    by += _op_bytes(op, comp)
            elif oc in COLLECTIVES or any(oc.startswith(c + "-") for c in COLLECTIVES):
                base = next((c for c in COLLECTIVES if oc == c or oc.startswith(c + "-")), oc)
                b = _operand_bytes(op, comp)
                cb += b
                fb += b + shape_bytes(op.out_type)
                total.collective_counts[base] = total.collective_counts.get(base, 0) + 1
                if top_level:
                    by += _op_bytes(op, comp)
            elif oc == "while":
                body = _called_comp(op.rest, "body")
                cond = _called_comp(op.rest, "condition")
                trips = trip_hints.get(op.name)
                if trips is None and cond in comps:
                    trips = _trip_count(comps[cond])
                trips = trips if trips and trips > 0 else dynamic_trip_default
                total.while_trip_counts[op.name] = trips
                bf, bb, bc, bfb = comp_cost(body, top_level) if body else (0, 0, 0, 0)
                cf, cbk, cc, cfb = comp_cost(cond, False) if cond else (0, 0, 0, 0)
                fl += trips * (bf + cf)
                by += trips * bb
                cb += trips * (bc + cc)
                fb += trips * (bfb + cfb)
            elif oc == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", op.rest)
                sub = [comp_cost(b, top_level) for b in branches if b in comps]
                if sub:
                    fl += max(s[0] for s in sub)
                    by += max(s[1] for s in sub)
                    cb += max(s[2] for s in sub)
                    fb += max(s[3] for s in sub)
            elif oc in ("fusion", "call", "async-start", "async-done", "custom-call", "map", "reduce", "sort", "scatter", "select-and-scatter"):
                callee = _called_comp(op.rest, "calls") or _called_comp(op.rest, "to_apply")
                if callee and callee in comps:
                    sf, _, sc, sfb = comp_cost(callee, False)
                    fl += sf
                    cb += sc
                    fb += sfb
                if oc in ("scatter", "select-and-scatter"):
                    fb += shape_bytes(op.out_type)
                if top_level and oc not in _FREE_OPS:
                    by += _op_bytes(op, comp)
            else:
                if oc in ("dynamic-update-slice", "gather", "dynamic-slice", "concatenate", "transpose", "reshape"):
                    # data-movement ops hit HBM even under TPU fusion
                    fb += shape_bytes(op.out_type)
                if top_level and oc not in _FREE_OPS:
                    by += _op_bytes(op, comp)
        memo[key] = (fl, by, cb, fb)
        return memo[key]

    def _operand_bytes(op: OpInfo, comp: Computation) -> float:
        names = _operand_names(op.opcode + "(" + op.rest)
        return float(sum(shape_bytes(comp.symbols.get(n, "")) for n in names))

    def _op_bytes(op: OpInfo, comp: Computation) -> float:
        # write once + read ~once downstream
        return 2.0 * shape_bytes(op.out_type)

    fl, by, cb, fb = comp_cost(entry, True)
    # entry parameters (weights, inputs) are read at least once
    for op in comps[entry].ops:
        if op.opcode == "parameter":
            by += shape_bytes(op.out_type)
            fb += shape_bytes(op.out_type)

    # CPU-backend artifact: entry-level whole-array bf16->f32 upcasts
    def _is_upcast(op: OpInfo, comp: Computation) -> bool:
        dims, dt = _shape_dims(op.out_type)
        if dt != "f32" or not dims:
            return False
        n = 1
        for d in dims:
            n *= d
        if n * 4 < (1 << 26):  # only count big (>=64 MiB) hoisted stacks
            return False
        if op.opcode == "convert":
            names = _operand_names(op.opcode + "(" + op.rest)
            src = comp.symbols.get(names[0], "") if names else ""
            sdims, sdt = _shape_dims(src)
            return sdt == "bf16" and sdims == dims
        if op.opcode == "fusion":
            callee = _called_comp(op.rest, "calls")
            sub = comps.get(callee)
            if sub and len([o for o in sub.ops if o.opcode != "parameter"]) == 1:
                root = [o for o in sub.ops if o.opcode != "parameter"][0]
                if root.opcode == "convert":
                    pdims = [
                        _shape_dims(o.out_type) for o in sub.ops if o.opcode == "parameter"
                    ]
                    return any(pd == dims and pt == "bf16" for pd, pt in pdims)
        return False

    artifact = 0.0
    for op in comps[entry].ops:
        if _is_upcast(op, comps[entry]):
            artifact += shape_bytes(op.out_type)

    total.flops = fl
    total.bytes_moved = by
    total.collective_bytes = cb
    total.bytes_moved_fused = fb
    total.cpu_upcast_artifact_bytes = artifact
    return total

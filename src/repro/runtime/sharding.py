"""Logical-axis -> PartitionSpec rules (divisibility-checked).

Params and activations carry *logical* axis names (DESIGN.md §4); this module
maps them onto the mesh:

* tensor-parallel names ("vocab", "mlp", "qkv", "heads", "kv", "experts")
  shard on the "model" axis;
* "batch" shards on ("pod","data") (greedily trimmed so the dim divides);
* "seq" (train/prefill activations) shards on "model" (sequence parallelism —
  no head-count divisibility constraints, DESIGN.md §4);
* "cache_seq" (decode KV caches) shards on "model", and additionally takes
  the "data" axis when the batch is too small to use it (long_500k, B=1);
* ZeRO: every parameter additionally shards its largest unmapped dim over
  ("pod","data") when divisible (optimizer state inherits param shardings).

jax rejects non-divisible shardings, so every mapping is checked against the
actual dim and silently falls back to replication when it does not divide.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TENSOR_AXES = ("vocab", "mlp", "qkv", "heads", "kv", "experts")


def _axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _fits(dim: int, mesh: Mesh, axes: Sequence[str]) -> bool:
    s = _axis_size(mesh, axes)
    return s > 1 and dim % s == 0


@dataclass
class ShardingRules:
    """Maps logical axis names to mesh axes for one (mesh, workload shape)."""

    mesh: Mesh
    batch_axes: tuple[str, ...] = ()
    zero: bool = True  # FSDP/ZeRO-shard params over the batch axes
    kind: str = "train"  # "train" | "prefill" | "decode"

    @classmethod
    def for_shape(cls, mesh: Mesh, *, kind: str, global_batch: int, zero: bool = True) -> "ShardingRules":
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        # greedily trim the batch axes until the global batch divides
        batch_axes = dp
        while batch_axes and global_batch % _axis_size(mesh, batch_axes) != 0:
            batch_axes = batch_axes[1:]
        return cls(mesh=mesh, batch_axes=batch_axes, zero=zero, kind=kind)

    # -- logical name -> candidate mesh axes --------------------------------

    def _map_name(self, name: str | None, dim: int) -> Any:
        if name is None or name == "layers":
            return None
        if name in TENSOR_AXES:
            return "model" if _fits(dim, self.mesh, ("model",)) else None
        if name == "embed":
            return None  # ZeRO may take it for params
        if name in ("batch", "moe_groups"):
            return self.batch_axes if _fits(dim, self.mesh, self.batch_axes) else None
        if name == "seq":
            return "model" if _fits(dim, self.mesh, ("model",)) else None
        if name == "cache_seq":
            unused = tuple(
                a for a in ("pod", "data") if a in self.mesh.shape and a not in self.batch_axes
            )
            cand = unused + ("model",)
            if _fits(dim, self.mesh, cand):
                return cand
            return "model" if _fits(dim, self.mesh, ("model",)) else None
        raise ValueError(f"unknown logical axis {name!r}")

    def spec(self, axes: Sequence[str | None], shape: Sequence[int], *, is_param: bool = False) -> P:
        entries: list[Any] = []
        used: set[str] = set()
        for name, dim in zip(axes, shape):
            m = self._map_name(name, dim)
            if isinstance(m, tuple) and not m:
                m = None
            if m is not None:
                flat = (m,) if isinstance(m, str) else tuple(m)
                if used & set(flat):
                    m = None  # a mesh axis may appear once per spec
                else:
                    used.update(flat)
            entries.append(m)
        if is_param and self.zero:
            entries = self._apply_zero(entries, axes, shape, used)
        while entries and entries[-1] is None:
            entries.pop()
        # 1-tuples mean the same partitioning as their bare axis name, but the
        # pinned jax's PartitionSpec compares them unequal — normalize
        entries = [e[0] if isinstance(e, tuple) and len(e) == 1 else e for e in entries]
        return P(*entries)

    def _apply_zero(self, entries, axes, shape, used) -> list:
        if "vocab" in axes:
            # embedding / lm_head stay vocab-sharded only: the vocab-parallel
            # CE (runtime/losses.py) consumes them directly per-shard
            return entries
        zero_axes = tuple(
            a for a in ("pod", "data") if a in self.mesh.shape and a not in used
        )
        if not zero_axes:
            return entries
        # largest unmapped dim that divides by the full zero-axis group
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is not None or axes[i] == "layers":
                continue
            for cand in (zero_axes, zero_axes[-1:]):
                if _fits(shape[i], self.mesh, cand):
                    entries[i] = cand if len(cand) > 1 else cand[0]
                    return entries
        return entries

    # -- tree-level helpers ---------------------------------------------------

    def shardings(self, axes_tree: Any, struct_tree: Any, *, is_param: bool = False) -> Any:
        def one(axes, struct):
            return NamedSharding(self.mesh, self.spec(axes, struct.shape, is_param=is_param))

        return jax.tree.map(
            one, axes_tree, struct_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
        )


def param_shardings(model, mesh: Mesh, *, zero: bool = True) -> Any:
    rules = ShardingRules(mesh=mesh, batch_axes=(), zero=zero)
    # params don't depend on the workload shape; batch axes only matter for ZeRO
    rules.batch_axes = ()
    return rules.shardings(model.param_axes(), model.param_struct(), is_param=True)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# activation-constraint context: models call ``constrain(x, axes)`` with
# logical names; it is a no-op unless a step builder installed rules.
# ---------------------------------------------------------------------------

import contextlib
import threading

_CTX = threading.local()


@contextlib.contextmanager
def activation_rules(rules: "ShardingRules | None"):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = prev


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """Attach a GSPMD sharding constraint using logical axis names (no-op
    outside an ``activation_rules`` context)."""
    rules: ShardingRules | None = getattr(_CTX, "rules", None)
    if rules is None:
        return x
    spec = rules.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))

"""Step builders: jit-wired train / prefill / decode steps for a (model, mesh).

``build_*`` return a :class:`StepBundle` holding the jitted function plus the
in/out shardings and ShapeDtypeStruct trees needed both by the dry-run
(``.lower(...)`` on structs) and by live execution (device_put real arrays to
the same shardings).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.base import BaseModel
from repro.runtime.optimizer import Optimizer, OptimizerConfig
from repro.runtime.sharding import ShardingRules, activation_rules, param_shardings


@dataclass
class StepBundle:
    fn: Callable  # jitted
    in_structs: tuple
    in_shardings: tuple
    out_shardings: Any
    rules: ShardingRules

    def lower(self):
        return self.fn.lower(*self.in_structs)


def _shard_tree(rules: ShardingRules, axes_tree, struct_tree):
    return rules.shardings(axes_tree, struct_tree)


def make_rules(mesh: Mesh, shape: ShapeConfig, *, zero: bool = True) -> ShardingRules:
    return ShardingRules.for_shape(
        mesh, kind=shape.kind, global_batch=shape.global_batch, zero=zero
    )


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_step(
    model: BaseModel,
    mesh: Mesh,
    shape: ShapeConfig,
    opt_cfg: OptimizerConfig | None = None,
    *,
    grad_accum: int | None = None,
    donate: bool = True,
) -> StepBundle:
    cfg = model.cfg
    opt = Optimizer(
        opt_cfg
        or OptimizerConfig(
            name=cfg.optimizer, moment_dtype=cfg.moment_dtype, first_moment=cfg.first_moment
        )
    )
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    # grad accumulators in param dtype: bf16 halves the accumulation buffer
    # for the trillion-param config (noise is amortized over few microbatches)
    accum_dtype = jnp.dtype(cfg.param_dtype)
    rules = make_rules(mesh, shape)

    p_shard = param_shardings(model, mesh)
    p_struct = model.param_struct()
    o_struct = opt.state_struct(p_struct)
    o_shard = rules.shardings(opt.state_axes(model.param_axes()), o_struct, is_param=True)
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        with activation_rules(rules):
            if accum <= 1:
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            else:
                # microbatch scan over the leading batch dim (activation
                # footprint / accum)
                def micro(carry, mb):
                    acc, lsum = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + (gg / accum).astype(accum_dtype), acc, g
                    )
                    return (acc, lsum + l), None

                mbs = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
                )
                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
                (grads, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mbs)
                loss = lsum / accum
                metrics = {}
            new_params, new_opt, stats = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    # metrics are scalars -> replicated
    out_metrics = jax.eval_shape(train_step, p_struct, o_struct, b_struct)[2]
    metric_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), out_metrics)

    fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        donate_argnums=(0, 1) if donate else (),
    )
    return StepBundle(
        fn=fn,
        in_structs=(p_struct, o_struct, b_struct),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        rules=rules,
    )


# ---------------------------------------------------------------------------
# serve: prefill & decode
# ---------------------------------------------------------------------------


def _serving_zero(model: BaseModel, mesh: Mesh) -> bool:
    """Serving shards weights over the batch axes too when the model-axis
    shard alone would not fit HBM (the 1T config); small models keep weights
    replicated across data shards to avoid per-layer gathers."""
    from repro.utils.tree import tree_bytes

    per_chip = tree_bytes(model.param_struct()) / mesh.shape.get("model", 1)
    return per_chip > 8e9


def build_prefill_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig) -> StepBundle:
    zero = _serving_zero(model, mesh)
    rules = make_rules(mesh, shape, zero=zero)
    p_shard = param_shardings(model, mesh, zero=zero)
    p_struct = model.param_struct()
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def prefill(params, batch):
        with activation_rules(rules):
            logits, cache = model.prefill(params, batch)
        return logits, cache

    out_struct = jax.eval_shape(prefill, p_struct, b_struct)
    logits_shard = NamedSharding(mesh, rules.spec(("batch", None, None), out_struct[0].shape))
    # prefill cache has the same tree as cache_struct (sequence = prompt len)
    cache_shard = rules.shardings(model.cache_axes(shape), out_struct[1])
    fn = jax.jit(
        prefill,
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, cache_shard),
    )
    return StepBundle(fn, (p_struct, b_struct), (p_shard, b_shard), (logits_shard, cache_shard), rules)


def build_decode_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig, *, donate: bool = True) -> StepBundle:
    zero = _serving_zero(model, mesh)
    rules = make_rules(mesh, shape, zero=zero)
    p_shard = param_shardings(model, mesh, zero=zero)
    p_struct = model.param_struct()
    c_struct = model.cache_struct(shape)
    c_shard = rules.shardings(model.cache_axes(shape), c_struct)
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def decode(params, cache, batch):
        with activation_rules(rules):
            logits, cache = model.decode(params, cache, batch)
        return logits, cache

    out_struct = jax.eval_shape(decode, p_struct, c_struct, b_struct)
    logits_shard = NamedSharding(mesh, rules.spec(("batch", None, None), out_struct[0].shape))
    fn = jax.jit(
        decode,
        in_shardings=(p_shard, c_shard, b_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return StepBundle(
        fn, (p_struct, c_struct, b_struct), (p_shard, c_shard, b_shard), (logits_shard, c_shard), rules
    )


def build_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig, **kw) -> StepBundle:
    """Dispatch on the shape kind (train_step vs serve_step)."""
    if shape.kind == "train":
        return build_train_step(model, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(model, mesh, shape)
    return build_decode_step(model, mesh, shape)

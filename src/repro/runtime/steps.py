"""Step builders: jit-wired train / prefill / decode steps for a (model, mesh).

``build_*`` return a :class:`StepBundle` holding the jitted function plus the
in/out shardings and ShapeDtypeStruct trees needed both by the dry-run
(``.lower(...)`` on structs) and by live execution (device_put real arrays to
the same shardings).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.base import BaseModel
from repro.runtime.optimizer import Optimizer, OptimizerConfig
from repro.runtime.sharding import ShardingRules, activation_rules, param_shardings


@dataclass
class StepBundle:
    fn: Callable  # jitted
    in_structs: tuple
    in_shardings: tuple
    out_shardings: Any
    rules: ShardingRules

    def lower(self):
        return self.fn.lower(*self.in_structs)


def _shard_tree(rules: ShardingRules, axes_tree, struct_tree):
    return rules.shardings(axes_tree, struct_tree)


def make_rules(mesh: Mesh, shape: ShapeConfig, *, zero: bool = True) -> ShardingRules:
    return ShardingRules.for_shape(
        mesh, kind=shape.kind, global_batch=shape.global_batch, zero=zero
    )


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_step(
    model: BaseModel,
    mesh: Mesh,
    shape: ShapeConfig,
    opt_cfg: OptimizerConfig | None = None,
    *,
    grad_accum: int | None = None,
    donate: bool = True,
) -> StepBundle:
    cfg = model.cfg
    opt = Optimizer(
        opt_cfg
        or OptimizerConfig(
            name=cfg.optimizer, moment_dtype=cfg.moment_dtype, first_moment=cfg.first_moment
        )
    )
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    # grad accumulators in param dtype: bf16 halves the accumulation buffer
    # for the trillion-param config (noise is amortized over few microbatches)
    accum_dtype = jnp.dtype(cfg.param_dtype)
    rules = make_rules(mesh, shape)

    p_shard = param_shardings(model, mesh)
    p_struct = model.param_struct()
    o_struct = opt.state_struct(p_struct)
    o_shard = rules.shardings(opt.state_axes(model.param_axes()), o_struct, is_param=True)
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        with activation_rules(rules):
            if accum <= 1:
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            else:
                # microbatch scan over the leading batch dim (activation
                # footprint / accum)
                def micro(carry, mb):
                    acc, lsum = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + (gg / accum).astype(accum_dtype), acc, g
                    )
                    return (acc, lsum + l), None

                mbs = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
                )
                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
                (grads, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mbs)
                loss = lsum / accum
                metrics = {}
            new_params, new_opt, stats = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    # metrics are scalars -> replicated
    out_metrics = jax.eval_shape(train_step, p_struct, o_struct, b_struct)[2]
    metric_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), out_metrics)

    fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        donate_argnums=(0, 1) if donate else (),
    )
    return StepBundle(
        fn=fn,
        in_structs=(p_struct, o_struct, b_struct),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        rules=rules,
    )


# ---------------------------------------------------------------------------
# serve: prefill & decode
# ---------------------------------------------------------------------------


def _serving_zero(model: BaseModel, mesh: Mesh) -> bool:
    """Serving shards weights over the batch axes too when the model-axis
    shard alone would not fit HBM (the 1T config); small models keep weights
    replicated across data shards to avoid per-layer gathers."""
    from repro.utils.tree import tree_bytes

    per_chip = tree_bytes(model.param_struct()) / mesh.shape.get("model", 1)
    return per_chip > 8e9


def build_prefill_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig) -> StepBundle:
    zero = _serving_zero(model, mesh)
    rules = make_rules(mesh, shape, zero=zero)
    p_shard = param_shardings(model, mesh, zero=zero)
    p_struct = model.param_struct()
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def prefill(params, batch):
        with activation_rules(rules):
            logits, cache = model.prefill(params, batch)
        return logits, cache

    out_struct = jax.eval_shape(prefill, p_struct, b_struct)
    logits_shard = NamedSharding(mesh, rules.spec(("batch", None, None), out_struct[0].shape))
    # prefill cache has the same tree as cache_struct (sequence = prompt len)
    cache_shard = rules.shardings(model.cache_axes(shape), out_struct[1])
    fn = jax.jit(
        prefill,
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, cache_shard),
    )
    return StepBundle(fn, (p_struct, b_struct), (p_shard, b_shard), (logits_shard, cache_shard), rules)


def build_decode_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig, *, donate: bool = True) -> StepBundle:
    zero = _serving_zero(model, mesh)
    rules = make_rules(mesh, shape, zero=zero)
    p_shard = param_shardings(model, mesh, zero=zero)
    p_struct = model.param_struct()
    c_struct = model.cache_struct(shape)
    c_shard = rules.shardings(model.cache_axes(shape), c_struct)
    b_struct = model.input_specs(shape)
    b_shard = _shard_tree(rules, model.input_axes(shape), b_struct)

    def decode(params, cache, batch):
        with activation_rules(rules):
            logits, cache = model.decode(params, cache, batch)
        return logits, cache

    out_struct = jax.eval_shape(decode, p_struct, c_struct, b_struct)
    logits_shard = NamedSharding(mesh, rules.spec(("batch", None, None), out_struct[0].shape))
    fn = jax.jit(
        decode,
        in_shardings=(p_shard, c_shard, b_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return StepBundle(
        fn, (p_struct, c_struct, b_struct), (p_shard, c_shard, b_shard), (logits_shard, c_shard), rules
    )


# ---------------------------------------------------------------------------
# paged serve: prefill & decode against a page pool (repro.serving)
# ---------------------------------------------------------------------------


def _check_paged(model: BaseModel) -> None:
    if not getattr(model, "SUPPORTS_PAGED", False) or getattr(model, "is_vlm", False):
        raise ValueError(
            f"{type(model).__name__} does not support the paged serving path "
            "(needs last_pos prefill + the standard (L,B,S,KV,hd) cache tree)"
        )


def build_paged_prefill_step(model: BaseModel, *, page_size: int, donate: bool = True) -> Callable:
    """Jitted prefill that writes the prompt cache straight into pool pages.

    ``fn(params, k_pages, v_pages, tokens, last_pos, table) -> (next_tok,
    k_pages, v_pages)`` with ``tokens``: (B, S) rows right-padded to a bucket
    that is a multiple of ``page_size``, ``last_pos``: (B,) index of each
    row's true last prompt token, ``table``: (B, S // page_size) physical
    page ids covering each row's whole bucket (padding rows/columns point at
    scratch page 0, whose writes are absorbed). One compile per (row bucket,
    prompt bucket) pair — a step's joiners prefill as one stacked call.
    Pages are donated: the caller re-assigns ``k_pages/v_pages`` from the
    result every call.
    """
    _check_paged(model)
    ps = int(page_size)

    def prefill(params, k_pages, v_pages, tokens, last_pos, table):
        B, S = tokens.shape
        logits, cache = model.prefill(
            params, {"tokens": tokens, "last_pos": last_pos})
        # scatter the (L, B, S, KV, hd) prompt cache into each row's pages;
        # flattening (B, S//ps) row-major keeps page blocks aligned with the
        # flattened table, and duplicate scratch-page indices may collide —
        # page 0 is never read
        def to_pages(pages, dense):
            L, _, _, KV, hd = dense.shape
            paged = dense.reshape(L, B * (S // ps), ps, KV, hd)
            return pages.at[:, table.reshape(-1)].set(paged.astype(pages.dtype))

        k_pages = to_pages(k_pages, cache["k"])
        v_pages = to_pages(v_pages, cache["v"])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
        return next_tok, k_pages, v_pages

    return jax.jit(prefill, donate_argnums=(1, 2) if donate else ())


def build_paged_decode_step(
    model: BaseModel,
    *,
    page_size: int,
    use_kernel: bool = False,
    interpret: bool | None = None,
    donate: bool = True,
    quantum: int = 1,
) -> Callable:
    """Jitted decode over gathered pages, one dispatch per scheduling quantum.

    With ``quantum=1`` (the default): ``fn(params, k_pages, v_pages, tokens,
    positions, table) -> (next_tok, k_pages, v_pages)`` with ``tokens``:
    (B, 1) current token per live row, ``positions``: (B,) write index
    (= live length) per row, ``table``: (B, max_pages) page ids padded with
    the scratch page 0. Gathers each row's logical context ``table ->
    (B, max_pages*page_size)`` dense view, runs ``model.decode``, and
    scatters only the new K/V entry back into the row's live page. Padded
    rows/entries resolve to page 0 — garbage that ``positions`` masks on
    read and scratch writes absorb. Compiles once per (batch-bucket,
    pages-bucket) pair.

    With ``quantum=q > 1`` ONE dispatch emits q greedy tokens per live row:
    ``fn(..., table, left) -> (tokens (B, q), k_pages, v_pages)`` where
    ``left``: (B,) tokens remaining in each row's output budget. The pages
    are gathered ONCE into a dense per-row context, a ``lax.scan`` decodes q
    steps against that small dense cache (the full pools stay out of the
    scan carry — carrying them would copy every page each iteration), and
    all q new K/V entries scatter back in a single pool update. Entries with
    ``s >= left[row]`` redirect to scratch page 0, so a row can never write
    past its page reservation; the host discards the surplus tokens (greedy
    decode is prefix-stable, so the kept prefix is identical to stepping one
    token at a time). This amortizes the per-dispatch host overhead that
    dominates one-token-per-call serving of small models, at the cost of
    joiners waiting up to q steps to enter.

    ``use_kernel`` routes decode attention through the Pallas kernel
    (trace-time scope; ``block_kv = page_size`` so cache chunks line up with
    pages and the early exit skips unwritten ones).
    """
    _check_paged(model)
    ps = int(page_size)
    q = max(int(quantum), 1)

    def one(params, k_pages, v_pages, tokens, positions, table, write):
        B, mp = table.shape
        L, _, _, KV, hd = k_pages.shape

        def gather(pages):
            return pages[:, table].reshape(L, B, mp * ps, KV, hd)

        cache = {"k": gather(k_pages), "v": gather(v_pages)}
        logits, cache = model.decode(
            params, cache, {"tokens": tokens, "positions": positions})
        # scatter back only the entry model.decode wrote at ``positions``;
        # rows past their budget (write=False) land in scratch page 0, and
        # the index clamps keep over-budget positions in bounds (their
        # values are discarded anyway)
        rows = jnp.arange(B)
        pg = jnp.where(write, table[rows, jnp.minimum(positions // ps, mp - 1)], 0)
        off = jnp.where(write, positions % ps, 0)

        def scatter(pages, dense):
            new = dense[:, rows, jnp.minimum(positions, mp * ps - 1)]  # (L, B, KV, hd)
            return pages.at[:, pg, off].set(new.astype(pages.dtype))

        k_pages = scatter(k_pages, cache["k"])
        v_pages = scatter(v_pages, cache["v"])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
        return next_tok, k_pages, v_pages

    if q == 1:
        def decode(params, k_pages, v_pages, tokens, positions, table):
            B = table.shape[0]
            return one(params, k_pages, v_pages, tokens, positions, table,
                       jnp.ones((B,), bool))
    else:
        def decode(params, k_pages, v_pages, tokens, positions, table, left):
            B, mp = table.shape
            L, _, _, KV, hd = k_pages.shape

            def gather(pages):
                return pages[:, table].reshape(L, B, mp * ps, KV, hd)

            cache = {"k": gather(k_pages), "v": gather(v_pages)}

            def body(carry, _):
                tok, pos, cache = carry
                logits, cache = model.decode(
                    params, cache, {"tokens": tok, "positions": pos})
                nt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return (nt[:, None], pos + 1, cache), nt

            (_, _, cache), toks = jax.lax.scan(
                body, (tokens, positions, cache), None, length=q)
            # one masked scatter of all q new entries per row back into the
            # pool; over-budget steps land in scratch page 0, index clamps
            # keep out-of-range positions in bounds (values discarded)
            rows = jnp.arange(B)[:, None]  # (B, 1)
            steps = jnp.arange(q)[None, :]  # (1, q)
            pos_q = positions[:, None] + steps  # (B, q)
            write = steps < left[:, None]
            pg = jnp.where(
                write, table[rows, jnp.minimum(pos_q // ps, mp - 1)], 0)
            off = jnp.where(write, pos_q % ps, 0)

            def scatter(pages, dense):
                # dense (L, B, mp*ps, KV, hd) -> the q freshly decoded slots
                new = jnp.take_along_axis(
                    dense, jnp.minimum(pos_q, mp * ps - 1)[None, :, :, None, None],
                    axis=2)  # (L, B, q, KV, hd)
                flat = new.reshape(L, B * q, KV, hd)
                return pages.at[:, pg.reshape(-1), off.reshape(-1)].set(
                    flat.astype(pages.dtype))

            k_pages = scatter(k_pages, cache["k"])
            v_pages = scatter(v_pages, cache["v"])
            return toks.T, k_pages, v_pages  # (B, q)

    if use_kernel:
        from repro.models.attention import decode_kernel_scope

        inner = decode

        def decode_with_kernel(params, k_pages, v_pages, *rest):
            # trace-time routing: jit traces this body once per shape, and the
            # scope is active during that trace, baking the kernel into HLO
            with decode_kernel_scope(block_kv=ps, interpret=interpret):
                return inner(params, k_pages, v_pages, *rest)

        decode = decode_with_kernel

    return jax.jit(decode, donate_argnums=(1, 2) if donate else ())


def build_step(model: BaseModel, mesh: Mesh, shape: ShapeConfig, **kw) -> StepBundle:
    """Dispatch on the shape kind (train_step vs serve_step)."""
    if shape.kind == "train":
        return build_train_step(model, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(model, mesh, shape)
    return build_decode_step(model, mesh, shape)

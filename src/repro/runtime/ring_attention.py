"""Ring attention: sequence-parallel prefill with overlapped KV rotation.

Instead of one bulk all-gather of K/V per layer (the baseline schedule),
each "model" shard holds its local KV block and the blocks rotate around
the ring via collective-permute — at step j shard i processes the block
originating at shard (i - j) mod n while the next block is in flight. The
total bytes moved match the all-gather, but:

* peak memory holds ONE rotating block instead of the full gathered KV
  ((n-1)/n less transient footprint), and
* every transfer is a neighbour permute that overlaps with the block's
  compute (the roofline max() model assumes overlap; on hardware this is
  what makes it true).

Forward-only (prefill/serve): the rotation loop uses fori_loop and is not
reverse-differentiable; the train path uses the custom-VJP flash instead
(runtime/sharded_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def ring_attention_shmap(q, k, v, rules, *, causal: bool, block_kv: int, scale: float):
    """q: (B,S,H,hd); k, v: (B,S,KV,hd) — all sequence-sharded on "model"."""
    mesh = rules.mesh
    n = mesh.shape["model"]
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bspec = rules.batch_axes if rules.batch_axes else None
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]
    spec = P(bspec, "model", None, None)
    perm = [(s, (s + 1) % n) for s in range(n)]

    def local(ql, kl, vl):
        i = jax.lax.axis_index("model")
        S_l = ql.shape[1]
        qg = (ql.reshape(ql.shape[0], S_l, KV, G, hd).astype(jnp.float32) * scale)
        q_pos = (i * S_l + jnp.arange(S_l)).astype(jnp.float32)

        acc0 = jnp.zeros((ql.shape[0], KV, G, S_l, hd), jnp.float32)
        m0 = jnp.full((ql.shape[0], KV, G, S_l), NEG_INF, jnp.float32)
        l0 = jnp.zeros((ql.shape[0], KV, G, S_l), jnp.float32)

        def step(j, carry):
            acc, m, l, k_blk, v_blk = carry
            src = (i - j) % n  # shard of origin of the block we now hold
            k_pos = (src * S_l + jnp.arange(S_l)).astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_blk.astype(jnp.float32))
            if causal:
                s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked blocks (future KV): exp(NEG_INF - NEG_INF)
            m_safe = jnp.maximum(m_new, -1e30)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            alpha = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_safe), 1.0)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32)
            )
            # rotate: send our current block to the next shard
            k_nxt = jax.lax.ppermute(k_blk, "model", perm)
            v_nxt = jax.lax.ppermute(v_blk, "model", perm)
            return acc, m_new, l, k_nxt, v_nxt

        acc, m, l, _, _ = jax.lax.fori_loop(0, n, step, (acc0, m0, l0, kl, vl))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(ql.shape[0], S_l, H, hd).astype(vl.dtype)

    fn = shard_map(
        local, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec, check_vma=False
    )
    return fn(q, k, v)

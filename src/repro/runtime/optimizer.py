"""Optimizers (AdamW, Adafactor) + LR schedules + global-norm clipping.

Built from scratch (no optax in the environment). Optimizer state mirrors the
parameter tree so it inherits parameter shardings (fully-sharded states =
ZeRO); Adafactor's factored second moment drops the dominant state term for
the trillion-parameter config (DESIGN.md §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # "adamw" | "adafactor" | "sgd"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "constant" | "linear"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "float32" | "bfloat16"
    min_lr_ratio: float = 0.1
    first_moment: bool = True  # adafactor: False drops m entirely (1T configs)
    # update stacked-layer leaves one layer slice at a time (lax.map):
    # bounds optimizer f32 temporaries to 1/L of the leaf instead of ~3x leaf
    layerwise_update: bool = True


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:  # linear
            decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    return cfg.learning_rate * warm * decay


def _leaf_sqnorm(x: jax.Array) -> jax.Array:
    # big stacked-layer leaves: reduce one slice at a time (f32 temp / L)
    if x.ndim >= 3 and x.size >= (1 << 22):
        return jax.lax.map(lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x).sum()
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(_leaf_sqnorm(x) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # scale in native dtype: no f32 copies of full leaves
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def _decay_mask(path_leaf) -> bool:
    """Weight decay only on >=2D params (skip norms/biases/scalars)."""
    return len(path_leaf.shape) >= 2


# ---------------------------------------------------------------------------


class Optimizer:
    """Stateless namespace bound to a config; state is an explicit pytree."""

    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg

    # -- state -------------------------------------------------------------

    def init(self, params: Any) -> dict:
        cfg = self.cfg
        mdt = jnp.dtype(cfg.moment_dtype)
        if cfg.name == "sgd":
            return {"step": jnp.zeros((), jnp.int32)}
        if cfg.name == "adamw":
            zeros = lambda p: jnp.zeros(p.shape, mdt)
            return {
                "step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
            }
        if cfg.name == "adafactor":
            def vrow(p):
                return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 else jnp.zeros(p.shape, jnp.float32)

            def vcol(p):
                if p.ndim >= 2:
                    return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return jnp.zeros((), jnp.float32)

            state = {
                "step": jnp.zeros((), jnp.int32),
                "v_row": jax.tree.map(vrow, params),
                "v_col": jax.tree.map(vcol, params),
            }
            if cfg.first_moment:
                state["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
            return state
        raise ValueError(self.cfg.name)

    def state_struct(self, param_struct: Any) -> dict:
        return jax.eval_shape(self.init, param_struct)

    def state_axes(self, param_axes: Any) -> dict:
        """Logical axes for optimizer state, derived from param axes."""
        cfg = self.cfg
        if cfg.name == "sgd":
            return {"step": ()}
        if cfg.name == "adamw":
            return {"step": (), "m": param_axes, "v": param_axes}
        strip_last = jax.tree.map(
            lambda ax: tuple(ax[:-1]) if len(ax) >= 2 else tuple(ax),
            param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
        strip_snd = jax.tree.map(
            lambda ax: tuple(ax[:-2] + ax[-1:]) if len(ax) >= 2 else (),
            param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
        axes = {"step": (), "v_row": strip_last, "v_col": strip_snd}
        if self.cfg.first_moment:
            axes["m"] = param_axes
        return axes

    # -- update -------------------------------------------------------------

    def update(self, grads: Any, state: dict, params: Any) -> tuple[Any, dict, dict]:
        cfg = self.cfg
        step = state["step"] + 1
        lr = lr_at(cfg, step)
        # clip folded into the (layerwise) update: g32 = g.astype(f32) * gscale
        gnorm = global_norm(grads)
        gscale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        stats = {"lr": lr, "grad_norm": gnorm}

        if cfg.name == "sgd":
            new_params = jax.tree.map(
                lambda p, g: (
                    p.astype(jnp.float32) - lr * gscale * g.astype(jnp.float32)
                ).astype(p.dtype),
                params,
                grads,
            )
            return new_params, {"step": step}, stats

        if cfg.name == "adamw":
            b1, b2 = cfg.b1, cfg.b2
            c1 = 1 - b1 ** step.astype(jnp.float32)
            c2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, g, m, v):
                g32 = g.astype(jnp.float32) * gscale
                m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
                mhat, vhat = m32 / c1, v32 / c2
                delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
                if _decay_mask(p):
                    delta = delta + cfg.weight_decay * p.astype(jnp.float32)
                return (
                    (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m32.astype(m.dtype),
                    v32.astype(v.dtype),
                )

            out = jax.tree.map(self._leafwise(upd), params, grads, state["m"], state["v"])
            pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
            return pick(0), {"step": step, "m": pick(1), "v": pick(2)}, stats

        if cfg.name == "adafactor":
            b2t = 1.0 - (step.astype(jnp.float32) ** -0.8)
            use_m = cfg.first_moment

            def upd(p, g, vr, vc, m=None):
                g32 = g.astype(jnp.float32) * gscale
                g2 = g32 * g32 + 1e-30
                if p.ndim >= 2:
                    vr32 = b2t * vr + (1 - b2t) * g2.mean(axis=-1)
                    vc32 = b2t * vc + (1 - b2t) * g2.mean(axis=-2)
                    denom = jnp.maximum(vr32.mean(axis=-1, keepdims=True), 1e-30)
                    vhat = (vr32[..., :, None] / denom[..., None]) * vc32[..., None, :]
                else:
                    vr32 = b2t * vr + (1 - b2t) * g2
                    vc32 = vc
                    vhat = vr32
                u = g32 / jnp.sqrt(vhat + cfg.eps)
                # update clipping (Adafactor §7)
                rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
                u = u / jnp.maximum(1.0, rms_u)
                if use_m:
                    u = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
                    new_m = u.astype(m.dtype)
                delta = u
                if _decay_mask(p):
                    delta = delta + cfg.weight_decay * p.astype(jnp.float32)
                new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
                return (new_p, vr32, vc32, new_m) if use_m else (new_p, vr32, vc32)

            if use_m:
                out = jax.tree.map(
                    self._leafwise(upd), params, grads, state["v_row"], state["v_col"], state["m"]
                )
            else:
                out = jax.tree.map(self._leafwise(upd), params, grads, state["v_row"], state["v_col"])
            pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
            new_state = {"step": step, "v_row": pick(1), "v_col": pick(2)}
            if use_m:
                new_state["m"] = pick(3)
            return pick(0), new_state, stats

        raise ValueError(cfg.name)

    def _leafwise(self, upd):
        """Wrap a per-leaf update to run one leading-dim slice at a time for
        big stacked-layer leaves (bounds f32 temporaries to leaf/L)."""
        if not self.cfg.layerwise_update:
            return upd

        def wrapped(p, g, *rest):
            big = p.ndim >= 3 and p.shape[0] >= 8 and p.size >= (1 << 22)
            consistent = all(
                r.ndim >= 1 and r.shape[:1] == p.shape[:1] for r in rest
            )
            if big and g.shape == p.shape and consistent:
                return jax.lax.map(lambda args: upd(*args), (p, g, *rest))
            return upd(p, g, *rest)

        return wrapped

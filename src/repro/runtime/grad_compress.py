"""Quantized cross-pod gradient reduction (beyond-paper distributed-opt trick).

The inter-pod link is the scarcest bandwidth on a multi-pod system (DCI <<
ICI). Gradients are reduced hierarchically: full-precision reduce-scatter
inside the pod (GSPMD), then an **int8 block-quantized all-gather + local
sum** across the "pod" axis via shard_map, with error feedback carrying the
quantization residual into the next step (Seide et al. / 1-bit-Adam lineage).

Why all-gather instead of all-reduce: an int8 all-reduce would overflow (or
silently upcast to int32 on the wire); gathering the int8 payloads + per-
block scales and summing after dequantization keeps the wire format at
~1.02 B/param vs 4 B/param f32 — a ~3.9x cross-pod traffic cut, visible as
`all-gather s8[...]` in the compiled HLO (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization. x: flat (N,) f32, N % BLOCK == 0."""
    blocks = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def _pad_flat(x: jax.Array) -> jax.Array:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat


def quantized_psum(x: jax.Array, resid: jax.Array, axis_name: str = "pod") -> tuple[jax.Array, jax.Array]:
    """Quantized cross-pod sum — call INSIDE a shard_map that is manual over
    ``axis_name``. ``x``: this pod's partial gradient (any shape); ``resid``:
    flat error-feedback state (padded length, see ``resid_len``).

    Returns (reduced value with x's shape/dtype, new residual).
    """
    shape, dtype = x.shape, x.dtype
    flat = _pad_flat(x)
    corrected = flat + resid
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, corrected.shape)
    new_resid = corrected - deq  # error feedback: residual re-enters next step
    qg = jax.lax.all_gather(q, axis_name)  # (p, blocks, BLOCK) int8 on the wire
    sg = jax.lax.all_gather(scale, axis_name)  # (p, blocks, 1) f32 (tiny)
    reduced = jnp.sum(qg.astype(jnp.float32) * sg, axis=0).reshape(flat.shape)
    n = 1
    for d in shape:
        n *= d
    return reduced[:n].reshape(shape).astype(dtype), new_resid


def resid_len(n_params: int) -> int:
    """Length of the flat error-feedback buffer for an ``n_params`` leaf."""
    return ((n_params + BLOCK - 1) // BLOCK) * BLOCK


def quantized_psum_tree(grads: Any, resids: Any, axis_name: str = "pod") -> tuple[Any, Any]:
    """Tree version of :func:`quantized_psum` (still inside a shard_map)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(resids)
    outs = [quantized_psum(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def compression_wire_bytes(n_params: int) -> tuple[int, int]:
    """(compressed, f32) bytes per cross-pod exchange of one gradient copy."""
    blocks = (n_params + BLOCK - 1) // BLOCK
    return n_params * 1 + blocks * 4, n_params * 4

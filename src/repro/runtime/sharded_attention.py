"""shard_map attention: sequence-parallel flash attention for train/prefill.

Why not plain GSPMD: a flash-style q-block loop is a *sequential* construct;
under GSPMD with the sequence dim sharded, reshaping (S,) -> (nq, bq) forces
an all-gather and the loop serializes across shards (measured: ~390 GB/device
collectives on smollm train_4k). The SPMD-correct structure maps the q-block
loop onto the mesh: each "model" shard owns S/16 query rows and runs a local
online-softmax loop over KV blocks.

Baseline schedule: all-gather K,V over "model" (one fused collective per
layer), then a dynamic-bound fori_loop over KV blocks with causal early-exit
(shard i stops after (i+1) * S_local rows). The ring schedule (§Perf,
runtime/ring_attention.py) replaces the all-gather with overlapped
collective-permutes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _pick_block(s: int, want: int) -> int:
    b = min(want, s)
    while s % b:
        b -= 1
    return b


def local_flash(q, k, v, *, q_offset, causal: bool, block_kv: int, scale: float,
                differentiable: bool):
    """Per-device flash attention.

    q: (B, Sq, KV, G, hd) grouped queries (global row ``q_offset + i``);
    k, v: (B, Skv, KV, hd) full keys/values. Online softmax over KV blocks.

    ``differentiable=False`` (prefill): dynamic-bound fori_loop — a causal
    shard skips KV blocks beyond its last query row (dynamic trip count is
    fine forward-only). ``differentiable=True`` (train): static lax.scan over
    all blocks with masking — reverse-mode AD cannot differentiate a
    dynamic-trip while loop. The §Perf pass replaces the train path with a
    custom-VJP flash that restores the causal skip.
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    bkv = _pick_block(Skv, block_kv)
    n_blocks = Skv // bkv
    qf = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)  # global rows

    acc0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)

    def attend(carry, j, k_blk, v_blk):
        acc, m, l = carry
        k_blk = k_blk.astype(jnp.float32)
        v_blk = v_blk.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_blk)
        if causal:
            k_pos = j * bkv + jnp.arange(bkv)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk)
        return acc, m_new, l

    if differentiable:
        kb = k.reshape(B, n_blocks, bkv, KV, hd).swapaxes(0, 1)
        vb = v.reshape(B, n_blocks, bkv, KV, hd).swapaxes(0, 1)

        def step(carry, inp):
            j, k_blk, v_blk = inp
            return attend(carry, j, k_blk, v_blk), None

        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (jnp.arange(n_blocks), kb, vb)
        )
    else:
        def body(j, carry):
            k_blk = jax.lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=1)
            return attend(carry, j, k_blk, v_blk)

        if causal:  # shard only needs KV rows <= its last query row
            n_needed = jnp.minimum((q_offset + Sq + bkv - 1) // bkv, n_blocks)
        else:
            n_needed = n_blocks
        acc, m, l = jax.lax.fori_loop(0, n_needed, body, (acc0, m0, l0))

    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,KV,G,Sq,hd)
    return out.transpose(0, 3, 1, 2, 4)  # (B,Sq,KV,G,hd)


# ---------------------------------------------------------------------------
# custom-VJP flash: no per-block residuals saved (bwd recomputes each block),
# causal early-exit in both directions. This is what bounds train-time
# attention memory to O(block) and halves causal attention flops vs the
# masked static scan (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------


def _flash_fwd_core(q, k, v, q_pos, *, causal, block_kv, scale):
    """Returns (out f32 (B,KV,G,Sq,hd), lse (B,KV,G,Sq)).

    ``q_pos``: (Sq,) f32 global row positions (f32 so it can be a plain
    differentiable arg of the custom_vjp with a zero cotangent — it is traced
    per-shard via axis_index and hence cannot be a nondiff argnum).
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    bkv = _pick_block(Skv, block_kv)
    n_blocks = Skv // bkv
    qf = q.astype(jnp.float32) * scale

    acc0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)

    def body(j, carry):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=1).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=1).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_blk)
        if causal:
            k_pos = (j * bkv + jnp.arange(bkv)).astype(jnp.float32)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk)
        return acc, m_new, l

    if causal:  # shard needs KV blocks up to its last query row only
        n_needed = jnp.minimum(q_pos[-1].astype(jnp.int32) // bkv + 1, n_blocks)
    else:
        n_needed = n_blocks
    acc, m, l = jax.lax.fori_loop(0, n_needed, body, (acc0, m0, l0))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, q_pos, causal, block_kv, scale):
    out, _ = _flash_fwd_core(q, k, v, q_pos, causal=causal, block_kv=block_kv, scale=scale)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B,Sq,KV,G,hd)


def _flash_fwd(q, k, v, q_pos, causal, block_kv, scale):
    out, lse = _flash_fwd_core(q, k, v, q_pos, causal=causal, block_kv=block_kv, scale=scale)
    res = (q, k, v, q_pos, out, lse)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype), res


def _flash_bwd(causal, block_kv, scale, res, g):
    q, k, v, q_pos, out, lse = res  # out/lse f32 (B,KV,G,Sq,...)
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    bkv = _pick_block(Skv, block_kv)
    n_blocks = Skv // bkv
    qf = q.astype(jnp.float32) * scale
    do = g.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (B,KV,G,Sq,hd)
    delta = jnp.sum(do * out, axis=-1)  # (B,KV,G,Sq)

    dq0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    dk0 = jnp.zeros((B, Skv, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, Skv, KV, hd), jnp.float32)

    def body(j, carry):
        dq, dk, dv = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=1).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=1).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_blk)
        if causal:
            k_pos = (j * bkv + jnp.arange(bkv)).astype(jnp.float32)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,KV,G,Sq,bkv)
        dv_blk = jnp.einsum("bkgqs,bkgqd->bskd", p, do)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do, v_blk)
        ds = p * (dp - delta[..., None])  # d(s_scaled)
        dq = dq + jnp.einsum("bkgqs,bskd->bkgqd", ds, k_blk) * scale
        dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, q.astype(jnp.float32)) * scale
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * bkv, bkv, 1) + dk_blk, j * bkv, 1
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * bkv, bkv, 1) + dv_blk, j * bkv, 1
        )
        return dq, dk, dv

    if causal:
        n_needed = jnp.minimum(q_pos[-1].astype(jnp.int32) // bkv + 1, n_blocks)
    else:
        n_needed = n_blocks
    dq, dk, dv = jax.lax.fori_loop(0, n_needed, body, (dq0, dk0, dv0))
    dq = dq.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,KV,G,hd)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), jnp.zeros_like(q_pos)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def sharded_attention(q, k, v, rules, *, causal: bool, block_kv: int = 512, impl: str = "allgather"):
    """Sequence-parallel attention over the "model" axis via shard_map.

    q: (B, S, H, hd); k, v: (B, Skv, KV, hd) — all sequence-sharded on
    "model", batch on the rules' batch axes.
    """
    mesh = rules.mesh
    B, S, H, hd = q.shape
    KV = k.shape[2]
    n_model = mesh.shape["model"]
    scale = 1.0 / math.sqrt(hd)
    bspec = rules.batch_axes if rules.batch_axes else None
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]
    qkv_spec = P(bspec, "model", None, None)

    if impl == "ring":
        if rules.kind == "train":  # rotation loop is fwd-only; train uses flash VJP
            impl = "flash"
        else:
            from repro.runtime.ring_attention import ring_attention_shmap

            return ring_attention_shmap(
                q, k, v, rules, causal=causal, block_kv=block_kv, scale=scale
            )

    differentiable = rules.kind == "train"
    use_flash_vjp = impl == "flash"

    def local(ql, kl, vl):
        i = jax.lax.axis_index("model")
        kg = jax.lax.all_gather(kl, "model", axis=1, tiled=True)  # (B_l, S, KV, hd)
        vg = jax.lax.all_gather(vl, "model", axis=1, tiled=True)
        Sq = ql.shape[1]
        qg = ql.reshape(ql.shape[0], Sq, KV, H // KV, hd)
        if use_flash_vjp:
            q_pos = (i * Sq + jnp.arange(Sq)).astype(jnp.float32)
            out = flash_attention(qg, kg, vg, q_pos, causal, block_kv, scale)
        else:
            out = local_flash(
                qg, kg, vg, q_offset=i * Sq, causal=causal, block_kv=block_kv,
                scale=scale, differentiable=differentiable,
            )
        return out.reshape(ql.shape[0], Sq, H, hd)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(qkv_spec, qkv_spec, qkv_spec),
        out_specs=qkv_spec,
        check_vma=False,
    )
    return fn(q, k, v).astype(v.dtype)

"""Model interface shared by every architecture family."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.common import SpecTree, init_params, spec_axes, spec_struct


class BaseModel:
    """A model = param specs + pure functions (loss / prefill / decode).

    Subclasses implement ``param_specs``, ``loss``, ``prefill``,
    ``decode`` and the shape-struct providers used by the dry-run.
    """

    #: model family supports the paged-KV serving path (runtime/steps.py
    #: paged builders): prefill honours ``batch["last_pos"]`` and its cache
    #: is the standard (L, B, S, KV, hd) {"k","v"} tree
    SUPPORTS_PAGED = False

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # ---- params ----------------------------------------------------------

    def param_specs(self) -> SpecTree:
        raise NotImplementedError

    def param_struct(self) -> Any:
        return spec_struct(self.param_specs())

    def param_axes(self) -> Any:
        return spec_axes(self.param_specs())

    def init(self, key: jax.Array) -> Any:
        return init_params(self.param_specs(), key)

    def expert_param_count(self) -> int:
        """Parameters living on the routed-expert path (MoE accounting)."""
        return 0

    # ---- compute ---------------------------------------------------------

    def loss(self, params: Any, batch: dict) -> tuple[jax.Array, dict]:
        """Mean next-token loss + metrics dict for one (micro)batch."""
        raise NotImplementedError

    def prefill(self, params: Any, batch: dict) -> tuple[jax.Array, Any]:
        """Process the full prompt; returns (last-token logits, cache)."""
        raise NotImplementedError

    def decode(self, params: Any, cache: Any, batch: dict) -> tuple[jax.Array, Any]:
        """One decode step; returns (logits, updated cache)."""
        raise NotImplementedError

    # ---- dry-run structs ---------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        raise NotImplementedError

    def input_axes(self, shape: ShapeConfig) -> dict:
        """Logical axes for each input (parallel to ``input_specs``)."""
        raise NotImplementedError

    def cache_struct(self, shape: ShapeConfig) -> Any:
        """ShapeDtypeStruct tree for the decode cache at this shape."""
        raise NotImplementedError

    def cache_axes(self, shape: ShapeConfig) -> Any:
        raise NotImplementedError

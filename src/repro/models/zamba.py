"""Zamba2-style hybrid: Mamba2 backbone + weight-tied shared attention block.

The shared transformer block (attention + MLP, one set of weights) is applied
before every ``shared_block_every``-th Mamba2 layer, consuming
``concat([x, x0])`` (current stream + original embeddings) as in Zamba2 —
the concat restores information the SSM stream may have compressed away.
Per-invocation LoRA adapters from the released model are omitted
(DESIGN.md §5); everything else follows the published layout.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import mamba2
from repro.models.base import BaseModel
from repro.models.common import embed_lookup, ParamSpec, chunked_cross_entropy, rms_norm, shift_targets
from repro.models.ffn import mlp_apply, mlp_specs
from repro.models.transformer import attn_block_apply, attn_block_decode, attn_block_specs


class ZambaLM(BaseModel):
    @property
    def n_sites(self) -> int:
        return math.ceil(self.cfg.n_layers / self.cfg.shared_block_every)

    def _groups(self) -> list[tuple[int, int]]:
        """[(start, end)] mamba layer index ranges, one per shared-block site."""
        k = self.cfg.shared_block_every
        L = self.cfg.n_layers
        return [(s, min(s + k, L)) for s in range(0, L, k)]

    def param_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        dt = self.param_dtype
        shared = {
            "attn_norm": ParamSpec((2 * d,), ("embed",), jnp.float32, init="ones"),
            "mlp_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            **attn_block_specs(cfg, None, d_in=2 * d),
            **mlp_specs(d, cfg.d_ff, None, dt),
        }
        return {
            "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed"), dt, init="normal"),
            "final_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            "lm_head": ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"), dt),
            "shared": shared,
            "mamba": mamba2.mamba_specs(cfg, cfg.n_layers),
        }

    # ---- forward ---------------------------------------------------------

    def _shared_block(self, params, x, x0, *, positions):
        cfg = self.cfg
        cd = self.compute_dtype
        sp = params["shared"]
        h = jnp.concatenate([x, x0], axis=-1)
        h = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
        a, kv = attn_block_apply(cfg, sp, h, positions=positions, compute_dtype=cd)
        x = x + a
        h = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
        return x + mlp_apply(sp, h, cd), kv

    def _shared_block_decode(self, params, x, x0, k_c, v_c, *, positions):
        cfg = self.cfg
        cd = self.compute_dtype
        sp = params["shared"]
        h = jnp.concatenate([x, x0], axis=-1)
        h = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
        a, (k_c, v_c) = attn_block_decode(cfg, sp, h, k_c, v_c, positions=positions, compute_dtype=cd)
        x = x + a
        h = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
        return x + mlp_apply(sp, h, cd), (k_c, v_c)

    def _forward(self, params, tokens, *, collect_cache: bool):
        cfg = self.cfg
        cd = self.compute_dtype
        x = embed_lookup(params["embed"], tokens).astype(cd)
        x0 = x
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def mamba_layer(x, lp):
            out, state = mamba2.mamba_apply(cfg, lp, x, None, compute_dtype=cd, chunked=True)
            return x + out, state if collect_cache else None

        if cfg.remat != "none":
            policy = None if cfg.remat == "full" else jax.checkpoint_policies.checkpoint_dots
            mamba_layer = jax.checkpoint(mamba_layer, policy=policy, prevent_cse=False)

        shared_block = self._shared_block
        if cfg.remat != "none":
            # the 7 shared-attention sites are unrolled (weight-tied), so
            # each needs its own remat scope or their residuals all coexist
            shared_block = jax.checkpoint(
                shared_block, prevent_cse=False, static_argnums=(), policy=None
            )

        kvs, mamba_states = [], []
        for (s, e) in self._groups():
            x, kv = shared_block(params, x, x0, positions=positions)
            lp_g = jax.tree.map(lambda a: a[s:e], params["mamba"])
            x, st = jax.lax.scan(mamba_layer, x, lp_g)
            kvs.append(kv)
            mamba_states.append(st)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

        cache = None
        if collect_cache:
            k = jnp.stack([kv[0] for kv in kvs])  # (sites,B,S,KV,hd)
            v = jnp.stack([kv[1] for kv in kvs])
            mamba_state = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *mamba_states)
            cache = {"k": k, "v": v, "mamba": mamba_state}
        return x, cache

    # ---- public API ------------------------------------------------------

    def loss(self, params, batch):
        tokens = batch["tokens"]
        x, _ = self._forward(params, tokens, collect_cache=False)
        targets, mask = shift_targets(tokens, batch.get("mask"))
        tot, cnt = chunked_cross_entropy(x, params["lm_head"].T, targets, mask, vocab_size=self.cfg.vocab_size)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"ce_loss": loss, "tokens": cnt}

    def prefill(self, params, batch):
        x, cache = self._forward(params, batch["tokens"], collect_cache=True)
        logits = x[:, -1:].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        cd = self.compute_dtype
        positions = batch["positions"]
        x = embed_lookup(params["embed"], batch["tokens"]).astype(cd)
        x0 = x

        def mamba_layer(x, inp):
            lp, conv, ssd = inp
            out, state = mamba2.mamba_apply(
                cfg, lp, x, {"conv": conv, "ssd": ssd}, compute_dtype=cd, chunked=False
            )
            return x + out, (state["conv"], state["ssd"])

        ks, vs, convs, ssds = [], [], [], []
        for i, (s, e) in enumerate(self._groups()):
            x, (k_c, v_c) = self._shared_block_decode(
                params, x, x0, cache["k"][i], cache["v"][i], positions=positions
            )
            lp_g = jax.tree.map(lambda a: a[s:e], params["mamba"])
            conv_g, ssd_g = cache["mamba"]["conv"][s:e], cache["mamba"]["ssd"][s:e]
            x, (conv_n, ssd_n) = jax.lax.scan(mamba_layer, x, (lp_g, conv_g, ssd_g))
            ks.append(k_c), vs.append(v_c), convs.append(conv_n), ssds.append(ssd_n)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        new_cache = {
            "k": jnp.stack(ks),
            "v": jnp.stack(vs),
            "mamba": {
                "conv": jnp.concatenate(convs, axis=0),
                "ssd": jnp.concatenate(ssds, axis=0),
            },
        }
        return logits, new_cache

    # ---- dry-run structs -------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def input_axes(self, shape: ShapeConfig) -> dict:
        if shape.kind == "decode":
            return {"tokens": ("batch", None), "positions": ("batch",)}
        return {"tokens": ("batch", "seq")}

    def cache_struct(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        kv = jax.ShapeDtypeStruct(
            (self.n_sites, B, S, cfg.n_kv_heads, cfg.resolved_head_dim), jnp.bfloat16
        )
        return {"k": kv, "v": kv, "mamba": mamba2.mamba_state_struct(cfg, cfg.n_layers, B)}

    def cache_axes(self, shape: ShapeConfig):
        ax = ("layers", "batch", "cache_seq", None, None)
        return {"k": ax, "v": ax, "mamba": mamba2.mamba_state_axes()}

"""Decoder-only transformer LM: dense, MoE and VLM (stub frontend) families.

Layers are stacked along a leading "layers" axis and applied with
``lax.scan`` (O(1)-in-depth HLO; production compile times). Attention blocks
are reusable by the enc-dec and hybrid families.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention as attn_lib
from repro.models import ffn, moe
from repro.models.base import BaseModel
from repro.models.common import embed_lookup, ParamSpec, apply_rope, chunked_cross_entropy, rms_norm, shift_targets


# ---------------------------------------------------------------------------
# attention block (shared with encdec / zamba)
# ---------------------------------------------------------------------------


def attn_block_specs(cfg: ArchConfig, n_layers: int | None, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    lead = () if n_layers is None else (n_layers,)
    lax_ = () if n_layers is None else ("layers",)
    specs = {
        "wqkv": ParamSpec(lead + (d, (H + 2 * KV) * hd), lax_ + ("embed", "qkv"), dt),
        "wo": ParamSpec(lead + (H * hd, cfg.d_model), lax_ + ("heads", "embed"), dt),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec(lead + (hd,), lax_ + (None,), jnp.float32, init="ones")
        specs["k_norm"] = ParamSpec(lead + (hd,), lax_ + (None,), jnp.float32, init="ones")
    return specs


def _split_qkv(cfg: ArchConfig, qkv: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, S = qkv.shape[:2]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = jnp.split(qkv, [H * hd, (H + KV) * hd], axis=-1)
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _qk_norm(cfg: ArchConfig, p: dict, q: jax.Array, k: jax.Array):
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def attn_block_apply(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    compute_dtype,
    causal: bool = True,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    cd = compute_dtype
    qkv = x.astype(cd) @ p["wqkv"].astype(cd)
    q, k, v = _split_qkv(cfg, qkv)
    q, k = _qk_norm(cfg, p, q, k)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    out = attn_lib.attention(
        q, k, v,
        impl=cfg.attention_impl,
        causal=causal,
        block_q=cfg.attention_block_q,
        block_kv=cfg.attention_block_kv,
    )
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1) @ p["wo"].astype(cd)
    return out, (k, v)


def attn_block_decode(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    positions: jax.Array,
    compute_dtype,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Single-token attention against the cache. ``x``: (B,1,d)."""
    cd = compute_dtype
    qkv = x.astype(cd) @ p["wqkv"].astype(cd)
    q, k, v = _split_qkv(cfg, qkv)
    q, k = _qk_norm(cfg, p, q, k)
    pos = positions[:, None]  # (B,1)
    q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_pct)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_pct)
    k_cache = attn_lib.update_cache(k_cache, k, positions)
    v_cache = attn_lib.update_cache(v_cache, v, positions)
    out = attn_lib.decode_attention(q, k_cache, v_cache, positions=positions)
    out = out.reshape(x.shape[0], 1, -1) @ p["wo"].astype(cd)
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# decoder-only LM
# ---------------------------------------------------------------------------


class DecoderLM(BaseModel):
    """Dense / MoE / VLM decoder-only language model."""

    SUPPORTS_PAGED = True

    @property
    def is_moe(self) -> bool:
        return bool(self.cfg.n_experts)

    @property
    def is_vlm(self) -> bool:
        return self.cfg.frontend == "vision"

    # ---- specs -----------------------------------------------------------

    def param_specs(self) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        dt = self.param_dtype
        d = cfg.d_model
        layers: dict[str, Any] = {
            "attn_norm": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
            "mlp_norm": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
            **attn_block_specs(cfg, L),
        }
        if self.is_moe:
            layers.update(moe.moe_specs(cfg, L))
        else:
            layers.update(ffn.mlp_specs(d, cfg.d_ff, L, dt, gated=cfg.gated_mlp))
        specs = {
            "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed"), dt, init="normal"),
            "final_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"), dt)
        if self.is_vlm:
            specs["vision_proj"] = ParamSpec((d, d), ("embed", None), dt)
        return specs

    def expert_param_count(self) -> int:
        if not self.is_moe:
            return 0
        cfg = self.cfg
        return cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff

    def _head(self, params: dict) -> jax.Array:
        """(V_pad, d) output projection."""
        if self.cfg.tie_embeddings:
            return params["embed"]
        return params["lm_head"].T

    # ---- forward ---------------------------------------------------------

    def _embed_inputs(self, params: dict, batch: dict) -> jax.Array:
        x = embed_lookup(params["embed"], batch["tokens"]).astype(self.compute_dtype)
        if self.is_vlm:
            patches = batch["patch_embeds"].astype(self.compute_dtype)
            patches = patches @ params["vision_proj"].astype(self.compute_dtype)
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def _layer_fn(self, collect_cache: bool):
        cfg = self.cfg
        cd = self.compute_dtype

        from repro.runtime.sharding import constrain

        def layer(carry, lp):
            x, aux, positions = carry
            x = constrain(x, ("batch", "seq", None))
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, kv = attn_block_apply(cfg, lp, h, positions=positions, compute_dtype=cd)
            x = x + a
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            if self.is_moe:
                m, layer_aux = moe.moe_apply(lp, h, cfg, cd)
                aux = aux + layer_aux
            else:
                m = ffn.mlp_apply(lp, h, cd)
            x = x + m
            ys = kv if collect_cache else None
            return (x, aux, positions), ys

        if cfg.remat != "none":
            policy = None if cfg.remat == "full" else jax.checkpoint_policies.checkpoint_dots
            layer = jax.checkpoint(layer, policy=policy, prevent_cse=False)
        return layer

    def _forward(self, params: dict, batch: dict, *, collect_cache: bool):
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        layer = self._layer_fn(collect_cache)
        (x, aux, _), caches = jax.lax.scan(layer, (x, jnp.float32(0.0), positions), params["layers"])
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return x, aux, caches

    # ---- public API ------------------------------------------------------

    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, aux, _ = self._forward(params, batch, collect_cache=False)
        tokens = batch["tokens"]
        targets, mask = shift_targets(tokens, batch.get("mask"))
        if self.is_vlm:  # text hidden states start at patch offset - 1
            P = x.shape[1] - tokens.shape[1]
            x = x[:, P :]
        tot, cnt = chunked_cross_entropy(
            x, self._head(params), targets, mask, vocab_size=cfg.vocab_size
        )
        loss = tot / jnp.maximum(cnt, 1.0)
        metrics = {"ce_loss": loss, "tokens": cnt}
        if self.is_moe:
            aux = aux / cfg.n_layers
            metrics["aux_loss"] = aux
            loss = loss + 0.01 * aux
        return loss, metrics

    def prefill(self, params: dict, batch: dict) -> tuple[jax.Array, Any]:
        x, _, (k, v) = self._forward(params, batch, collect_cache=True)
        last = batch.get("last_pos")
        if last is None:
            xs = x[:, -1:]
        else:
            # variable-length prompts right-padded to a bucket: the logits
            # must come from the true last token, not the padding tail
            xs = x[jnp.arange(x.shape[0]), last][:, None]
        logits = xs.astype(jnp.float32) @ self._head(params).T.astype(jnp.float32)
        cache = {"k": k, "v": v}  # (L, B, S, KV, hd)
        return logits, cache

    def decode(self, params: dict, cache: Any, batch: dict) -> tuple[jax.Array, Any]:
        cfg = self.cfg
        cd = self.compute_dtype
        tokens, positions = batch["tokens"], batch["positions"]
        x = embed_lookup(params["embed"], tokens).astype(cd)  # (B,1,d)

        def layer(carry, inp):
            x, positions = carry
            lp, k_c, v_c = inp
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, (k_c, v_c) = attn_block_decode(
                cfg, lp, h, k_c, v_c, positions=positions, compute_dtype=cd
            )
            x = x + a
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            if self.is_moe:
                m, _ = moe.moe_apply(lp, h, cfg, cd)
            else:
                m = ffn.mlp_apply(lp, h, cd)
            return (x + m, positions), (k_c, v_c)

        (x, _), (k, v) = jax.lax.scan(layer, (x, positions), (params["layers"], cache["k"], cache["v"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x.astype(jnp.float32) @ self._head(params).T.astype(jnp.float32)
        return logits, {"k": k, "v": v}

    # ---- dry-run structs -------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
            }
        if self.is_vlm:
            P = self.cfg.n_patches
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
                "patch_embeds": jax.ShapeDtypeStruct((B, P, self.cfg.d_model), jnp.bfloat16),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def input_axes(self, shape: ShapeConfig) -> dict:
        if shape.kind == "decode":
            return {"tokens": ("batch", None), "positions": ("batch",)}
        axes = {"tokens": ("batch", "seq")}
        if self.is_vlm:
            axes["patch_embeds"] = ("batch", "seq", None)
        return axes

    def cache_struct(self, shape: ShapeConfig) -> Any:
        cfg = self.cfg
        L, B, S = cfg.n_layers, shape.global_batch, shape.seq_len
        kv = jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, cfg.resolved_head_dim), jnp.bfloat16)
        return {"k": kv, "v": kv}

    def cache_axes(self, shape: ShapeConfig) -> Any:
        ax = ("layers", "batch", "cache_seq", None, None)
        return {"k": ax, "v": ax}

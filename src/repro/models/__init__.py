"""Model zoo: ``build_model(cfg) -> BaseModel`` dispatch by family."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.base import BaseModel


def build_model(cfg: ArchConfig) -> BaseModel:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import DecoderLM

        return DecoderLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import Rwkv6LM

        return Rwkv6LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.zamba import ZambaLM

        return ZambaLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


__all__ = ["BaseModel", "build_model"]

"""Attention: GQA with blockwise (flash) / naive / ring backends + KV cache.

Distribution scheme (DESIGN.md §4): heads are never sharded — Q/K/V
activations are *sequence*-sharded on the "model" mesh axis, which removes
every head-count divisibility constraint of the assigned pool (9/24/40 heads,
kv=2/3/8 on a 16-way axis). Blockwise attention keeps the O(block) memory
profile of flash attention in pure JAX so it lowers on any backend; the
Pallas TPU kernel (kernels/attention) is the hardware target for prefill and
is numerically validated against the same reference.

All functions take Q: (B, Sq, H, hd); K,V: (B, Skv, KV, hd) with H % KV == 0.
"""
from __future__ import annotations

import contextlib
import functools
import math
import threading
from typing import Literal

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# decode-kernel routing context (see decode_kernel_scope)
_DECODE_KERNEL = threading.local()


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,H,hd) -> (B,S,KV,G,hd) grouped by kv head."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_positions: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    kv_mask: jax.Array | None = None,
) -> jax.Array:
    """Reference attention; materializes full scores. Oracle + small shapes."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)  # (B,Sq,KV,G,hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None]
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1])[None]
    mask = jnp.ones((B, Sq, k.shape[1]), bool)
    if causal:
        mask &= q_positions[:, :, None] >= kv_positions[:, None, :]
    if kv_mask is not None:
        mask &= kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure JAX: online softmax over KV blocks.

    Peak memory is O(block_q * block_kv) per (batch, kv-head, group) instead
    of O(Sq * Skv). Under GSPMD with Q sequence-sharded this is the baseline
    production attention; the Pallas kernel implements the same schedule in
    VMEM on TPU.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, block_q, Skv, block_kv)
    nq, nk = Sq // block_q, Skv // block_kv
    scale = 1.0 / math.sqrt(hd)

    qg = _group(q, KV).astype(jnp.float32) * scale  # (B,Sq,KV,G,hd)
    qg = qg.reshape(B, nq, block_q, KV, H // KV, hd)
    kb = k.reshape(B, nk, block_kv, KV, hd)
    vb = v.reshape(B, nk, block_kv, KV, hd)

    q_pos = jnp.arange(Sq).reshape(nq, block_q)
    k_pos = jnp.arange(Skv).reshape(nk, block_kv)

    def q_block(args):
        qi, qp = args  # (B,bq,KV,G,hd), (bq,)

        def kv_step(carry, kv_args):
            acc, m, l = carry
            ki, vi, kp = kv_args  # (B,bkv,KV,hd), ..., (bkv,)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki.astype(jnp.float32))
            if causal:
                s = jnp.where(qp[:, None] >= kp[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
            return (acc, m_new, l), None

        G = qi.shape[3]
        acc0 = jnp.zeros((B, KV, G, qi.shape[1], hd), jnp.float32)
        m0 = jnp.full((B, KV, G, qi.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qi.shape[1]), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,KV,G,bq,hd)
        return out.transpose(0, 3, 1, 2, 4)  # (B,bq,KV,G,hd)

    out = jax.lax.map(q_block, (qg.swapaxes(0, 1), q_pos))  # (nq,B,bq,KV,G,hd)
    out = out.swapaxes(0, 1).reshape(B, Sq, H, hd)
    return out.astype(v.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    positions: jax.Array,
) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    ``q``: (B, 1, H, hd); caches: (B, S, KV, hd); ``positions``: (B,) number
    of valid cache entries per sequence (the new token attends to < pos+1).
    Softmax reductions over the sharded S dim lower to partial max/sum +
    all-reduce under GSPMD — a distributed flash-decode by construction.

    Inside a :func:`decode_kernel_scope` the same computation dispatches to
    the Pallas decode kernel (kernels/attention/decode_kernel.py) — routing
    happens at trace time, so a jitted decode step traced under the scope
    bakes the kernel in.
    """
    cfg = getattr(_DECODE_KERNEL, "cfg", None)
    if cfg is not None:
        from repro.kernels.attention.decode_kernel import decode_attention_pallas

        return decode_attention_pallas(q, k_cache, v_cache, positions, **cfg)
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    qg = _group(q, KV)[:, 0].astype(jnp.float32)  # (B,KV,G,hd) after squeeze
    qg = qg * (1.0 / math.sqrt(hd))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] <= positions[:, None]  # (B,S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(v_cache.dtype)


@contextlib.contextmanager
def decode_kernel_scope(*, block_kv: int = 128, interpret: bool | None = None):
    """Route :func:`decode_attention` through the Pallas decode kernel.

    Trace-time routing: wrap the *tracing* call (the first invocation of a
    jitted decode step) — the traced HLO then contains the kernel for the
    life of that compilation. ``interpret=None`` resolves to interpret mode
    off-TPU (the correct-but-slow fallback), native on TPU.
    """
    if interpret is None:
        from repro.streaming.dispatch import kernel_interpret

        interpret = kernel_interpret()
    prev = getattr(_DECODE_KERNEL, "cfg", None)
    _DECODE_KERNEL.cfg = {"block_kv": int(block_kv), "interpret": bool(interpret)}
    try:
        yield
    finally:
        _DECODE_KERNEL.cfg = prev


def update_cache(
    cache: jax.Array, new: jax.Array, positions: jax.Array
) -> jax.Array:
    """Write ``new`` (B,1,KV,hd) into ``cache`` (B,S,KV,hd) at per-seq ``positions``.

    Implemented as a scatter (per-sequence write offsets -> continuous
    batching); lowers to a guarded local update per shard when S is sharded.
    """
    B = cache.shape[0]
    return cache.at[jnp.arange(B), positions].set(new[:, 0].astype(cache.dtype))


ATTENTION_IMPLS = {
    "naive": naive_attention,
    "blockwise": blockwise_attention,
}


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    impl: str = "blockwise",
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jax.Array:
    # Under an activation_rules context with a real "model" axis, train/prefill
    # attention runs sequence-parallel via shard_map (see
    # runtime/sharded_attention.py for why GSPMD alone can't do this well).
    from repro.runtime.sharding import _CTX  # lazy to avoid cycle

    rules = getattr(_CTX, "rules", None)
    if rules is not None and rules.mesh.shape.get("model", 1) > 1:
        n_model = rules.mesh.shape["model"]
        if q.shape[1] % n_model == 0 and k.shape[1] % n_model == 0 and q.shape[1] > 1:
            from repro.runtime.sharded_attention import sharded_attention

            shard_impl = {"ring": "ring", "flash": "flash"}.get(impl, "allgather")
            return sharded_attention(
                q, k, v, rules, causal=causal, block_kv=block_kv, impl=shard_impl
            )
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal)
    if impl in ("blockwise", "ring", "flash"):
        return blockwise_attention(q, k, v, causal=causal, block_q=block_q, block_kv=block_kv)
    raise ValueError(f"unknown attention impl {impl!r}")

"""Encoder-decoder transformer (seamless-m4t backbone).

The speech frontend is a stub: the encoder consumes precomputed frame
embeddings (B, S_enc, d) from ``input_specs()``. The decoder is a standard
causal transformer with cross-attention into the encoder memory; decode
carries a self-attention KV cache plus a static cross-attention cache
computed once at prefill.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention as attn_lib
from repro.models.base import BaseModel
from repro.models.common import embed_lookup, ParamSpec, chunked_cross_entropy, rms_norm, shift_targets
from repro.models.ffn import mlp_apply, mlp_specs
from repro.models.transformer import attn_block_apply, attn_block_decode, attn_block_specs


def _cross_attn_specs(cfg: ArchConfig, L: int) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "xattn_norm": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
        "wq_x": ParamSpec((L, d, H * hd), ("layers", "embed", "heads"), dt),
        "wkv_x": ParamSpec((L, d, 2 * KV * hd), ("layers", "embed", "kv"), dt),
        "wo_x": ParamSpec((L, H * hd, d), ("layers", "heads", "embed"), dt),
    }


class EncDecLM(BaseModel):
    def param_specs(self) -> dict:
        cfg = self.cfg
        d, dt = cfg.d_model, self.param_dtype
        Le, Ld = cfg.n_enc_layers, cfg.n_layers
        enc_layers = {
            "attn_norm": ParamSpec((Le, d), ("layers", "embed"), jnp.float32, init="ones"),
            "mlp_norm": ParamSpec((Le, d), ("layers", "embed"), jnp.float32, init="ones"),
            **attn_block_specs(cfg, Le),
            **mlp_specs(d, cfg.d_ff, Le, dt),
        }
        dec_layers = {
            "attn_norm": ParamSpec((Ld, d), ("layers", "embed"), jnp.float32, init="ones"),
            "mlp_norm": ParamSpec((Ld, d), ("layers", "embed"), jnp.float32, init="ones"),
            **attn_block_specs(cfg, Ld),
            **_cross_attn_specs(cfg, Ld),
            **mlp_specs(d, cfg.d_ff, Ld, dt),
        }
        return {
            "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed"), dt, init="normal"),
            "frame_proj": ParamSpec((d, d), ("embed", None), dt),
            "enc_final_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            "final_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            "lm_head": ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"), dt),
            "encoder": enc_layers,
            "decoder": dec_layers,
        }

    # ---- encoder -----------------------------------------------------------

    def _encode(self, params, frame_embeds):
        cfg = self.cfg
        cd = self.compute_dtype
        x = frame_embeds.astype(cd) @ params["frame_proj"].astype(cd)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def layer(x, lp):
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, _ = attn_block_apply(cfg, lp, h, positions=positions, compute_dtype=cd, causal=False)
            x = x + a
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            return x + mlp_apply(lp, h, cd), None

        if cfg.remat != "none":
            layer = jax.checkpoint(layer, prevent_cse=False)
        x, _ = jax.lax.scan(layer, x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    # ---- decoder -----------------------------------------------------------

    def _cross_kv(self, lp, memory):
        cd = self.compute_dtype
        cfg = self.cfg
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        kv = memory.astype(cd) @ lp["wkv_x"].astype(cd)
        B, S = memory.shape[:2]
        k, v = jnp.split(kv, 2, axis=-1)
        return k.reshape(B, S, KV, hd), v.reshape(B, S, KV, hd)

    def _cross_attend(self, lp, x, k_mem, v_mem):
        cd = self.compute_dtype
        cfg = self.cfg
        H, hd = cfg.n_heads, cfg.resolved_head_dim
        B, S = x.shape[:2]
        q = (x.astype(cd) @ lp["wq_x"].astype(cd)).reshape(B, S, H, hd)
        out = attn_lib.attention(q, k_mem, v_mem, impl="blockwise" if S > 1 else "naive", causal=False)
        return out.reshape(B, S, H * hd) @ lp["wo_x"].astype(cd)

    def _decode_stack(self, params, x, memory, *, positions, collect_cache):
        cfg = self.cfg
        cd = self.compute_dtype

        def layer(carry, lp):
            x, = carry
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, kv = attn_block_apply(cfg, lp, h, positions=positions, compute_dtype=cd)
            x = x + a
            h = rms_norm(x, lp["xattn_norm"], cfg.norm_eps)
            k_mem, v_mem = self._cross_kv(lp, memory)
            x = x + self._cross_attend(lp, h, k_mem, v_mem)
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            x = x + mlp_apply(lp, h, cd)
            ys = (kv, (k_mem, v_mem)) if collect_cache else None
            return (x,), ys

        if cfg.remat != "none":
            layer = jax.checkpoint(layer, prevent_cse=False)
        (x,), caches = jax.lax.scan(layer, (x,), params["decoder"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps), caches

    # ---- public API ----------------------------------------------------------

    def loss(self, params, batch):
        cfg = self.cfg
        memory = self._encode(params, batch["frame_embeds"])
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens).astype(self.compute_dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._decode_stack(params, x, memory, positions=positions, collect_cache=False)
        targets, mask = shift_targets(tokens, batch.get("mask"))
        tot, cnt = chunked_cross_entropy(x, params["lm_head"].T, targets, mask, vocab_size=cfg.vocab_size)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"ce_loss": loss, "tokens": cnt}

    def prefill(self, params, batch):
        memory = self._encode(params, batch["frame_embeds"])
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens).astype(self.compute_dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, caches = self._decode_stack(params, x, memory, positions=positions, collect_cache=True)
        (k, v), (k_mem, v_mem) = caches
        logits = x[:, -1:].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logits, {"k": k, "v": v, "k_mem": k_mem, "v_mem": v_mem}

    def decode(self, params, cache, batch):
        cfg = self.cfg
        cd = self.compute_dtype
        positions = batch["positions"]
        x = embed_lookup(params["embed"], batch["tokens"]).astype(cd)

        def layer(carry, inp):
            x, = carry
            lp, k_c, v_c, k_mem, v_mem = inp
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            a, (k_c, v_c) = attn_block_decode(cfg, lp, h, k_c, v_c, positions=positions, compute_dtype=cd)
            x = x + a
            h = rms_norm(x, lp["xattn_norm"], cfg.norm_eps)
            x = x + self._cross_attend(lp, h, k_mem, v_mem)
            h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            x = x + mlp_apply(lp, h, cd)
            return (x,), (k_c, v_c)

        (x,), (k, v) = jax.lax.scan(
            layer, (x,), (params["decoder"], cache["k"], cache["v"], cache["k_mem"], cache["v_mem"])
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logits, {"k": k, "v": v, "k_mem": cache["k_mem"], "v_mem": cache["v_mem"]}

    # ---- dry-run structs -------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
            }
        half = S // 2
        return {
            "frame_embeds": jax.ShapeDtypeStruct((B, half, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, half), jnp.int32),
        }

    def input_axes(self, shape: ShapeConfig) -> dict:
        if shape.kind == "decode":
            return {"tokens": ("batch", None), "positions": ("batch",)}
        return {"frame_embeds": ("batch", "seq", None), "tokens": ("batch", "seq")}

    def cache_struct(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        half = S // 2
        kv = jax.ShapeDtypeStruct((cfg.n_layers, B, half, cfg.n_kv_heads, cfg.resolved_head_dim), jnp.bfloat16)
        return {"k": kv, "v": kv, "k_mem": kv, "v_mem": kv}

    def cache_axes(self, shape: ShapeConfig):
        ax = ("layers", "batch", "cache_seq", None, None)
        return {"k": ax, "v": ax, "k_mem": ax, "v_mem": ax}

"""Shared model building blocks: param specs, norms, RoPE, embeddings, loss.

Parameters are plain nested dicts of arrays. Every leaf is declared through a
:class:`ParamSpec` carrying *logical axis names*; ``runtime.sharding`` maps
those names onto mesh axes. The same spec tree serves real initialization
(smoke tests, examples) and allocation-free ``ShapeDtypeStruct`` trees
(dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple  # tuple[str | None, ...]


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: Axes  # logical axis name per dim (None = replicated dim)
    dtype: Any = jnp.float32
    init: str = "fan_in"  # "fan_in" | "normal" | "zeros" | "ones" | "small"
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def initialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            std = 0.02 * self.scale
        elif self.init == "small":
            std = 1e-3 * self.scale
        else:  # fan_in
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale / math.sqrt(max(fan_in, 1))
        x = jax.random.normal(key, self.shape, jnp.float32) * std
        return x.astype(self.dtype)


SpecTree = Any  # nested dict[str, ParamSpec]


def spec_struct(specs: SpecTree) -> Any:
    return jax.tree.map(lambda s: s.struct(), specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_axes(specs: SpecTree) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(specs: SpecTree, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [s.initialize(k) for s, k in zip(leaves, keys)])


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm(x: jax.Array, n_groups: int, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the last dim split into ``n_groups`` (RWKV wkv output)."""
    dt = x.dtype
    *lead, d = x.shape
    g = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.mean((g - mu) ** 2, axis=-1, keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    x = g.reshape(*lead, d)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings (GPT-NeoX half-rotation convention)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, rope_pct: float = 1.0) -> jax.Array:
    """Inverse frequencies for the rotated fraction of the head dim."""
    rot = int(head_dim * rope_pct) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, rope_pct: float = 1.0) -> jax.Array:
    """``x``: (..., seq, heads, head_dim); ``positions``: broadcastable (..., seq)."""
    dt = x.dtype
    hd = x.shape[-1]
    rot = int(hd * rope_pct) // 2 * 2
    inv = rope_frequencies(hd, theta, rope_pct)  # (rot/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (..., seq, 1, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xr, xp = x[..., :rot].astype(jnp.float32), x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(dt), xp], axis=-1) if rot < hd else rotated.astype(dt)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    x: jax.Array,
    embedding: jax.Array,
    targets: jax.Array,
    mask: jax.Array,
    *,
    vocab_size: int,
    chunk: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    """Next-token CE without materializing full (B,S,V) logits.

    ``x``: (B,S,D) final hidden states; ``embedding``: (V_pad, D) output head;
    ``targets``: (B,S) int32; ``mask``: (B,S) {0,1}. Scans over sequence
    chunks so peak logits memory is (B, chunk, V) regardless of sharding.
    Returns (sum_loss, sum_mask).
    """
    # sequence-parallel path: Megatron-style vocab-parallel CE via shard_map
    from repro.runtime.sharding import _CTX  # lazy to avoid import cycle

    rules = getattr(_CTX, "rules", None)
    if rules is not None and rules.mesh.shape.get("model", 1) > 1:
        from repro.runtime.losses import vocab_parallel_cross_entropy

        return vocab_parallel_cross_entropy(
            x, embedding, targets, mask.astype(jnp.float32), rules, chunk=chunk
        )

    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)  # (n,B,c,D)
    ts = targets.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk).swapaxes(0, 1)

    emb = embedding

    def step(carry, inp):
        xc, tc, mc = inp
        logits = (xc @ emb.T.astype(xc.dtype)).astype(jnp.float32)  # (B,c,Vp)
        # padded vocab entries never appear as targets; logsumexp over the
        # padded tail is harmless (their logits train toward -inf).
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ts, ms))
    return tot, cnt


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """Embedding lookup, vocab-parallel under an activation_rules context
    (plain ``embed[tokens]`` makes GSPMD all-gather the full table)."""
    from repro.runtime.sharding import _CTX  # lazy to avoid import cycle

    rules = getattr(_CTX, "rules", None)
    if (
        rules is not None
        and rules.mesh.shape.get("model", 1) > 1
        and tokens.ndim == 2
        and embed.shape[0] % rules.mesh.shape["model"] == 0
    ):
        from repro.runtime.losses import vocab_parallel_embed

        return vocab_parallel_embed(tokens, embed, rules)
    return embed[tokens]


def shift_targets(tokens: jax.Array, mask: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Standard LM shift: predict token t+1 at position t."""
    targets = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    m = jnp.ones_like(tokens, dtype=jnp.float32)
    if mask is not None:
        m = m * mask.astype(jnp.float32)
    m = m.at[:, -1].set(0.0)
    return targets, m

"""Mamba2 block (SSD — state-space duality, chunked matmul form).

Recurrence per head (state S in R^{headdim x d_state}):
    S_t = exp(dt_t * A) S_{t-1} + (dt_t x_t) B_t^T
    y_t = S_t C_t + D x_t
``ssd_chunked`` is the matmul-heavy chunked algorithm of the Mamba2 paper
(intra-chunk (C,C) scalar decay masks -> MXU-friendly); ``ssd_recurrent`` is
the token-level oracle used for decode and tests.

The depthwise causal conv (width 4) is implemented as explicit shifts + MACs
(elementwise; avoids conv ops so the HLO cost model stays dot-only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm


def conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None):
    """Depthwise causal conv. ``x``: (B,T,Ch); ``w``: (K,Ch); ``state``: (B,K-1,Ch)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, T+K-1, Ch)
    T = x.shape[1]
    out = sum(xp[:, i : i + T] * w[i][None, None] for i in range(K)) + b[None, None]
    new_state = xp[:, -(K - 1) :]
    return jax.nn.silu(out), new_state


def ssd_recurrent(x, dt, A, B, C, D, state):
    """Oracle/decode SSD.

    x: (Bt,T,H,P); dt: (Bt,T,H); A: (H,) negative; B,C: (Bt,T,G,N) with G=1;
    D: (H,); state: (Bt,H,P,N). Returns (y, state).
    """

    def step(S, inp):
        x_t, dt_t, B_t, C_t = inp  # (Bt,H,P), (Bt,H), (Bt,G,N), (Bt,G,N)
        decay = jnp.exp(dt_t * A[None])  # (Bt,H)
        dBx = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t[:, 0])
        S = decay[..., None, None] * S + dBx
        y = jnp.einsum("bhpn,bn->bhp", S, C_t[:, 0]) + D[None, :, None] * x_t
        return S, y

    xs = x.swapaxes(0, 1)
    dts = dt.swapaxes(0, 1)
    Bs = B.swapaxes(0, 1)
    Cs = C.swapaxes(0, 1)
    state, ys = jax.lax.scan(step, state, (xs, dts, Bs, Cs))
    return ys.swapaxes(0, 1), state


def ssd_chunked(x, dt, A, B, C, D, state, *, chunk: int = 64, checkpoint_chunks: bool = False):
    """Chunked SSD (Mamba2 paper alg.); same semantics as ``ssd_recurrent``.
    ``checkpoint_chunks`` remats chunk bodies (backward recomputes the (C,C)
    decay masks instead of saving them)."""
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    Cn = min(chunk, T)
    assert T % Cn == 0, (T, Cn)
    n = T // Cn

    xc = x.reshape(Bt, n, Cn, H, P).transpose(1, 0, 3, 2, 4)  # (n,Bt,H,C,P)
    dtc = dt.reshape(Bt, n, Cn, H).transpose(1, 0, 3, 2)  # (n,Bt,H,C)
    Bc = B[:, :, 0].reshape(Bt, n, Cn, N).transpose(1, 0, 2, 3)  # (n,Bt,C,N)
    Cc = C[:, :, 0].reshape(Bt, n, Cn, N).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((Cn, Cn), bool))  # a <= t

    def chunk_step(S, inp):
        x_i, dt_i, B_i, C_i = inp
        dA = dt_i * A[None, :, None]  # (Bt,H,C), <= 0
        cum = jnp.cumsum(dA, axis=-1)  # inclusive
        # intra: scores[t,a] = exp(cum_t - cum_a) * (C_t . B_a) * dt_a,  a <= t
        L = jnp.exp(jnp.clip(cum[..., :, None] - cum[..., None, :], -60.0, 0.0))
        L = jnp.where(tri[None, None], L, 0.0)
        CB = jnp.einsum("btn,ban->bta", C_i, B_i)  # (Bt,C,C)
        scores = CB[:, None] * L * dt_i[..., None, :]  # (Bt,H,C,C)
        y = jnp.einsum("bhta,bhap->bhtp", scores, x_i)
        # inter: y += (C_t exp(cum_t)) . S
        y = y + jnp.einsum("btn,bht,bhpn->bhtp", C_i, jnp.exp(cum), S)
        # state update
        last = cum[..., -1:]  # (Bt,H,1)
        w = jnp.exp(jnp.clip(last - cum, -60.0, 0.0)) * dt_i  # (Bt,H,C)
        dBx = jnp.einsum("bhtp,bht,btn->bhpn", x_i, w, B_i)
        S = jnp.exp(last[..., 0])[..., None, None] * S + dBx
        return S, y

    step = jax.checkpoint(chunk_step, prevent_cse=False) if checkpoint_chunks else chunk_step
    state, ys = jax.lax.scan(step, state, (xc, dtc, Bc, Cc))
    ys = ys.transpose(1, 0, 3, 2, 4).reshape(Bt, T, H, P)
    return ys + D[None, None, :, None] * x, state


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def mamba_specs(cfg, n_layers: int) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    conv_ch = di + 2 * ds  # x, B, C  (ngroups=1)
    dt = jnp.dtype(cfg.param_dtype)
    L = n_layers
    return {
        "norm": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
        "w_in": ParamSpec((L, d, 2 * di + 2 * ds + H), ("layers", "embed", "mlp"), dt),
        "conv_w": ParamSpec((L, cfg.conv_width, conv_ch), ("layers", None, "mlp"), jnp.float32),
        "conv_b": ParamSpec((L, conv_ch), ("layers", "mlp"), jnp.float32, init="zeros"),
        "A_log": ParamSpec((L, H), ("layers", None), jnp.float32, init="small"),
        "D": ParamSpec((L, H), ("layers", None), jnp.float32, init="ones"),
        "dt_bias": ParamSpec((L, H), ("layers", None), jnp.float32, init="small"),
        "ssd_norm": ParamSpec((L, di), ("layers", "mlp"), jnp.float32, init="ones"),
        "w_out": ParamSpec((L, di, d), ("layers", "mlp", "embed"), dt),
    }


def mamba_state_struct(cfg, n_layers: int, batch: int) -> dict:
    di, ds = cfg.d_inner, cfg.ssm_state
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * ds
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, cfg.conv_width - 1, conv_ch), jnp.dtype(cfg.compute_dtype)),
        "ssd": jax.ShapeDtypeStruct((n_layers, batch, H, P, ds), jnp.float32),
    }


def mamba_state_axes() -> dict:
    return {
        "conv": ("layers", "batch", None, "mlp"),
        "ssd": ("layers", "batch", None, None, None),
    }


def mamba_apply(cfg, lp: dict, x: jax.Array, state: dict | None, *, compute_dtype, chunked: bool):
    """One Mamba2 block. ``x``: (B,T,d). Returns (out, new_state)."""
    cd = compute_dtype
    di, ds = cfg.d_inner, cfg.ssm_state
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    B_, T, _ = x.shape

    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    zxbcdt = h.astype(cd) @ lp["w_in"].astype(cd)
    z, xs, Bm, Cm, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = None if state is None else state["conv"]

    from repro.runtime.sharding import _CTX

    rules = getattr(_CTX, "rules", None)
    if (
        state is None
        and rules is not None
        and rules.mesh.shape.get("model", 1) > 1
        and T % rules.mesh.shape["model"] == 0
        and T > 1
    ):
        from repro.runtime.sequence_parallel import conv1d_sharded

        conv_out = conv1d_sharded(conv_in, lp["conv_w"].astype(cd), lp["conv_b"].astype(cd), rules)
        new_conv = conv_in[:, -(cfg.conv_width - 1) :]
    else:
        conv_out, new_conv = conv1d_causal(conv_in, lp["conv_w"].astype(cd), lp["conv_b"].astype(cd), conv_state)
    xs, Bm, Cm = jnp.split(conv_out, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None])  # (B,T,H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))  # (H,)
    xh = xs.reshape(B_, T, H, P).astype(jnp.float32)
    Bg = Bm[:, :, None, :].astype(jnp.float32)  # (B,T,1,N)
    Cg = Cm[:, :, None, :].astype(jnp.float32)
    S0 = jnp.zeros((B_, H, P, ds), jnp.float32) if state is None else state["ssd"]
    # sequence-parallel core when activations are seq-sharded (DESIGN.md §4)
    from repro.runtime.sharding import _CTX

    rules = getattr(_CTX, "rules", None)
    if (
        chunked
        and state is None
        and rules is not None
        and rules.mesh.shape.get("model", 1) > 1
        and T % rules.mesh.shape["model"] == 0
        and T > 1
    ):
        from repro.runtime.sequence_parallel import ssd_sharded

        y, new_ssd = ssd_sharded(xh, dt, A, Bg, Cg, lp["D"].astype(jnp.float32), rules)
    else:
        fn = ssd_chunked if chunked else ssd_recurrent
        y, new_ssd = fn(xh, dt, A, Bg, Cg, lp["D"].astype(jnp.float32), S0)
    y = y.reshape(B_, T, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cd), lp["ssd_norm"], cfg.norm_eps)
    out = y @ lp["w_out"].astype(cd)
    new_state = {"conv": new_conv.astype(cd), "ssd": new_ssd}
    return out, new_state

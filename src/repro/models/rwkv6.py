"""RWKV6 ("Finch") — attention-free LM with data-dependent per-channel decay.

Two numerically-equivalent WKV6 implementations:

* ``wkv6_recurrent`` — token-by-token ``lax.scan`` (decode path + test oracle)
* ``wkv6_chunked``  — chunked-parallel form used for train/prefill. All decay
  exponents are differences of within-chunk cumulative log-decays and hence
  <= 0 (no overflow); the intra-chunk score needs a per-channel decay factor
  so it is a 3-operand einsum (VPU work; the channel-mix matmuls dominate
  FLOPs by ~300x, see DESIGN.md).

State per layer/head: S in R^{N x N} (key-dim x value-dim):
    o_t = r_t^T (S_{t-1} + u . k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.base import BaseModel
from repro.models.common import (
    embed_lookup,
    ParamSpec,
    chunked_cross_entropy,
    group_norm,
    rms_norm,
    shift_targets,
)

MIX_LORA = 32  # ddlerp lora rank (5 heads)
DECAY_LORA = 64


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------


def wkv6_recurrent(r, k, v, w, u, state):
    """Oracle/decode WKV. r,k,v,w: (B,H,T,N); u: (H,N); state: (B,H,N,N)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,N)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,N,N)
        o = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    rs, ks, vs, ws = (x.swapaxes(0, 2).swapaxes(1, 2) for x in (r, k, v, w))  # (T,B,H,N)
    state, outs = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return outs.transpose(1, 2, 0, 3), state  # (B,H,T,N)


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 32, checkpoint_chunks: bool = False):
    """Chunked-parallel WKV. Same signature/semantics as ``wkv6_recurrent``.
    ``checkpoint_chunks`` remats each chunk step so backward recomputes the
    (C,C,N) decay tensors instead of saving them (train path)."""
    B, H, T, N = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n = T // C

    def to_chunks(x):
        return x.reshape(B, H, n, C, N).transpose(2, 0, 1, 3, 4)  # (n,B,H,C,N)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = jnp.log(jnp.maximum(to_chunks(w), 1e-38))  # (n,B,H,C,N), <= 0
    clog = jnp.cumsum(lw, axis=-2)  # inclusive cumulative log decay
    cprev = clog - lw  # exclusive

    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)  # strictly lower: a < t

    def chunk_step(S, inp):
        r_i, k_i, v_i, clog_i, cprev_i = inp
        # intra-chunk: scores[t,a] = sum_i r[t,i] k[a,i] exp(cprev[t,i]-clog[a,i])
        decay = jnp.exp(
            jnp.clip(cprev_i[..., :, None, :] - clog_i[..., None, :, :], -60.0, 0.0)
        )  # (B,H,C,C,N)
        decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
        # diagonal bonus term u
        scores = jnp.einsum("bhti,bhai,bhtai->bhta", r_i, k_i, decay)
        diag = jnp.einsum("bhti,hi->bht", r_i * k_i, u)
        o = jnp.einsum("bhta,bhaj->bhtj", scores, v_i) + diag[..., None] * v_i
        # inter-chunk: carry-in state
        o = o + jnp.einsum("bhti,bhij->bhtj", r_i * jnp.exp(cprev_i), S)
        # state update
        last = clog_i[..., -1:, :]  # (B,H,1,N)
        k_hat = k_i * jnp.exp(last - clog_i)
        S = jnp.exp(last[..., 0, :])[..., :, None] * S + jnp.einsum(
            "bhai,bhaj->bhij", k_hat, v_i
        )
        return S, o

    step = jax.checkpoint(chunk_step, prevent_cse=False) if checkpoint_chunks else chunk_step
    state, outs = jax.lax.scan(step, state, (rc, kc, vc, clog, cprev))
    outs = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, N)
    return outs, state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


class Rwkv6LM(BaseModel):
    def param_specs(self) -> dict:
        cfg = self.cfg
        d, L = cfg.d_model, cfg.n_layers
        H, N = cfg.n_rwkv_heads, cfg.rwkv_head_dim
        dt = self.param_dtype
        layers = {
            "ln1": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
            "ln2": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="ones"),
            # time-mix ddlerp
            "tm_mix_x": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="small"),
            "tm_mix": ParamSpec((L, 5, d), ("layers", None, "embed"), jnp.float32, init="small"),
            "tm_lora_a": ParamSpec((L, d, 5 * MIX_LORA), ("layers", "embed", None), dt),
            "tm_lora_b": ParamSpec((L, 5, MIX_LORA, d), ("layers", None, None, "embed"), dt, init="small"),
            # projections (fused dims shard on "heads")
            "w_r": ParamSpec((L, d, H * N), ("layers", "embed", "heads"), dt),
            "w_k": ParamSpec((L, d, H * N), ("layers", "embed", "heads"), dt),
            "w_v": ParamSpec((L, d, H * N), ("layers", "embed", "heads"), dt),
            "w_g": ParamSpec((L, d, H * N), ("layers", "embed", "heads"), dt),
            "w_o": ParamSpec((L, H * N, d), ("layers", "heads", "embed"), dt),
            # data-dependent decay
            "decay_base": ParamSpec((L, H * N), ("layers", "heads"), jnp.float32, init="small"),
            "decay_lora_a": ParamSpec((L, d, DECAY_LORA), ("layers", "embed", None), dt),
            "decay_lora_b": ParamSpec((L, DECAY_LORA, H * N), ("layers", None, "heads"), dt, init="small"),
            "u_bonus": ParamSpec((L, H, N), ("layers", None, None), jnp.float32, init="small"),
            "wkv_norm_scale": ParamSpec((L, H * N), ("layers", "heads"), jnp.float32, init="ones"),
            "wkv_norm_bias": ParamSpec((L, H * N), ("layers", "heads"), jnp.float32, init="zeros"),
            # channel-mix
            "cm_mix_k": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="small"),
            "cm_mix_r": ParamSpec((L, d), ("layers", "embed"), jnp.float32, init="small"),
            "cm_k": ParamSpec((L, d, cfg.d_ff), ("layers", "embed", "mlp"), dt),
            "cm_v": ParamSpec((L, cfg.d_ff, d), ("layers", "mlp", "embed"), dt),
            "cm_r": ParamSpec((L, d, d), ("layers", "embed", None), dt),
        }
        return {
            "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed"), dt, init="normal"),
            "final_norm": ParamSpec((d,), ("embed",), jnp.float32, init="ones"),
            "lm_head": ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"), dt),
            "layers": layers,
        }

    # ---- layer pieces ------------------------------------------------------

    def _time_mix(self, lp, x, shift_state, wkv_state, *, chunked: bool):
        cfg = self.cfg
        cd = self.compute_dtype
        H, N = cfg.n_rwkv_heads, cfg.rwkv_head_dim
        B, T, d = x.shape
        prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
        xx = prev - x
        base = x + xx * lp["tm_mix_x"].astype(x.dtype)
        s = jnp.tanh(base.astype(cd) @ lp["tm_lora_a"].astype(cd))
        s = s.reshape(B, T, 5, MIX_LORA)
        delta = jnp.einsum("btfr,frd->btfd", s, lp["tm_lora_b"].astype(cd))  # (B,T,5,d)
        mix = lp["tm_mix"].astype(cd)[None, None] + delta  # (B,T,5,d)
        xw, xk, xv, xr, xg = [
            (x + xx * mix[:, :, i]).astype(cd) for i in range(5)
        ]
        r = (xr @ lp["w_r"].astype(cd)).reshape(B, T, H, N)
        k = (xk @ lp["w_k"].astype(cd)).reshape(B, T, H, N)
        v = (xv @ lp["w_v"].astype(cd)).reshape(B, T, H, N)
        g = jax.nn.silu(xg @ lp["w_g"].astype(cd))
        dlogit = lp["decay_base"].astype(jnp.float32) + (
            jnp.tanh(xw @ lp["decay_lora_a"].astype(cd)) @ lp["decay_lora_b"].astype(cd)
        ).astype(jnp.float32)
        w = jnp.exp(-jnp.exp(dlogit.reshape(B, T, H, N)))  # (0,1) per channel

        to_bhtn = lambda a: a.transpose(0, 2, 1, 3).astype(jnp.float32)
        u = lp["u_bonus"].astype(jnp.float32)
        # sequence-parallel core when the activations are seq-sharded (a
        # chunk scan over a sharded dim would serialize across shards)
        from repro.runtime.sharding import _CTX

        rules = getattr(_CTX, "rules", None)
        if (
            chunked
            and rules is not None
            and rules.mesh.shape.get("model", 1) > 1
            and T % rules.mesh.shape["model"] == 0
            and T > 1
        ):
            from repro.runtime.sequence_parallel import wkv6_sharded

            o, wkv_state = wkv6_sharded(
                to_bhtn(r), to_bhtn(k), to_bhtn(v), to_bhtn(w), u, rules
            )
        else:
            fn = wkv6_chunked if chunked else wkv6_recurrent
            o, wkv_state = fn(to_bhtn(r), to_bhtn(k), to_bhtn(v), to_bhtn(w), u, wkv_state)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, H * N)
        o = group_norm(o, H, lp["wkv_norm_scale"], lp["wkv_norm_bias"], 64e-5)
        out = (o.astype(cd) * g) @ lp["w_o"].astype(cd)
        return out, x[:, -1], wkv_state

    def _channel_mix(self, lp, x, shift_state):
        cd = self.compute_dtype
        prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
        xx = prev - x
        xk = (x + xx * lp["cm_mix_k"].astype(x.dtype)).astype(cd)
        xr = (x + xx * lp["cm_mix_r"].astype(x.dtype)).astype(cd)
        kk = jnp.square(jax.nn.relu(xk @ lp["cm_k"].astype(cd)))
        out = jax.nn.sigmoid(xr @ lp["cm_r"].astype(cd)) * (kk @ lp["cm_v"].astype(cd))
        return out, x[:, -1]

    # ---- forward -----------------------------------------------------------

    def _layer_fn(self, chunked: bool, collect_state: bool):
        cfg = self.cfg

        def layer(x, lp, states=None):
            B = x.shape[0]
            H, N = cfg.n_rwkv_heads, cfg.rwkv_head_dim
            if states is None:
                tm_shift = jnp.zeros((B, cfg.d_model), x.dtype)
                cm_shift = jnp.zeros((B, cfg.d_model), x.dtype)
                wkv = jnp.zeros((B, H, N, N), jnp.float32)
            else:
                tm_shift, cm_shift, wkv = states["tm_shift"], states["cm_shift"], states["wkv"]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, tm_shift, wkv = self._time_mix(lp, h, tm_shift, wkv, chunked=chunked)
            x = x + a
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            m, cm_shift = self._channel_mix(lp, h, cm_shift)
            x = x + m
            new_states = {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}
            return x, new_states

        return layer

    def _forward(self, params, tokens, *, collect_state: bool):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens).astype(self.compute_dtype)
        layer = self._layer_fn(chunked=True, collect_state=collect_state)

        def body(x, lp):
            x, states = layer(x, lp)
            return x, states if collect_state else None

        if cfg.remat != "none":
            policy = None if cfg.remat == "full" else jax.checkpoint_policies.checkpoint_dots
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)
        x, states = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, states

    # ---- public API ----------------------------------------------------------

    def loss(self, params, batch):
        tokens = batch["tokens"]
        x, _ = self._forward(params, tokens, collect_state=False)
        targets, mask = shift_targets(tokens, batch.get("mask"))
        tot, cnt = chunked_cross_entropy(x, params["lm_head"].T, targets, mask, vocab_size=self.cfg.vocab_size)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"ce_loss": loss, "tokens": cnt}

    def prefill(self, params, batch):
        x, states = self._forward(params, batch["tokens"], collect_state=True)
        logits = x[:, -1:].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logits, states

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"]).astype(self.compute_dtype)  # (B,1,d)
        layer = self._layer_fn(chunked=False, collect_state=True)

        def body(x, inp):
            lp, states = inp
            x, new_states = layer(x, lp, states)
            return x, new_states

        x, states = jax.lax.scan(body, x, (params["layers"], cache))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logits, states

    # ---- dry-run structs ------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def input_axes(self, shape: ShapeConfig) -> dict:
        if shape.kind == "decode":
            return {"tokens": ("batch", None), "positions": ("batch",)}
        return {"tokens": ("batch", "seq")}

    def cache_struct(self, shape: ShapeConfig):
        cfg = self.cfg
        B, L = shape.global_batch, cfg.n_layers
        H, N = cfg.n_rwkv_heads, cfg.rwkv_head_dim
        return {
            "tm_shift": jax.ShapeDtypeStruct((L, B, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
            "cm_shift": jax.ShapeDtypeStruct((L, B, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
            "wkv": jax.ShapeDtypeStruct((L, B, H, N, N), jnp.float32),
        }

    def cache_axes(self, shape: ShapeConfig):
        return {
            "tm_shift": ("layers", "batch", "embed"),
            "cm_shift": ("layers", "batch", "embed"),
            "wkv": ("layers", "batch", None, None, None),
        }

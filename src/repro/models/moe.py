"""Mixture-of-Experts layer: top-k routing with grouped dense dispatch.

GSPMD-friendly formulation (GShard/Switch style): tokens are reshaped into
(groups, group_size); routing produces a dispatch one-hot
(groups, group_size, experts, capacity) and a combine tensor of the same
shape, so dispatch/return are einsums that lower to all-to-alls when the
expert dim is sharded on "model" and groups on ("pod","data").

Capacity dropping is the standard trade-off: tokens routed beyond
``capacity = group_size * top_k / n_experts * capacity_factor`` fall through
on the residual path. The auxiliary load-balance loss (Switch §2.2) keeps
drop rates low.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def moe_specs(cfg, n_layers: int | None) -> dict:
    lead = () if n_layers is None else (n_layers,)
    lax = () if n_layers is None else ("layers",)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    specs = {
        "router": ParamSpec(lead + (d, e), lax + ("embed", None), jnp.float32, init="small"),
        "w_gate": ParamSpec(lead + (e, d, f), lax + ("experts", "embed", "mlp"), dt),
        "w_up": ParamSpec(lead + (e, d, f), lax + ("experts", "embed", "mlp"), dt),
        "w_down": ParamSpec(lead + (e, f, d), lax + ("experts", "mlp", "embed"), dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        specs.update(
            shared_gate=ParamSpec(lead + (d, fs), lax + ("embed", "mlp"), dt),
            shared_up=ParamSpec(lead + (d, fs), lax + ("embed", "mlp"), dt),
            shared_down=ParamSpec(lead + (fs, d), lax + ("mlp", "embed"), dt),
        )
    return specs


def moe_capacity(group_size: int, top_k: int, n_experts: int, capacity_factor: float) -> int:
    c = int(math.ceil(group_size * top_k / n_experts * capacity_factor))
    return max(c, 4)


def moe_apply(p: dict, x: jax.Array, cfg, compute_dtype) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN. ``x``: (B, S, d). Returns (y, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    gs = min(cfg.moe_group_size, T)
    while T % gs:  # largest divisor of T (decode windows are small/ragged)
        gs -= 1
    G = T // gs
    C = moe_capacity(gs, K, E, cfg.capacity_factor)

    xt = x.reshape(G, gs, d)
    # router matmul in compute dtype: an f32 cast of xt here would make the
    # *entire* upstream cotangent chain f32 (2x grad memory, measured on the
    # 1T config); softmax still runs in f32
    logits = (xt.astype(compute_dtype) @ p["router"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection, one expert at a time (keeps masks small and static)
    gates = jnp.zeros((G, gs, E), jnp.float32)
    masked = probs
    for _ in range(K):
        idx = jnp.argmax(masked, axis=-1)  # (G,gs)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
        gates = gates + onehot * probs
        masked = masked * (1.0 - onehot)
    # renormalize combined gate weights over selected experts
    denom = jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = gates / denom

    # capacity assignment: position of each token in its expert's buffer
    sel = (gates > 0).astype(jnp.float32)  # (G,gs,E)
    pos_in_expert = jnp.cumsum(sel, axis=1) * sel - 1.0  # (G,gs,E), -1 if unrouted
    keep = (pos_in_expert >= 0) & (pos_in_expert < C)
    slot = jnp.clip(pos_in_expert, 0, C - 1).astype(jnp.int32)
    slot_onehot = jax.nn.one_hot(slot, C, dtype=jnp.float32) * keep[..., None]
    dispatch = slot_onehot  # (G,gs,E,C)
    combine = dispatch * gates[..., None]

    cd = compute_dtype
    from repro.runtime.sharding import constrain

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(cd), xt.astype(cd))  # (G,E,C,d)
    xe = constrain(xe, ("moe_groups", "experts", None, None))  # dispatch all-to-all
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(cd))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(cd))
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cd))  # (G,E,C,d)
    ye = constrain(ye, ("moe_groups", "experts", None, None))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cd), ye)  # (G,gs,d)
    y = y.reshape(B, S, d)

    if cfg.n_shared_experts:
        xs = x.astype(cd)
        hs = jax.nn.silu(xs @ p["shared_gate"].astype(cd)) * (xs @ p["shared_up"].astype(cd))
        y = y + hs @ p["shared_down"].astype(cd)

    # Switch-style load balance loss: E * sum_e f_e * p_e
    frac_routed = sel.mean(axis=(0, 1))  # fraction of tokens per expert
    mean_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_routed * mean_prob) / K
    return y.astype(x.dtype), aux

"""Feed-forward blocks: gated (SwiGLU) MLP used by every transformer arch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def mlp_specs(d_model: int, d_ff: int, n_layers: int | None, dtype, *, gated: bool = True) -> dict:
    """(Gated) MLP params; optionally stacked over a leading layer axis."""
    lead = () if n_layers is None else (n_layers,)
    lax = () if n_layers is None else ("layers",)
    specs = {
        "w_up": ParamSpec(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype),
        "w_down": ParamSpec(lead + (d_ff, d_model), lax + ("mlp", "embed"), dtype),
    }
    if gated:
        specs["w_gate"] = ParamSpec(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype)
    return specs


def mlp_apply(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    x = x.astype(compute_dtype)
    u = x @ p["w_up"].astype(compute_dtype)
    if "w_gate" in p:  # SwiGLU
        u = jax.nn.silu(x @ p["w_gate"].astype(compute_dtype)) * u
    else:  # classic 2-matrix MLP (starcoder2)
        u = jax.nn.gelu(u)
    return u @ p["w_down"].astype(compute_dtype)

"""Checkpointing: sharded-tree save/restore with atomic commits.

Supports the streaming exactly-once contract: a checkpoint stores the state
pytree *plus* the consumer offsets in one atomic unit (directory rename), so
recovery = restore state + rewind consumers to the stored offsets.
``restore(mesh=...)`` re-shards onto a different mesh (elastic restart).
Async mode overlaps serialization with compute (the paper's long-running
streaming jobs cannot stall for checkpoints).
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
import time
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_flatten_with_paths


@contextlib.contextmanager
def atomic_dir(final: str, lock: threading.Lock | None = None) -> Iterator[str]:
    """Write a directory atomically: the body fills a ``.tmp`` sibling, and
    only a clean exit swaps it into place with an ``os.rename`` commit — a
    crash mid-write leaves the previous version (or nothing) behind, never
    a torn directory. ``lock`` (if given) is held only around the swap, so
    slow serialization never serializes against readers.

    Shared by checkpoints and state migrations (repro.state.migrator): both
    need the same "either the old snapshot or the new one, never half"
    guarantee.
    """
    tmp = final + ".tmp"
    if os.path.exists(tmp):  # stale tmp from a crashed writer
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        yield tmp
    except BaseException:
        # failed write (disk full, serde error): monotonically-increasing
        # step/seq names mean this path is never retried, so the tmp would
        # leak forever if left for the entry-time sweep
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with lock if lock is not None else contextlib.nullcontext():
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit


def _to_numpy(x) -> np.ndarray:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype.name == "bfloat16":  # portable on-disk encoding
        return arr.view(np.uint16)
    return arr


def _from_numpy(arr: np.ndarray, dtype_name: str):
    if dtype_name == "bfloat16":
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(arr)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep_last = keep_last
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()

    # ---- write -----------------------------------------------------------

    def save(self, step: int, state: Any, *, meta: dict | None = None) -> str:
        """Write checkpoint ``step``; returns its path. Atomic via tmp+rename."""
        flat = tree_flatten_with_paths(state)
        host = [(path, _to_numpy(x), str(jnp.asarray(x).dtype)) for path, x in flat]
        if self.async_save:
            self.wait()  # at most one in flight
            t = threading.Thread(target=self._write, args=(step, host, meta or {}), daemon=True)
            t.start()
            self._pending = t
        else:
            self._write(step, host, meta or {})
        return self._path(step)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _write(self, step: int, host: list, meta: dict) -> None:
        with atomic_dir(self._path(step), lock=self._lock) as tmp:
            arrays = {f"a{i}": arr for i, (_, arr, _) in enumerate(host)}
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": [
                    {"path": path, "index": i, "dtype": dt, "shape": list(arr.shape)}
                    for i, (path, arr, dt) in enumerate(host)
                ],
                "meta": meta,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
        with self._lock:
            self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # ---- read -----------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None, *, shardings: Any = None) -> tuple[Any, dict]:
        """Rebuild ``template``-shaped state (optionally placed onto
        ``shardings`` — a different mesh than the one that saved is fine)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        by_path = {
            leaf["path"]: _from_numpy(data[f"a{leaf['index']}"], leaf["dtype"])
            for leaf in manifest["leaves"]
        }
        flat_t = tree_flatten_with_paths(template)
        leaves = []
        for p, tmpl in flat_t:
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            leaves.append(by_path[p])
        treedef = jax.tree.structure(template)
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, manifest["meta"]

from repro.streaming.dispatch import (
    AsyncWindow,
    LatencyWindow,
    ShapeBuckets,
    compile_count,
    kernel_interpret,
    pad_rows,
)
from repro.streaming.rate_control import PIDRateController
from repro.streaming.windows import (
    SessionWindow,
    SlidingWindow,
    TumblingWindow,
    WatermarkTracker,
)

__all__ = [
    "AsyncWindow",
    "LatencyWindow",
    "PIDRateController",
    "SessionWindow",
    "ShapeBuckets",
    "SlidingWindow",
    "TumblingWindow",
    "WatermarkTracker",
    "compile_count",
    "kernel_interpret",
    "pad_rows",
]

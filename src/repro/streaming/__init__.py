from repro.streaming.rate_control import PIDRateController
from repro.streaming.windows import (
    SessionWindow,
    SlidingWindow,
    TumblingWindow,
    WatermarkTracker,
)

__all__ = [
    "PIDRateController",
    "SessionWindow",
    "SlidingWindow",
    "TumblingWindow",
    "WatermarkTracker",
]

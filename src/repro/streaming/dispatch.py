"""Streaming hot-path dispatch: shape buckets + async double-buffering.

The micro-batch ``process()`` hot path has two structural costs that dominate
per-message overhead (paper §6.4 / the serverless-HPC characterization
follow-up):

1. **Recompiles** — ``jax.jit`` specializes on input shapes, so every
   distinct batch size from a variable-rate source triggers a fresh XLA
   compile. :class:`ShapeBuckets` quantizes sizes to a small power-of-two
   set; batches are zero-padded up to their bucket and processed with masked
   updates, so steady state runs with at most ``len(buckets)`` compiles.

2. **Dispatch stalls** — an unconditional ``block_until_ready()`` after
   every batch serializes host dispatch against device compute.
   :class:`AsyncWindow` keeps a bounded number of batches in flight
   (double-buffering at ``depth=2``): batch N+1 is dispatched while batch N
   executes, and the host only blocks when the window is full or at an
   explicit ``sync()`` boundary (stats read, checkpoint, elastic rescale —
   see docs/perf.md for the sync contract).

:class:`LatencyWindow` tracks rolling per-batch completion latency and
exposes p50/p99 for the elastic ``MetricsBus``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable

import jax
import numpy as np


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class ShapeBuckets:
    """Quantize variable sizes to a fixed power-of-two bucket set.

    Sizes above ``max_size`` round up to the next multiple of ``max_size``
    (rare giant batches cost one extra compile each instead of unbounded
    bucket growth).
    """

    def __init__(self, min_size: int = 256, max_size: int = 65536):
        self.min_size = next_pow2(min_size)
        self.max_size = max(next_pow2(max_size), self.min_size)
        sizes, s = [], self.min_size
        while s <= self.max_size:
            sizes.append(s)
            s *= 2
        self.sizes: tuple[int, ...] = tuple(sizes)

    def fit(self, n: int) -> int:
        """Smallest bucket that holds ``n`` rows."""
        for s in self.sizes:
            if n <= s:
                return s
        return -(-n // self.max_size) * self.max_size

    def __len__(self) -> int:
        return len(self.sizes)

    def __iter__(self):
        return iter(self.sizes)

    def __repr__(self) -> str:
        return f"ShapeBuckets({list(self.sizes)})"


def pad_rows(arr: np.ndarray, size: int) -> np.ndarray:
    """Zero-pad axis 0 of ``arr`` up to ``size`` rows (host-side, cheap)."""
    if arr.shape[0] >= size:
        return arr
    out = np.zeros((size,) + arr.shape[1:], arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def kernel_interpret() -> bool:
    """Pallas kernels compile natively on TPU; everywhere else they run in
    interpret mode (correct but slow — the automatic off-TPU fallback)."""
    return jax.default_backend() != "tpu"


def compile_count(jitted: Callable) -> int:
    """Number of distinct XLA compilations a jitted fn has performed."""
    try:
        return int(jitted._cache_size())
    except Exception:
        return -1


class LatencyWindow:
    """Rolling window of per-batch latencies with cheap quantiles."""

    def __init__(self, maxlen: int = 256):
        self._lat: deque[float] = deque(maxlen=maxlen)
        self.count = 0

    def record(self, dt: float) -> None:
        self._lat.append(dt)
        self.count += 1

    def quantile(self, q: float) -> float:
        if not self._lat:
            return 0.0
        return float(np.quantile(np.asarray(self._lat), q))

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def __len__(self) -> int:
        return len(self._lat)


class AsyncWindow:
    """Bounded window of in-flight jax computations (double buffering).

    ``push(result, meta)`` enqueues a just-dispatched result. When more than
    ``depth`` results are pending the oldest is blocked on, so the device
    queue stays bounded while newer batches dispatch. Each completed entry is
    returned as ``(result, meta, latency_s)`` — callers fold these into
    their stats. ``depth=0`` degenerates to fully synchronous execution
    (the pre-overhaul behavior, kept for before/after benchmarking).
    """

    def __init__(self, depth: int = 2, latency: LatencyWindow | None = None):
        self.depth = max(int(depth), 0)
        self.latency = latency
        self._pending: deque[tuple[Any, Any, float]] = deque()
        # the engine thread pushes; sync() may come from a rescale/stats
        # thread — serialize drains so both never pop the same entry
        self._lock = threading.Lock()

    def push(self, result: Any, meta: Any = None,
             t0: float | None = None) -> list[tuple[Any, Any, float]]:
        """Enqueue a dispatched result. ``t0`` is the batch's start-of-work
        timestamp (defaults to now): completion latency is measured from it,
        so host-side batch prep counts toward the recorded latency."""
        done = []
        with self._lock:
            self._pending.append((result, meta, time.monotonic() if t0 is None else t0))
            while len(self._pending) > self.depth:
                done.append(self._wait_oldest())
        return done

    def _wait_oldest(self) -> tuple[Any, Any, float]:
        result, meta, t0 = self._pending.popleft()
        jax.block_until_ready(result)
        dt = time.monotonic() - t0
        if self.latency is not None:
            self.latency.record(dt)
        return result, meta, dt

    def sync(self) -> list[tuple[Any, Any, float]]:
        """Drain every in-flight batch (the stats/checkpoint/rescale barrier)."""
        done = []
        with self._lock:
            while self._pending:
                done.append(self._wait_oldest())
        return done

    def discard(self) -> int:
        """Drop every pending entry without waiting on or delivering it.
        Crash-injection path: replay re-produces the dropped work, so
        delivering it here would double-count. Returns the count dropped."""
        with self._lock:
            n = len(self._pending)
            self._pending.clear()
            return n

    @property
    def in_flight(self) -> int:
        return len(self._pending)

"""Window assigners + watermarks (paper §3.1: fixed/sliding/session windows,
processing-time or event-time)."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

#: a window is the half-open interval [start, end)
Window = tuple[float, float]


@dataclass(frozen=True)
class TumblingWindow:
    size: float

    def assign(self, ts: float) -> list[Window]:
        start = math.floor(ts / self.size) * self.size
        return [(start, start + self.size)]


@dataclass(frozen=True)
class SlidingWindow:
    size: float
    slide: float

    def assign(self, ts: float) -> list[Window]:
        out = []
        first = math.floor((ts - self.size) / self.slide) * self.slide + self.slide
        start = first
        while start <= ts:
            out.append((start, start + self.size))
            start += self.slide
        return [w for w in out if w[0] <= ts < w[1]]


@dataclass
class SessionWindow:
    """Gap-based session windows; assignment is stateful per key."""

    gap: float
    _sessions: dict = field(default_factory=dict)  # key -> (start, end)

    def assign(self, ts: float, key=None) -> list[Window]:
        cur = self._sessions.get(key)
        if cur is not None and ts < cur[1]:
            merged = (min(cur[0], ts), max(cur[1], ts + self.gap))
        else:
            merged = (ts, ts + self.gap)
        self._sessions[key] = merged
        return [merged]

    def close_before(self, watermark: float, key=None) -> list[Window]:
        closed = []
        for k, (s, e) in list(self._sessions.items()):
            if (key is None or k == key) and e <= watermark:
                closed.append((s, e))
                del self._sessions[k]
        return closed


class WatermarkTracker:
    """Event-time watermark: max observed timestamp minus allowed lateness."""

    def __init__(self, allowed_lateness: float = 0.0):
        self.allowed_lateness = allowed_lateness
        self._max_ts = -math.inf

    def observe(self, ts: float) -> None:
        self._max_ts = max(self._max_ts, ts)

    @property
    def watermark(self) -> float:
        return self._max_ts - self.allowed_lateness

    def is_late(self, ts: float) -> bool:
        return ts < self.watermark

"""Window assigners + watermarks (paper §3.1: fixed/sliding/session windows,
processing-time or event-time)."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

#: a window is the half-open interval [start, end)
Window = tuple[float, float]


@dataclass(frozen=True)
class TumblingWindow:
    size: float

    def assign(self, ts: float) -> list[Window]:
        start = math.floor(ts / self.size) * self.size
        return [(start, start + self.size)]


@dataclass(frozen=True)
class SlidingWindow:
    size: float
    slide: float

    def assign(self, ts: float) -> list[Window]:
        out = []
        first = math.floor((ts - self.size) / self.slide) * self.slide + self.slide
        start = first
        while start <= ts:
            out.append((start, start + self.size))
            start += self.slide
        return [w for w in out if w[0] <= ts < w[1]]


@dataclass
class SessionWindow:
    """Gap-based session windows; assignment is stateful per key.

    Each element opens the proto-session ``[ts, ts + gap)``; any existing
    session of the key that *overlaps* it (half-open intervals — touching
    exactly at the boundary starts a new session) is folded in. A key may
    hold several concurrent sessions, so out-of-order arrivals can bridge
    two older sessions into one — and the final session set for a key is a
    pure interval union, independent of arrival order (property-tested in
    tests/test_windows.py; de-facto required for rescale determinism, since
    a migration replays buffers in canonical, not arrival, order).
    """

    gap: float
    _sessions: dict = field(default_factory=dict)  # key -> [(start, end), ...]

    def assign(self, ts: float, key=None) -> list[Window]:
        lo, hi = ts, ts + self.gap
        keep = []
        for s in self._sessions.get(key, ()):
            if s[1] <= lo or s[0] >= hi:  # disjoint: keep as-is
                keep.append(s)
            else:  # overlap: absorb into the merged session
                lo, hi = min(lo, s[0]), max(hi, s[1])
        merged = (lo, hi)
        keep.append(merged)
        keep.sort()
        self._sessions[key] = keep
        return [merged]

    def sessions(self, key=None) -> list[Window]:
        """Current (un-closed) sessions of ``key``, ordered by start."""
        return list(self._sessions.get(key, ()))

    def close_before(self, watermark: float, key=None) -> list[Window]:
        closed = []
        for k, sessions in list(self._sessions.items()):
            if key is not None and k != key:
                continue
            done = [s for s in sessions if s[1] <= watermark]
            if done:
                closed.extend(done)
                remaining = [s for s in sessions if s[1] > watermark]
                if remaining:
                    self._sessions[k] = remaining
                else:
                    del self._sessions[k]
        return sorted(closed)


class WatermarkTracker:
    """Event-time watermark: max observed timestamp minus allowed lateness."""

    def __init__(self, allowed_lateness: float = 0.0):
        self.allowed_lateness = allowed_lateness
        self._max_ts = -math.inf

    def observe(self, ts: float) -> None:
        self._max_ts = max(self._max_ts, ts)

    @property
    def watermark(self) -> float:
        return self._max_ts - self.allowed_lateness

    def is_late(self, ts: float) -> bool:
        return ts < self.watermark

"""PID-based backpressure rate controller (Spark Streaming's
``spark.streaming.backpressure`` estimator, adapted).

Keeps the micro-batch processing time at or below the batch interval by
adjusting the per-batch ingestion bound. The dysfunctional-system failure
mode this prevents — processing rate < production rate -> unbounded lag —
is the paper's core motivating scenario (§1, §3.3).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class PIDRateController:
    batch_interval: float  # target seconds per micro-batch
    kp: float = 1.0
    ki: float = 0.2
    kd: float = 0.0
    min_rate: float = 10.0  # records/sec floor

    _latest_rate: float = 0.0
    _latest_error: float = 0.0
    _integral: float = 0.0
    _initialized: bool = False

    def update(self, n_records: int, processing_delay: float, scheduling_delay: float = 0.0) -> float:
        """Returns the new max ingestion rate (records/sec)."""
        if n_records <= 0 or processing_delay <= 0:
            return self._latest_rate or self.min_rate
        processing_rate = n_records / processing_delay
        error = self._latest_rate - processing_rate if self._initialized else 0.0
        # records queued due to scheduling delay act as accumulated error
        hist_error = scheduling_delay * processing_rate / self.batch_interval
        d_error = (error - self._latest_error) / max(self.batch_interval, 1e-6)
        new_rate = processing_rate - self.kp * error - self.ki * hist_error - self.kd * d_error
        if not self._initialized:
            new_rate = processing_rate
            self._initialized = True
        new_rate = max(new_rate, self.min_rate)
        self._latest_rate = new_rate
        self._latest_error = error
        return new_rate

    @property
    def max_records_per_batch(self) -> int:
        rate = self._latest_rate or self.min_rate
        return max(int(rate * self.batch_interval), 1)

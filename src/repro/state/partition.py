"""Key -> partition -> owner mapping for partitioned keyed state.

The keyed window state of the continuous engine is sharded over a *fixed*
ring of ``n_partitions`` state partitions (Flink's "key groups"): a key is
hashed onto a partition once and forever, and elasticity only ever remaps
*partitions* to owners. A grow/shrink therefore moves whole partitions, not
individual keys, and the set of moved partitions is exactly the assignment
diff — the property the :class:`~repro.state.migrator.StateMigrator` and the
``tests/test_state.py`` suite are built on.

Hashing must be stable across processes and runs (``hash()`` is salted per
process for str/bytes), so keys are canonically encoded and digested with
blake2b. Numeric keys are normalized the same way Python dict equality
treats them (``3 == 3.0 == True`` share a bucket), so a store keyed by a
mix of ints and floats cannot split one dict key over two partitions.
"""
from __future__ import annotations

import hashlib
import struct
from typing import Any, Hashable, Mapping, Sequence

import numpy as np

#: default ring size — enough granularity to split across tens of owners
#: while keeping per-partition bookkeeping cheap
DEFAULT_PARTITIONS = 64

#: owner sentinel for state that has not (yet) been spread across pilots
LOCAL_OWNER = "__local__"


def normalize_key(key: Hashable) -> Hashable:
    """Fold a key to the canonical member of its dict-equality class:
    ``np.int64(3)``, ``3.0``, ``True`` and ``3`` are ONE dict key and must
    normalize (and therefore hash and serialize) identically. The single
    normalization step shared by :func:`key_bytes` and the partition serde
    — two independent ladders would inevitably drift.
    """
    if isinstance(key, np.generic):  # np.int64/np.float64/np.str_ key_fns
        key = key.item()
    if isinstance(key, bool):
        return int(key)
    if isinstance(key, float) and key.is_integer():
        # floats equal to an int must fold to the int (0.0 == 0, and
        # float(2**53) == 2**53); int() is exact for any integral float
        return int(key)
    if isinstance(key, tuple):
        return tuple(normalize_key(k) for k in key)
    return key


def key_bytes(key: Hashable) -> bytes:
    """Canonical encoding of a state key.

    ``None``, bool, int, float, str, bytes and tuples thereof (the types
    the engines produce) encode process-stably, with equal-comparing
    numerics encoding identically — mirroring dict-key semantics. Any
    other hashable falls back to a repr-based encoding (deterministic
    in-process, so routing stays correct; see below).
    """
    key = normalize_key(key)
    if key is None:
        return b"\x00"
    if isinstance(key, float):  # non-integral after normalization
        return b"\x03" + struct.pack("<d", key)
    if isinstance(key, int):
        return b"\x02" + str(key).encode("ascii")
    if isinstance(key, str):
        return b"\x04" + key.encode("utf-8")
    if isinstance(key, (bytes, bytearray)):
        return b"\x05" + bytes(key)
    if isinstance(key, tuple):
        parts = [key_bytes(k) for k in key]
        return b"\x06" + b"".join(
            struct.pack("<I", len(p)) + p for p in parts
        )
    # any other hashable (frozenset, frozen dataclass, ...): the engine's
    # key_fn contract predates this module and allows them. repr is
    # deterministic within a process — enough for routing (equal keys are
    # one dict key and must repr equally) — though unlike the types above
    # it is not guaranteed stable across interpreter runs.
    return b"\x07" + type(key).__qualname__.encode() + b"\x00" + repr(key).encode()


def partition_for(key: Hashable, n_partitions: int = DEFAULT_PARTITIONS) -> int:
    """The partition a key permanently belongs to (consistent across
    processes, runs, and rescales)."""
    digest = hashlib.blake2b(key_bytes(key), digest_size=8).digest()
    return int.from_bytes(digest, "big") % n_partitions


def range_assignment(n_partitions: int, owners: Sequence[Any]) -> dict[int, Any]:
    """Assign partitions to owners as contiguous ranges (Flink key-group
    ranges): owner ``i`` of ``k`` gets ``[i*N//k, (i+1)*N//k)``.

    Contiguous ranges (rather than ``p % k`` striping) keep the assignment
    diff small under grow/shrink: going ``k -> k+1`` only moves the range
    tails, not every other partition. Every partition gets exactly one
    owner; with more owners than partitions the surplus owners get none.
    """
    owners = list(owners)
    if not owners:
        raise ValueError("range_assignment needs at least one owner")
    k = len(owners)
    assignment: dict[int, Any] = {}
    for i, owner in enumerate(owners):
        for p in range(i * n_partitions // k, (i + 1) * n_partitions // k):
            assignment[p] = owner
    return assignment


def moved_partitions(old: Mapping[int, Any], new: Mapping[int, Any]) -> list[int]:
    """Partitions whose owner differs between two assignments — the only
    state a migration may touch."""
    return sorted(p for p in new if old.get(p) != new[p])

"""PartitionedStateStore — the keyed window state of the continuous engine.

Each ``(key, window)`` buffer lives in the partition its key hashes to
(:func:`~repro.state.partition.partition_for`); the store also keeps the
per-partition record/late counters and max event time, so a partition is a
self-contained unit of state that can be snapshotted, shipped and restored
without touching its neighbors. The serde (msgpack envelope + the broker's
npy value encoding) round-trips buffers *exactly*: key types, window
bounds, per-buffer message order, and counters all survive a migration —
the invariant ``tests/test_state.py`` drives with hypothesis.
"""
from __future__ import annotations

import math
import pickle
from dataclasses import dataclass, field
from typing import Any, Iterable

import msgpack
import numpy as np

from repro.broker.consumer import Message
from repro.broker.records import decode_array, encode_array
from repro.state.partition import (
    DEFAULT_PARTITIONS,
    LOCAL_OWNER,
    key_bytes,
    normalize_key,
    partition_for,
    range_assignment,
)

#: a window is the half-open interval [start, end) — streaming/windows.py
Window = tuple[float, float]


@dataclass
class StatePartition:
    """One shard of keyed state: buffers + counters, migratable as a unit."""

    pid: int
    buffers: dict[tuple, list] = field(default_factory=dict)  # (key, w) -> [Message]
    records: int = 0
    late_records: int = 0
    max_event_time: float = -math.inf

    @property
    def buffered_records(self) -> int:
        return sum(len(msgs) for msgs in self.buffers.values())


def merge_session_into(part: StatePartition, key, merged: Window) -> None:
    """Fold every buffered window of ``key`` overlapping ``merged`` into the
    ``(key, merged)`` buffer (session-window merge), preserving canonical
    event-time order. Shared by the in-process store and the worker-process
    runtime (repro.workers) so both executors merge identically."""
    victims = [
        (k, w) for (k, w) in part.buffers
        if k == key and w != merged
        and not (w[1] <= merged[0] or w[0] >= merged[1])
    ]
    if not victims:
        return
    target = part.buffers.setdefault((key, merged), [])
    for kw in victims:
        target.extend(part.buffers.pop(kw))
    # canonical event-time order: plain fold order would depend on dict
    # insertion order, which a migration round trip permutes (restored
    # buffers come back in canonical serde order) — an order-sensitive
    # window_fn would then see rescale-dependent float low bits
    target.sort(key=lambda m: (m.timestamp, m.partition, m.offset))


def ready_buffers(partitions: Iterable[StatePartition],
                  watermark: float) -> list[tuple[Any, Window, int]]:
    """Buffers whose window closed at ``watermark``, in the deterministic
    firing order both executors share: (window end, window start, partition,
    key encoding). Dict insertion order — which a migration round trip (or a
    worker restart replay) may permute — never decides firing order."""
    out = []
    for part in partitions:
        for (key, w) in part.buffers:
            if w[1] <= watermark:
                out.append((key, w, part.pid))
    out.sort(key=lambda kwp: (kwp[1][1], kwp[1][0], kwp[2], key_bytes(kwp[0])))
    return out


class PartitionedStateStore:
    """Fixed ring of ``n_partitions`` state partitions plus the live
    partition -> owner assignment.

    All partitions are resident in-process (this reproduction is single
    host); the assignment still matters because it defines which partitions
    a rescale *moves* — and moved partitions take the full serialize ->
    spool -> deserialize round trip a real hand-off would.
    """

    def __init__(self, n_partitions: int = DEFAULT_PARTITIONS,
                 owners: Iterable[Any] | None = None):
        if n_partitions < 1:
            raise ValueError("need at least one state partition")
        self.n_partitions = n_partitions
        self.partitions: dict[int, StatePartition] = {
            p: StatePartition(p) for p in range(n_partitions)
        }
        owners = list(owners) if owners else [LOCAL_OWNER]
        self.assignment: dict[int, Any] = range_assignment(n_partitions, owners)
        # keyed streams repeat keys heavily; memoize the blake2b routing so
        # the per-record hot path pays one dict lookup, not a digest
        self._pid_cache: dict = {}

    # -- key routing ----------------------------------------------------------

    def partition_of(self, key) -> int:
        pid = self._pid_cache.get(key)
        if pid is None:
            if len(self._pid_cache) > 65536:  # pathological key cardinality
                self._pid_cache.clear()
            pid = self._pid_cache[key] = partition_for(key, self.n_partitions)
        return pid

    def owner_of(self, key) -> Any:
        return self.assignment[self.partition_of(key)]

    @property
    def owners(self) -> list:
        """Distinct owners in assignment order (partition 0 upward)."""
        out: list = []
        for p in range(self.n_partitions):
            o = self.assignment[p]
            if not out or out[-1] != o:
                out.append(o)
        return out

    # -- write path (engine ingest) -------------------------------------------

    def append(self, key, window: Window, msg: Message) -> None:
        """Buffer one message into one window (call once per assigned
        window; per-record counters live in :meth:`observe`)."""
        part = self.partitions[self.partition_of(key)]
        part.buffers.setdefault((key, window), []).append(msg)

    def observe(self, key, ts: float) -> None:
        """Per-record bookkeeping, exactly once per ingested record — a
        sliding assigner appends the same record to several windows, which
        must not inflate the partition's record count."""
        part = self.partitions[self.partition_of(key)]
        part.records += 1
        if ts > part.max_event_time:
            part.max_event_time = ts

    def record_late(self, key) -> None:
        self.partitions[self.partition_of(key)].late_records += 1

    def merge_session(self, key, merged: Window) -> None:
        """Fold every buffered window of ``key`` overlapping ``merged`` into
        the ``(key, merged)`` buffer (session-window merge). Buffer order is
        preserved: earlier windows' messages keep their relative order."""
        merge_session_into(self.partitions[self.partition_of(key)], key, merged)

    # -- read path (engine firing) ----------------------------------------------

    def _ready(self, watermark: float) -> list[tuple[Any, Window, int]]:
        return ready_buffers(self.partitions.values(), watermark)

    def pop_ready(self, watermark: float) -> list[tuple[Any, Window, list]]:
        return [
            (key, w, self.partitions[pid].buffers.pop((key, w)))
            for key, w, pid in self._ready(watermark)
        ]

    # -- aggregate views ----------------------------------------------------------

    @property
    def buffered_windows(self) -> int:
        return sum(len(p.buffers) for p in self.partitions.values())

    @property
    def buffered_records(self) -> int:
        return sum(p.buffered_records for p in self.partitions.values())

    def items(self) -> Iterable[tuple[tuple, list]]:
        """Every live ``((key, window), msgs)`` buffer across partitions."""
        for p in range(self.n_partitions):
            yield from self.partitions[p].buffers.items()


# ---------------------------------------------------------------------------
# partition serde — the wire format of a migration
# ---------------------------------------------------------------------------

_INF = float("inf")


def _enc_key(key) -> list:
    key = normalize_key(key)  # the ONE folding rule, shared with key_bytes
    if key is None:
        return ["n"]
    if isinstance(key, int):
        return ["i", str(key)]  # str: msgpack ints cap at 64 bits
    if isinstance(key, float):  # non-integral after normalization
        return ["f", key]
    if isinstance(key, str):
        return ["s", key]
    if isinstance(key, (bytes, bytearray)):
        return ["y", bytes(key)]
    if isinstance(key, tuple):
        return ["t", [_enc_key(k) for k in key]]
    # arbitrary hashable (see key_bytes): pickle restores an equal object
    return ["p", pickle.dumps(key, protocol=4)]


def _dec_key(enc: list):
    tag = enc[0]
    if tag == "n":
        return None
    if tag == "i":
        return int(enc[1])
    if tag == "t":
        return tuple(_dec_key(e) for e in enc[1])
    if tag == "p":
        return pickle.loads(enc[1])
    return enc[1]


def _enc_value(value) -> list:
    if isinstance(value, np.ndarray):
        return ["npy", encode_array(value)]
    if isinstance(value, np.generic):  # numpy scalar: keep dtype
        return ["nps", encode_array(np.asarray(value))]
    if isinstance(value, tuple):
        return ["tup", [_enc_value(v) for v in value]]
    if isinstance(value, list):
        return ["list", [_enc_value(v) for v in value]]
    return ["raw", value]  # msgpack-native (None/bool/num/str/bytes/dict)


def _dec_value(enc: list):
    tag, body = enc
    if tag == "npy":
        return decode_array(body)
    if tag == "nps":
        return decode_array(body)[()]
    if tag == "tup":
        return tuple(_dec_value(v) for v in body)
    if tag == "list":
        return [_dec_value(v) for v in body]
    return body


def serialize_partition(part: StatePartition) -> bytes:
    """Self-contained snapshot of one partition. Buffers are emitted in a
    canonical order (key encoding, then window) so equal states serialize
    identically regardless of insertion history.

    Array values are stored *columnar*: all messages sharing a (dtype,
    shape) signature stack into one contiguous blob, so restore pays one
    ``frombuffer`` per group instead of one numpy call per message —
    per-message envelopes dominated migration latency at large state
    sizes (benchmarks/rescale_state.py).
    """
    groups: dict[tuple, list] = {}  # (dtype.str, shape) -> [gid, [arrays]]
    # flat per-message columns (msgpack C-packs homogeneous lists fast and
    # decode rebuilds all messages in one comprehension — per-buffer nested
    # structures cost a frame per buffer, which dominated at scale)
    buffers_meta: list = []  # [enc_key, w_start, w_end, n_msgs]
    mpart: list[int] = []
    moff: list[int] = []
    mts: list[float] = []
    vgid: list[int] = []  # value group id, -1 = see vother
    vrow: list[int] = []
    vother: list = []  # [flat_index, _enc_value(...)] pairs

    for (key, w), msgs in sorted(
        part.buffers.items(), key=lambda kw: (key_bytes(kw[0][0]), kw[0][1])
    ):
        buffers_meta.append([_enc_key(key), w[0], w[1], len(msgs)])
        for m in msgs:
            mpart.append(m.partition)
            moff.append(m.offset)
            mts.append(m.timestamp)
            value = m.value
            # structured dtypes must keep the npy envelope: dtype.str for
            # them is an opaque '|V8'-style void dropping field metadata
            if (isinstance(value, np.ndarray) and value.ndim >= 1
                    and not value.dtype.hasobject
                    and value.dtype.names is None):
                arr = np.ascontiguousarray(value)
                g = groups.setdefault((arr.dtype.str, arr.shape), [len(groups), []])
                g[1].append(arr)
                vgid.append(g[0])
                vrow.append(len(g[1]) - 1)
            else:
                vother.append([len(vgid), _enc_value(value)])
                vgid.append(-1)
                vrow.append(-1)
    payload = {
        "v": 2,
        "pid": part.pid,
        "records": part.records,
        "late_records": part.late_records,
        # msgpack refuses -inf on some strict decoders; None = "no events"
        "max_event_time": None if part.max_event_time == -_INF else part.max_event_time,
        "buffers": buffers_meta,
        "mpart": mpart,
        "moff": moff,
        "mts": mts,
        "vgid": vgid,
        "vrow": vrow,
        "vother": vother,
        # dict insertion order == gid order, so a plain list round-trips
        "groups": [
            [dtype, list(shape), len(arrs), b"".join(a.tobytes() for a in arrs)]
            for (dtype, shape), (_gid, arrs) in groups.items()
        ],
    }
    return msgpack.packb(payload, use_bin_type=True)


def deserialize_partition(data: bytes) -> StatePartition:
    payload = msgpack.unpackb(data, raw=False, strict_map_key=False)
    part = StatePartition(
        pid=payload["pid"],
        records=payload["records"],
        late_records=payload["late_records"],
        max_event_time=(-_INF if payload["max_event_time"] is None
                        else payload["max_event_time"]),
    )
    # one frombuffer + copy per value group; rows are writable views that
    # own disjoint slices, so per-message mutation stays per-message
    groups = [
        np.frombuffer(blob, dtype=np.dtype(dtype)).reshape([n, *shape]).copy()
        for dtype, shape, n, blob in payload.get("groups", ())
    ]
    other = {i: _dec_value(enc) for i, enc in payload["vother"]}
    values = [
        groups[g][r] if g >= 0 else other[i]
        for i, (g, r) in enumerate(zip(payload["vgid"], payload["vrow"]))
    ]
    msgs_flat = [
        Message(p, off, ts, v)
        for p, off, ts, v in zip(payload["mpart"], payload["moff"],
                                 payload["mts"], values)
    ]
    pos = 0
    for enc_key, ws, we, n in payload["buffers"]:
        part.buffers[(_dec_key(enc_key), (ws, we))] = msgs_flat[pos:pos + n]
        pos += n
    return part

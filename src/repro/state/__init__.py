"""Partitioned keyed state for the continuous engine (docs/state.md).

Keys hash onto a fixed ring of state partitions; elasticity remaps
partitions to owners (contiguous ranges), and a grow/shrink migrates only
the partitions whose owner changed — quiesce -> snapshot -> reassign ->
restore, with an atomic on-disk spool. The property/chaos suites in
``tests/test_state*.py`` hold the subsystem to: every key has exactly one
live owner, and no ``(key, window)`` buffer is ever lost, duplicated, or
reordered across any sequence of rescales.
"""
from repro.state.migrator import MigrationReport, StateMigrator
from repro.state.partition import (
    DEFAULT_PARTITIONS,
    LOCAL_OWNER,
    key_bytes,
    moved_partitions,
    normalize_key,
    partition_for,
    range_assignment,
)
from repro.state.store import (
    PartitionedStateStore,
    StatePartition,
    deserialize_partition,
    serialize_partition,
)

__all__ = [
    "DEFAULT_PARTITIONS",
    "LOCAL_OWNER",
    "MigrationReport",
    "PartitionedStateStore",
    "StateMigrator",
    "StatePartition",
    "deserialize_partition",
    "key_bytes",
    "moved_partitions",
    "normalize_key",
    "partition_for",
    "range_assignment",
    "serialize_partition",
]

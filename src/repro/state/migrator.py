"""StateMigrator — rescale-safe hand-off of state partitions.

The migration lifecycle the continuous engine drives on every grow/shrink
(the caller quiesces first — ``ContinuousStream.rescale`` holds its state
lock and runs the ``sync_fn`` barrier before calling in):

1. **plan**: diff the store's current partition -> owner assignment against
   the range assignment over the new owner set; only the diff moves.
2. **snapshot**: serialize each moved partition and spool the lot to disk
   in one atomic directory (the checkpoint manager's tmp+rename commit —
   a crash mid-migration leaves the previous spool, never a torn one).
3. **reassign**: install the new assignment.
4. **restore**: read every spooled partition back and deserialize it into
   the store — moved state always takes the full serde round trip a real
   cross-host hand-off would take, which is what lets the property suite
   prove no buffer is lost, duplicated, or reordered.

Gauges (published when a bus is attached): ``state.migrated_partitions``,
``state.migration_ms``, ``state.bytes_moved`` — labeled with the owning
stream so multi-stage pipelines don't mix them.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from repro.checkpoint.manager import atomic_dir
from repro.state.partition import LOCAL_OWNER, moved_partitions, range_assignment
from repro.state.store import (
    PartitionedStateStore,
    deserialize_partition,
    serialize_partition,
)


@dataclass(frozen=True)
class MigrationReport:
    """What one rescale actually moved."""

    seq: int
    from_owners: tuple
    to_owners: tuple
    moved: tuple[int, ...]  # partition ids that changed owner
    n_partitions: int
    bytes_moved: int
    buffered_records_moved: int
    duration_ms: float
    spool_path: str = ""

    @property
    def moved_fraction(self) -> float:
        return len(self.moved) / self.n_partitions if self.n_partitions else 0.0


@dataclass
class StateMigrator:
    """One migrator per stream; keeps a bounded spool directory and the
    history of reports (newest last)."""

    directory: str | None = None
    bus: Any = None  # repro.elastic.MetricsBus | None
    label: str | None = None
    keep_last: int = 2  # spools retained for post-mortems
    reports: list[MigrationReport] = field(default_factory=list)
    _seq: int = 0

    _owns_dir: bool = False

    def _spool_root(self) -> str:
        if self.directory is None:
            self.directory = tempfile.mkdtemp(prefix="repro-state-migrations-")
            self._owns_dir = True
        else:
            os.makedirs(self.directory, exist_ok=True)
        return self.directory

    def cleanup(self) -> None:
        """Remove the spool directory if this migrator created it (a
        caller-provided ``directory`` is left alone). Safe to call
        repeatedly; a later migrate() just spools afresh."""
        if self._owns_dir and self.directory is not None:
            shutil.rmtree(self.directory, ignore_errors=True)
            self.directory = None
            self._owns_dir = False

    def plan(self, store: PartitionedStateStore,
             new_owners: Sequence[Any]) -> tuple[dict[int, Any], list[int]]:
        """The new assignment and the partitions a migration would move."""
        owners = list(new_owners) or [LOCAL_OWNER]
        new = range_assignment(store.n_partitions, owners)
        return new, moved_partitions(store.assignment, new)

    def migrate(self, store: PartitionedStateStore,
                new_owners: Sequence[Any]) -> MigrationReport:
        """Quiesced-caller contract: the store must not be mutated while
        this runs (ContinuousStream holds its state lock around the call).

        The in-process special case of :meth:`handoff`: fetch serializes
        straight out of the store, install deserializes straight back in.
        """

        def fetch(pids: Sequence[int]) -> dict[int, bytes]:
            return {pid: serialize_partition(store.partitions[pid]) for pid in pids}

        def install(assignment: dict[int, Any],
                    payloads: Mapping[int, bytes]) -> int:
            store.assignment = assignment
            moved_records = 0
            for pid, data in payloads.items():
                part = deserialize_partition(data)
                assert part.pid == pid
                store.partitions[pid] = part
                moved_records += part.buffered_records
            return moved_records

        return self.handoff(store, new_owners, fetch, install)

    def handoff(self, store: PartitionedStateStore, new_owners: Sequence[Any],
                fetch: Callable[[Sequence[int]], dict[int, bytes]],
                install: Callable[[dict[int, Any], Mapping[int, bytes]], int],
                ) -> MigrationReport:
        """The migration lifecycle with pluggable endpoints — what lets the
        same quiesce -> snapshot -> spool -> reassign -> restore path move
        partitions *between worker processes* (repro.workers) as well as
        within the host store.

        ``fetch(pids)`` pulls the serialized bytes of each moved partition
        from wherever it currently lives (and releases it there);
        ``install(assignment, payloads)`` makes the new assignment live and
        delivers the spooled bytes to each partition's new home, returning
        the number of buffered records moved. Moved state always takes the
        full serialize -> spool -> read-back trip, regardless of endpoint.
        """
        t0 = time.perf_counter()
        from_owners = tuple(store.owners)
        new, moved = self.plan(store, new_owners)
        seq = self._seq
        self._seq += 1

        payloads = fetch(moved)
        spool = ""
        if payloads:
            spool = self.write_spool(payloads, f"migration_{seq:06d}")

        # deliver from the spool (not from the in-memory payloads): moved
        # state must survive the full serde + disk round trip
        restored = self.read_spool(spool, moved) if payloads else {}
        moved_records = install(new, restored)

        self._gc_spools("migration_")
        report = MigrationReport(
            seq=seq,
            from_owners=from_owners,
            to_owners=tuple(list(new_owners) or [LOCAL_OWNER]),
            moved=tuple(moved),
            n_partitions=store.n_partitions,
            bytes_moved=sum(len(d) for d in payloads.values()),
            buffered_records_moved=moved_records,
            duration_ms=(time.perf_counter() - t0) * 1e3,
            spool_path=spool,
        )
        self.reports.append(report)
        if self.bus is not None:
            labels = {} if self.label is None else {"stream": self.label}
            self.bus.publish("state.migrated_partitions", len(moved), **labels)
            self.bus.publish("state.migration_ms", report.duration_ms, **labels)
            self.bus.publish("state.bytes_moved", report.bytes_moved, **labels)
        return report

    # -- spool primitives (shared with the worker runtime's checkpoints) -------

    def write_spool(self, payloads: Mapping[int, bytes], name: str,
                    *, meta: bytes | None = None) -> str:
        """Atomically write one ``pid -> serialized partition`` set under
        ``name`` in the spool root; returns the committed path. Used for
        migration spools and for the worker runtime's periodic restart
        checkpoints (``wckpt_*``). ``meta`` rides along as a sidecar blob
        (``meta.bin`` — outside the partition namespace) for stream-global
        state a checkpoint must carry: consumer positions, watermark,
        counters (ContinuousStream's ``sckpt_*`` crash checkpoints)."""
        spool = os.path.join(self._spool_root(), name)
        with atomic_dir(spool) as tmp:
            for pid, data in payloads.items():
                with open(os.path.join(tmp, f"p{pid:05d}.bin"), "wb") as f:
                    f.write(data)
            if meta is not None:
                with open(os.path.join(tmp, "meta.bin"), "wb") as f:
                    f.write(meta)
        return spool

    def read_meta(self, spool: str) -> bytes | None:
        """The sidecar meta blob of a committed spool (None if absent)."""
        path = os.path.join(spool, "meta.bin")
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return f.read()

    def latest_spool(self, prefix: str) -> str | None:
        """Path of the newest committed spool with ``prefix`` (crash
        recovery entry point: sequence-numbered names sort temporally)."""
        if self.directory is None or not os.path.isdir(self.directory):
            return None
        spools = sorted(
            n for n in os.listdir(self.directory)
            if n.startswith(prefix) and not n.endswith(".tmp")
        )
        if not spools:
            return None
        return os.path.join(self.directory, spools[-1])

    def read_spool(self, spool: str,
                   pids: Sequence[int] | None = None) -> dict[int, bytes]:
        """Read back serialized partitions from a committed spool directory
        (all of them, or just ``pids``)."""
        if pids is None:
            pids = sorted(
                int(n[1:-4]) for n in os.listdir(spool)
                if n.startswith("p") and n.endswith(".bin")
            )
        out: dict[int, bytes] = {}
        for pid in pids:
            path = os.path.join(spool, f"p{pid:05d}.bin")
            if os.path.exists(path):
                with open(path, "rb") as f:
                    out[pid] = f.read()
        return out

    def _gc_spools(self, prefix: str) -> None:
        if self.directory is None or not os.path.isdir(self.directory):
            return
        spools = sorted(
            n for n in os.listdir(self.directory)
            if n.startswith(prefix) and not n.endswith(".tmp")
        )
        for name in spools[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def gc_checkpoints(self) -> None:
        """Bound the worker-checkpoint spools like migration spools."""
        self._gc_spools("wckpt_")

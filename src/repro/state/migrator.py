"""StateMigrator — rescale-safe hand-off of state partitions.

The migration lifecycle the continuous engine drives on every grow/shrink
(the caller quiesces first — ``ContinuousStream.rescale`` holds its state
lock and runs the ``sync_fn`` barrier before calling in):

1. **plan**: diff the store's current partition -> owner assignment against
   the range assignment over the new owner set; only the diff moves.
2. **snapshot**: serialize each moved partition and spool the lot to disk
   in one atomic directory (the checkpoint manager's tmp+rename commit —
   a crash mid-migration leaves the previous spool, never a torn one).
3. **reassign**: install the new assignment.
4. **restore**: read every spooled partition back and deserialize it into
   the store — moved state always takes the full serde round trip a real
   cross-host hand-off would take, which is what lets the property suite
   prove no buffer is lost, duplicated, or reordered.

Gauges (published when a bus is attached): ``state.migrated_partitions``,
``state.migration_ms``, ``state.bytes_moved`` — labeled with the owning
stream so multi-stage pipelines don't mix them.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.checkpoint.manager import atomic_dir
from repro.state.partition import LOCAL_OWNER, moved_partitions, range_assignment
from repro.state.store import (
    PartitionedStateStore,
    deserialize_partition,
    serialize_partition,
)


@dataclass(frozen=True)
class MigrationReport:
    """What one rescale actually moved."""

    seq: int
    from_owners: tuple
    to_owners: tuple
    moved: tuple[int, ...]  # partition ids that changed owner
    n_partitions: int
    bytes_moved: int
    buffered_records_moved: int
    duration_ms: float
    spool_path: str = ""

    @property
    def moved_fraction(self) -> float:
        return len(self.moved) / self.n_partitions if self.n_partitions else 0.0


@dataclass
class StateMigrator:
    """One migrator per stream; keeps a bounded spool directory and the
    history of reports (newest last)."""

    directory: str | None = None
    bus: Any = None  # repro.elastic.MetricsBus | None
    label: str | None = None
    keep_last: int = 2  # spools retained for post-mortems
    reports: list[MigrationReport] = field(default_factory=list)
    _seq: int = 0

    _owns_dir: bool = False

    def _spool_root(self) -> str:
        if self.directory is None:
            self.directory = tempfile.mkdtemp(prefix="repro-state-migrations-")
            self._owns_dir = True
        else:
            os.makedirs(self.directory, exist_ok=True)
        return self.directory

    def cleanup(self) -> None:
        """Remove the spool directory if this migrator created it (a
        caller-provided ``directory`` is left alone). Safe to call
        repeatedly; a later migrate() just spools afresh."""
        if self._owns_dir and self.directory is not None:
            shutil.rmtree(self.directory, ignore_errors=True)
            self.directory = None
            self._owns_dir = False

    def plan(self, store: PartitionedStateStore,
             new_owners: Sequence[Any]) -> tuple[dict[int, Any], list[int]]:
        """The new assignment and the partitions a migration would move."""
        owners = list(new_owners) or [LOCAL_OWNER]
        new = range_assignment(store.n_partitions, owners)
        return new, moved_partitions(store.assignment, new)

    def migrate(self, store: PartitionedStateStore,
                new_owners: Sequence[Any]) -> MigrationReport:
        """Quiesced-caller contract: the store must not be mutated while
        this runs (ContinuousStream holds its state lock around the call)."""
        t0 = time.perf_counter()
        from_owners = tuple(store.owners)
        new, moved = self.plan(store, new_owners)
        seq = self._seq
        self._seq += 1

        # snapshot: serialize only the diff, spool atomically
        payloads = {pid: serialize_partition(store.partitions[pid]) for pid in moved}
        spool = ""
        if payloads:
            spool = os.path.join(self._spool_root(), f"migration_{seq:06d}")
            with atomic_dir(spool) as tmp:
                for pid, data in payloads.items():
                    with open(os.path.join(tmp, f"p{pid:05d}.bin"), "wb") as f:
                        f.write(data)

        # reassign, then restore from the spool (not from the live objects:
        # moved state must survive the full serde round trip)
        store.assignment = new
        moved_records = 0
        for pid in moved:
            with open(os.path.join(spool, f"p{pid:05d}.bin"), "rb") as f:
                part = deserialize_partition(f.read())
            assert part.pid == pid
            store.partitions[pid] = part
            moved_records += part.buffered_records

        self._gc_spools()
        report = MigrationReport(
            seq=seq,
            from_owners=from_owners,
            to_owners=tuple(list(new_owners) or [LOCAL_OWNER]),
            moved=tuple(moved),
            n_partitions=store.n_partitions,
            bytes_moved=sum(len(d) for d in payloads.values()),
            buffered_records_moved=moved_records,
            duration_ms=(time.perf_counter() - t0) * 1e3,
            spool_path=spool,
        )
        self.reports.append(report)
        if self.bus is not None:
            labels = {} if self.label is None else {"stream": self.label}
            self.bus.publish("state.migrated_partitions", len(moved), **labels)
            self.bus.publish("state.migration_ms", report.duration_ms, **labels)
            self.bus.publish("state.bytes_moved", report.bytes_moved, **labels)
        return report

    def _gc_spools(self) -> None:
        if self.directory is None or not os.path.isdir(self.directory):
            return
        spools = sorted(
            n for n in os.listdir(self.directory)
            if n.startswith("migration_") and not n.endswith(".tmp")
        )
        for name in spools[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

"""`repro-pipeline` — run and validate declarative pipeline specs.

    repro-pipeline validate spec.json [--import mymodule]
    repro-pipeline run spec.json --devices 8 [--duration 10] [--share 2]

(or ``python -m repro.pipeline ...`` without installing the console script.)

``validate`` rehydrates the builder from the JSON spec and prints the
builder's **full** error list — the same checks ``Pipeline.build()`` runs,
so a spec that validates here will provision. ``--import`` loads modules
first so custom processors/sources/sinks registered at import time are
known to the validator (and the runner).
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time

from repro.pipeline.builder import Pipeline
from repro.pipeline.spec import PipelineSpec


def _load_spec(path: str) -> PipelineSpec:
    with open(path) as f:
        return PipelineSpec.from_dict(json.load(f))


def _import_modules(mods: list[str]) -> None:
    for m in mods:
        importlib.import_module(m)


def _validate(spec: PipelineSpec) -> list[str]:
    return Pipeline.from_spec(spec).validate()


def cmd_validate(args: argparse.Namespace) -> int:
    _import_modules(args.imports)
    spec = _load_spec(args.spec)
    errors = _validate(spec)
    if errors:
        print(f"invalid pipeline {spec.name!r} ({len(errors)} problem"
              f"{'s' if len(errors) != 1 else ''}):", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    n_el = sum(1 for s in spec.stages if s.elastic is not None)
    print(f"{args.spec}: pipeline {spec.name!r} OK "
          f"({len(spec.broker.topics)} topics, {len(spec.sources)} sources, "
          f"{len(spec.stages)} stages [{n_el} elastic], "
          f"{len(spec.sinks)} sinks"
          f"{', elastic broker' if spec.broker.elastic else ''})")
    return 0


def cmd_run(args: argparse.Namespace) -> int:
    _import_modules(args.imports)
    spec = _load_spec(args.spec)
    errors = _validate(spec)
    if errors:
        print(f"invalid pipeline {spec.name!r}:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    finite = all(s.total_messages is not None for s in spec.sources) and spec.sources
    with spec.run(devices=args.devices, share=args.share) as run:
        t0 = time.monotonic()
        next_report = args.report_every
        try:
            while True:
                elapsed = time.monotonic() - t0
                if args.duration is not None and elapsed >= args.duration:
                    break
                time.sleep(0.25)  # poll fast, print at --report-every cadence
                lags = {s.name: run.lag(s.name) for s in spec.stages}
                if elapsed >= next_report:
                    next_report += args.report_every
                    devs = {n: c.devices for n, c in run.controllers.items()}
                    print(f"t={elapsed:6.1f}s  lag={lags}"
                          + (f"  devices={devs}" if devs else ""))
                # early exit only when finite sources have actually drained
                # their quotas AND consumers caught up — lag alone reads 0
                # whenever consumers merely keep pace with production
                if (finite and run.sources_finished
                        and all(v == 0 for v in lags.values())):
                    break
        except KeyboardInterrupt:
            pass
        for s in spec.stages:
            st = run.stream(s.name).stats
            records = getattr(st, "records", 0)
            print(f"stage {s.name!r}: {records} records")
    if run.errors:
        for e in run.errors:
            print(f"teardown error: {e!r}", file=sys.stderr)
        return 1
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-pipeline",
        description="Run/validate declarative streaming-pipeline specs "
                    "(repro.pipeline; see docs/pipeline.md)",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    val = sub.add_parser("validate", help="check a spec, print every problem")
    val.add_argument("spec", help="path to a PipelineSpec JSON file")
    val.add_argument("--import", dest="imports", action="append", default=[],
                     metavar="MODULE",
                     help="import MODULE first (registers custom "
                          "processors/sources/sinks); repeatable")
    val.set_defaults(fn=cmd_validate)

    runp = sub.add_parser("run", help="provision and run a spec")
    runp.add_argument("spec", help="path to a PipelineSpec JSON file")
    runp.add_argument("--devices", type=int, default=None,
                      help="device-pool size (default: all local devices)")
    runp.add_argument("--duration", type=float, default=10.0,
                      help="seconds to run (finite sources may stop earlier); "
                           "default 10")
    runp.add_argument("--share", type=float, default=None,
                      help="override the spec's pipeline-level fair-share weight")
    runp.add_argument("--report-every", type=float, default=1.0,
                      help="seconds between progress lines")
    runp.add_argument("--import", dest="imports", action="append", default=[],
                      metavar="MODULE", help="import MODULE first; repeatable")
    runp.set_defaults(fn=cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

"""PipelineRun — one call from spec to a live, elastic pipeline.

Implements the declarative layer purely on top of the imperative API
(``PilotComputeService`` / engine plugins / ``repro.elastic``): nothing the
runner does is impossible by hand, it just encodes the ordering and wiring
that every hand-written example used to repeat.

Start order (dependencies first)::

    service -> broker pilot -> topics -> engine pilots -> sinks
            -> streams -> controllers -> sources -> rate scenarios

Teardown runs the exact reverse, even when ``start()`` fails half-way or a
stage dies mid-run: every component is pushed onto a stack as it comes up,
and ``stop()`` pops the stack, recording (not raising) per-component
errors so one wedged component cannot leak the pilots behind it.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

import time

from repro.broker.consumer import Consumer, ConsumerGroup
from repro.broker.producer import Producer
from repro.core import PilotComputeService
from repro.elastic import (
    ElasticConfig,
    ElasticController,
    MetricsBus,
    PreemptionHooks,
)
from repro.pipeline import registry
from repro.pipeline.spec import ElasticSpec, PipelineSpec, SinkSpec, StageSpec
from repro.scheduler import HOSTS, ResourceRequest
from repro.streaming.windows import SessionWindow, SlidingWindow, TumblingWindow


class BrokerStallProbe:
    """Differentiates the cluster's cumulative token-bucket stall seconds
    into a per-tick stall *fraction* — the broker controller's saturation
    signal (clamped to [0, 1]; concurrent producers can stall in
    parallel)."""

    def __init__(self, cluster):
        self.cluster = cluster
        self._t = time.monotonic()
        self._s = cluster.io_stall_seconds()

    def __call__(self) -> float:
        now, s = time.monotonic(), self.cluster.io_stall_seconds()
        dt = max(now - self._t, 1e-6)
        frac = (s - self._s) / dt
        self._t, self._s = now, s
        return min(max(frac, 0.0), 1.0)


class StageReconciler:
    """Pilot-crash recovery for continuous stages (docs/faults.md).

    Subscribes to the service's :class:`HeartbeatMonitor` failure
    callbacks; when a *managed* stage pilot goes stale — a real crash
    (``inject_failure``) or a false positive (the ``drop_heartbeats``
    fault) — it fences first and recovers second:

    1. ``stream.crash()`` — idempotent; after this the old incarnation
       cannot emit, so a false positive costs one recovery, never a
       duplicate firing;
    2. ``service.submit_pilot(pcd)`` — a replacement pilot on fresh
       devices;
    3. attach the stream to the new pilot's plugin and ``stream.
       recover()`` — state restored from the latest ``sckpt_*`` spool
       (``StageSpec.checkpoint_every``), consumer re-seeked, replay with
       emit suppression: zero lost, zero duplicated firings.

    Usable standalone (chaos tests bind it to hand-built streams) or via
    ``PipelineRun``, which manages every continuous stage that checkpoints.
    """

    def __init__(self, service: PilotComputeService, *, bus: MetricsBus | None = None,
                 on_recovered: Callable[[str, Any], None] | None = None):
        self.service = service
        self.bus = bus
        self.on_recovered = on_recovered
        self.recoveries = 0
        #: (stage name, recovery latency ms) per recovery, oldest first
        self.log: list[tuple[str, float]] = []
        #: recovery failures (kept, not raised — callbacks run on the
        #: monitor thread, which swallows exceptions)
        self.errors: list[BaseException] = []
        self._managed: dict[int, tuple[str, Any, dict]] = {}
        self._closed = False
        self._lock = threading.Lock()
        service.monitor.on_failure(self._on_failure)

    def manage(self, name: str, pilot: Any, stream: Any, pcd: dict) -> None:
        """Watch ``pilot``; on failure, reprovision from ``pcd`` and
        recover ``stream`` onto the replacement."""
        with self._lock:
            self._managed[id(pilot)] = (name, stream, dict(pcd))

    def unmanage(self, pilot: Any) -> None:
        with self._lock:
            self._managed.pop(id(pilot), None)

    def close(self) -> None:
        """Stop reconciling (the monitor keeps its callback — it just
        no-ops); teardown calls this before stopping streams so a stop
        is not mistaken for a crash."""
        with self._lock:
            self._closed = True
            self._managed.clear()

    def _on_failure(self, pilot: Any) -> None:
        with self._lock:
            if self._closed:
                return
            entry = self._managed.pop(id(pilot), None)
        if entry is None:
            return  # not ours (another run's pilot on a shared service)
        name, stream, pcd = entry
        t0 = time.perf_counter()
        try:
            stream.crash()  # fencing — safe and idempotent on a dead stream
            new_pilot = self.service.submit_pilot(pcd)
            plugin = new_pilot.plugin
            if hasattr(plugin, "streams") and stream not in plugin.streams:
                plugin.streams.append(stream)
            stream.recover()
        except BaseException as e:
            self.errors.append(e)
            return
        ms = (time.perf_counter() - t0) * 1e3
        self.recoveries += 1
        self.log.append((name, ms))
        if self.bus is not None:
            self.bus.publish("pipeline.stage_recoveries", self.recoveries,
                             stage=name)
            self.bus.publish("pipeline.stage_recovery_ms", ms, stage=name)
        self.manage(name, new_pilot, stream, pcd)
        if self.on_recovered is not None:
            self.on_recovered(name, new_pilot)


class SinkRunner:
    """Terminal consumer: drains a topic, applying a fn or collecting."""

    def __init__(self, spec: SinkSpec, cluster, fn: Callable | None):
        self.spec = spec
        self.items: list = []
        self._fn = fn
        group = ConsumerGroup(cluster, f"sink-{spec.name}", spec.topic)
        self._consumer = Consumer(cluster, group, member_id=f"sink-{spec.name}")
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.error: BaseException | None = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                msgs = self._consumer.poll(max_records=256, timeout=0.05)
                for m in msgs:
                    if self._fn is not None:
                        self._fn(m)
                    else:
                        self.items.append(m.value)
                if msgs:
                    self._consumer.commit()
            except BaseException as e:
                self.error = e
                return

    def start(self) -> "SinkRunner":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self.error is not None:  # surfaced into PipelineRun.errors
            raise self.error


def _make_assigner(window: dict):
    kind = window.get("window", "tumbling")
    if kind == "tumbling":
        return TumblingWindow(window.get("size", 1.0))
    if kind == "sliding":
        return SlidingWindow(window.get("size", 1.0), window.get("slide", 0.5))
    return SessionWindow(window.get("gap", 1.0))


class PipelineRun:
    """Context manager around one provisioned pipeline.

    ``with spec.run(devices=8) as run:`` starts everything; leaving the
    block (or calling :meth:`stop`, which is idempotent) tears down in
    reverse order. Pass an existing ``service`` to share a device pool with
    other pipelines; the run then only cancels the pilots *it* created.
    """

    def __init__(self, spec: PipelineSpec, *, service: PilotComputeService | None = None,
                 devices: int | list | None = None, bus: MetricsBus | None = None,
                 share: float | None = None):
        self.spec = spec
        self.bus = bus or MetricsBus()
        self._own_service = service is None
        if service is None:
            devs = list(range(devices)) if isinstance(devices, int) else devices
            service = PilotComputeService(devices=devs, metrics=self.bus)
        self.service = service
        #: pipeline-level fair-share weight (spec.share unless overridden);
        #: every stage request carries ``share * stage.share``
        self.share = spec.share if share is None else share
        #: the service's single ResourceArbiter — set during provisioning
        #: iff any stage (or the broker) is elastic
        self.arbiter = None
        #: pilot-crash recovery — set during provisioning iff any
        #: continuous stage checkpoints (StageSpec.checkpoint_every)
        self.reconciler: StageReconciler | None = None
        self.cluster = None
        self._streams: dict[str, Any] = {}
        self._pilots: dict[str, Any] = {}
        self._controllers: dict[str, ElasticController] = {}
        self._sources: dict[str, list] = {}  # topic -> sources, spec order
        self._scenarios: dict[str, list] = {}
        self._sinks: dict[str, SinkRunner] = {}
        self._processors: dict[str, Any] = {}
        #: LIFO of (label, stop_callable) — teardown pops from the end
        self._teardown: list[tuple[str, Callable[[], None]]] = []
        #: labels in the order components were torn down (tests assert this)
        self.teardown_log: list[str] = []
        #: component errors collected during stop() — never raised there
        self.errors: list[BaseException] = []
        self._started = False
        self._stopped = False
        self._lock = threading.Lock()

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "PipelineRun":
        if self._started:
            return self
        self._started = True
        try:
            self._provision()
        except BaseException:
            # unwind whatever came up before the failure, then re-raise
            self.stop()
            raise
        return self

    def __enter__(self) -> "PipelineRun":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    def stop(self) -> None:
        """Reverse-order teardown; safe to call twice (second call no-ops)."""
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
            steps, self._teardown = list(self._teardown), []
        for label, fn in reversed(steps):
            try:
                fn()
            except BaseException as e:
                self.errors.append(e)
            finally:
                self.teardown_log.append(label)

    # -- provisioning (start order = spec dependency order) --------------------

    def _push(self, label: str, stop_fn: Callable[[], None]) -> None:
        self._teardown.append((label, stop_fn))

    def _provision(self) -> None:
        spec = self.spec
        if self._own_service:
            self._push("service", self.service.cancel)

        # one arbiter per *service*: every run sharing the pool files its
        # requests here, so contention resolves by weight/priority instead
        # of first-come-first-served. Refcounted — the loop stops when the
        # last run releases it.
        if spec.broker.elastic is not None or any(
            s.elastic is not None for s in spec.stages
        ):
            self.arbiter = self.service.get_arbiter(self.bus).retain()
            self._push("arbiter", self.arbiter.release)

        broker_pilot = self.service.submit_pilot({
            "number_of_nodes": spec.broker.nodes,
            "type": spec.broker.framework,
            "io_rate_per_node": spec.broker.io_rate_per_node,
        })
        self._pilots["__broker__"] = broker_pilot
        if not self._own_service:
            self._push("broker", broker_pilot.cancel)
        self.cluster = broker_pilot.get_context()
        self.cluster.metrics = self.bus  # broker.failovers/lost_records
        if spec.broker.transport == "shm":
            # mount the zero-copy data plane before any topic carries data;
            # ring allocator stall joins io_stall_seconds, so the broker
            # saturation probe (and elasticity) needs no special casing
            from repro.transport import ShmTransport

            transport = ShmTransport(**dict(spec.broker.transport_options))
            self.cluster.attach_transport(transport)
            self._push("transport", transport.close)
        for topic, parts in spec.broker.topics.items():
            self.cluster.create_topic(
                topic, parts,
                replication_factor=min(spec.broker.replication_factor,
                                       spec.broker.nodes))
            if spec.broker.transport == "shm":
                self.cluster.transport.mount(topic)

        # host stages before their co-located guests (a guest reuses the
        # host's pilot, so the host must exist first)
        ordered = [s for s in spec.stages if s.colocate_with is None] + [
            s for s in spec.stages if s.colocate_with is not None
        ]
        for stage in ordered:
            self._provision_stage(stage)

        for sink in spec.sinks:
            fn = None if sink.kind == "collect" else registry.resolve_sink(sink.kind)
            runner = SinkRunner(sink, self.cluster, fn)
            self._sinks[sink.name] = runner
            runner.start()
            self._push(f"sink:{sink.name}", runner.stop)

        for stage in spec.stages:
            stream = self._streams[stage.name]
            stream.start()
            self._push(f"stream:{stage.name}", stream.stop)

        recoverable = [
            s for s in spec.stages
            if s.engine == "continuous" and s.checkpoint_every
            and s.colocate_with is None
        ]
        if recoverable:
            self.reconciler = StageReconciler(
                self.service, bus=self.bus,
                on_recovered=lambda name, pilot: self._pilots.__setitem__(
                    name, pilot))
            for stage in recoverable:
                self.reconciler.manage(
                    stage.name, self._pilots[stage.name],
                    self._streams[stage.name],
                    {"number_of_nodes": stage.nodes,
                     "cores_per_node": stage.cores_per_node,
                     "type": "flink"})
            self._push("reconciler", self.reconciler.close)

        for stage in spec.stages:
            if stage.elastic is not None:
                ctl = self._make_controller(stage)
                self._controllers[stage.name] = ctl
                ctl.start()
                self._push(f"controller:{stage.name}", ctl.shutdown)

        if spec.broker.elastic is not None:
            ctl = self._make_broker_controller(spec.broker.elastic)
            self._controllers["__broker__"] = ctl
            ctl.start()
            self._push("controller:__broker__", ctl.shutdown)

        for src_spec in spec.sources:
            source, scenario = self._make_source(src_spec)
            self._sources.setdefault(src_spec.topic, []).append(source)
            source.start()
            self._push(f"source:{src_spec.topic}", source.stop)
            if scenario is not None:
                self._scenarios.setdefault(src_spec.topic, []).append(scenario)
                scenario.start()
                self._push(f"scenario:{src_spec.topic}", scenario.stop)

    def _provision_stage(self, stage: StageSpec) -> None:
        if stage.colocate_with is not None:
            # spec-level placement: the guest rides the host's pilot (and
            # its rescales); the host owns provisioning and teardown
            pilot = self._pilots[stage.colocate_with]
            self._pilots[stage.name] = pilot
        else:
            framework = "spark" if stage.engine == "microbatch" else "flink"
            pilot = self.service.submit_pilot({
                "number_of_nodes": stage.nodes,
                "cores_per_node": stage.cores_per_node,
                "type": framework,
            })
            self._pilots[stage.name] = pilot
            if not self._own_service:
                self._push(f"pilot:{stage.name}", pilot.cancel)
        ctx = pilot.get_context()
        proc = registry.make_processor(
            stage.processor, dict(stage.options), metrics=self.bus)
        self._processors[stage.name] = proc
        # topic alone is ambiguous when two stages consume the same topic,
        # and topic/group alone is ambiguous when two *pipelines* share a
        # bus (the multi-tenant case) — qualify with the pipeline name so
        # each controller only ever reads its own stage's gauges
        label = f"{self.spec.name}/{stage.topic}/{stage.consumer_group}"

        if stage.engine == "microbatch":
            process_fn = proc.process if hasattr(proc, "process") else proc
            on_rescale = getattr(proc, "on_rescale", None)
            sync_fn = getattr(proc, "sync", None)
            if stage.emits:
                process_fn = self._emitting(process_fn, stage.output_topic)
            stream = ctx.stream(
                self.cluster, stage.topic,
                group=stage.consumer_group,
                process_fn=process_fn,
                batch_interval=stage.batch_interval,
                max_batch_records=stage.max_batch_records,
                backpressure=stage.backpressure,
                metrics=self.bus,
                sync_fn=sync_fn,
                on_rescale=on_rescale,
                metrics_label=label,
                transport=stage.transport,
            )
        else:
            window_fn = proc.process if hasattr(proc, "process") else proc
            stream = ctx.stream(
                self.cluster, stage.topic,
                group=stage.consumer_group,
                assigner=_make_assigner(stage.window),
                window_fn=window_fn,
                allowed_lateness=stage.window.get("allowed_lateness", 0.0),
                metrics=self.bus,
                # rescale sync barrier auto-wires from a bound window_fn's
                # .sync, same as the micro-batch engine
                on_rescale=getattr(proc, "on_rescale", None),
                metrics_label=label,
                n_partitions=stage.state_partitions,
                executor=stage.executor,
                checkpoint_every=stage.checkpoint_every,
                transport=stage.transport,
                async_emit=stage.async_emit,
            )
        self._streams[stage.name] = stream

    def _emitting(self, fn: Callable, topic: str) -> Callable:
        """Wrap a ``(state, msgs) -> (state, outputs)`` processor so outputs
        land on the stage's output topic."""
        producer = Producer(self.cluster, topic, serializer="npy")

        def wrapped(state, msgs):
            state, outs = fn(state, msgs)
            for out in outs or ():
                producer.send(out)
            return state

        return wrapped

    def _request_name(self, component: str) -> str:
        return f"{self.spec.name}/{component}"

    def _make_controller(self, stage: StageSpec) -> ElasticController:
        el = stage.elastic
        params = dict(el.params)
        if el.policy == "latency":
            params.setdefault("batch_interval", stage.batch_interval)
        policy = registry.resolve_policy(el.policy)(**params)
        stream = self._streams[stage.name]
        # no colocate hint on the request: an elastic stage is never a
        # co-location guest (builder-validated), so spec-level placement is
        # entirely the pilot sharing done in _provision_stage
        request = ResourceRequest(
            name=self._request_name(stage.name),
            min_devices=el.min_devices,
            max_devices=el.max_devices,
            weight=stage.share * self.share,
            priority=stage.priority,
        )
        return ElasticController(
            self.service, self._pilots[stage.name], self.bus, policy,
            config=ElasticConfig(
                interval=el.interval, min_devices=el.min_devices,
                max_devices=el.max_devices,
                devices_per_step=el.devices_per_step, cooldown=el.cooldown,
                migration_cost_frac=el.migration_cost_frac,
            ),
            lag_probe=lambda: sum(stream.lag().values()),
            # scope the controller's snapshot to this stage's stream gauges
            # (the bus is shared by every stage in the pipeline)
            stream=stream.metrics_label,
            arbiter=self.arbiter,
            request=request,
            hooks=(self._make_preemption_hooks(stage, stream)
                   if el.preemptible else None),
        )

    def _make_preemption_hooks(self, stage: StageSpec, stream) -> PreemptionHooks:
        """Checkpoint-then-kill wiring for a preemptible stage (builder
        guarantees: continuous engine, checkpoint_every > 0,
        min_devices == 0). The kill hook detaches the stream from its
        plugin *before* the controller cancels the pilots — a plugin-driven
        ``stream.stop()`` would delete the sckpt spools the resume needs —
        and unmanages the pilot so the reconciler cannot mistake the
        deliberate cancel for a crash."""
        name = stage.name
        pcd = {"number_of_nodes": stage.nodes,
               "cores_per_node": stage.cores_per_node, "type": "flink"}

        def checkpoint() -> None:
            stream.checkpoint()

        def kill() -> None:
            pilot = self._pilots[name]
            plugin = getattr(pilot, "plugin", None)
            if plugin is not None and stream in getattr(plugin, "streams", ()):
                plugin.streams.remove(stream)
            if self.reconciler is not None:
                self.reconciler.unmanage(pilot)
            stream.crash()

        def resume(pilot) -> None:
            plugin = pilot.plugin
            if hasattr(plugin, "streams") and stream not in plugin.streams:
                plugin.streams.append(stream)
            stream.recover()
            # the replacement pilot may hold different device ids than the
            # parked one (that's the whole point of preemption): re-home the
            # restored state onto the new owner set
            devs = list(getattr(plugin, "devices", []) or [])
            if devs:
                stream.rescale(devs)
            self._pilots[name] = pilot
            if self.reconciler is not None:
                self.reconciler.manage(name, pilot, stream, pcd)

        return PreemptionHooks(checkpoint, kill, resume)

    def _make_broker_controller(self, el: ElasticSpec) -> ElasticController:
        """Spec-driven broker elasticity: a node-unit controller estimates
        demand from the producer token-bucket saturation signal; arbiter
        grants become ``BrokerCluster.add_node/remove_node`` via extension
        pilots on the broker pilot — no direct ``add_node`` calls here."""
        label = self._request_name("__broker__")
        policy = registry.resolve_policy(el.policy)(**dict(el.params))
        request = ResourceRequest(
            name=label,
            min_devices=el.min_devices,
            max_devices=el.max_devices,
            weight=self.share,
            unit=HOSTS,
        )
        return ElasticController(
            self.service, self._pilots["__broker__"], self.bus, policy,
            config=ElasticConfig(
                interval=el.interval, min_devices=el.min_devices,
                max_devices=el.max_devices,
                devices_per_step=el.devices_per_step, cooldown=el.cooldown,
            ),
            probes={"broker.stall_frac": BrokerStallProbe(self.cluster)},
            stream=label,
            unit="nodes",
            arbiter=self.arbiter,
            request=request,
        )

    def _make_source(self, src) -> tuple:
        from repro.miniapps import RateStepScenario, SourceConfig

        factory = registry.resolve_source(src.kind)
        config = SourceConfig(
            src.topic, rate_msgs_per_s=src.rate_msgs_per_s,
            total_messages=src.total_messages, n_producers=src.n_producers,
            seed=src.seed,
        )
        source = factory(self.cluster, config, **dict(src.options))
        scenario = None
        if src.rate_schedule:
            scenario = RateStepScenario(source, [tuple(s) for s in src.rate_schedule])
        return source, scenario

    # -- accessors ------------------------------------------------------------

    def stream(self, stage: str):
        return self._streams[stage]

    def processor(self, stage: str):
        return self._processors[stage]

    def controller(self, stage: str) -> ElasticController:
        return self._controllers[stage]

    def source(self, topic: str, index: int = 0):
        """The ``index``-th source feeding ``topic`` (spec order) — a topic
        may have several producer groups."""
        return self._sources[topic][index]

    def scenario(self, topic: str, index: int = 0):
        return self._scenarios[topic][index]

    def sink(self, name: str) -> SinkRunner:
        return self._sinks[name]

    @property
    def controllers(self) -> dict[str, ElasticController]:
        """Live controllers by stage name (plus ``__broker__``) — the
        public view the CLI's progress loop reads."""
        return dict(self._controllers)

    @property
    def sources_finished(self) -> bool:
        """True once every (finite) source has produced its quota."""
        return all(
            src.finished for srcs in self._sources.values() for src in srcs
        )

    def pilot(self, stage: str):
        return self._pilots[stage]

    @property
    def broker_pilot(self):
        """The broker's pilot — parent for manual extension pilots
        (paper Listing 4)."""
        return self._pilots["__broker__"]

    @property
    def broker_controller(self) -> ElasticController:
        """The node-unit controller created by ``BrokerSpec.elastic``."""
        return self._controllers["__broker__"]

    def await_batches(self, stage: str, n: int, timeout: float = 60.0) -> None:
        self._streams[stage].await_batches(n, timeout=timeout)

    def await_windows(self, stage: str, n: int, timeout: float = 30.0) -> None:
        self._streams[stage].await_windows(n, timeout=timeout)

    def lag(self, stage: str) -> float:
        return float(sum(self._streams[stage].lag().values()))

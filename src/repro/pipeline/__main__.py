"""``python -m repro.pipeline`` — see :mod:`repro.pipeline.cli`."""
import sys

from repro.pipeline.cli import main

sys.exit(main())

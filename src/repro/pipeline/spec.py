"""Declarative pipeline topology — the paper's composition pitch made data.

A :class:`PipelineSpec` is a frozen, JSON-serializable description of one
streaming pipeline: broker sizing, topics, sources, processing stages
(micro-batch or continuous) chained topic -> topic, sinks, and per-stage
elasticity policy. It describes *what* to run; the builder
(:mod:`repro.pipeline.builder`) checks it, and the runner
(:mod:`repro.pipeline.runner`) turns it into pilots, streams and
controllers through the existing imperative API.

Callables (custom processors, sources, sinks) are referenced by *name*
through :mod:`repro.pipeline.registry`, so a spec round-trips losslessly:
``PipelineSpec.from_dict(spec.to_dict()) == spec``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, fields, replace
from typing import Any, Mapping


def _freeze_options(opts: Mapping[str, Any] | None) -> dict:
    """Shallow-copy option mappings so frozen specs don't alias caller dicts."""
    return dict(opts or {})


@dataclass(frozen=True)
class BrokerSpec:
    """The broker pilot: node count, topic layout, and (optionally) its own
    elasticity. With ``elastic`` set, a node-unit controller watches the
    producer token-bucket saturation signal (``broker.stall_frac``) and
    drives ``BrokerCluster.add_node/remove_node`` through the arbiter —
    application code never calls ``add_node`` itself."""

    nodes: int = 1
    framework: str = "kafka"
    #: topic name -> partition count
    topics: dict = field(default_factory=dict)
    #: per-node byte-rate budget (None = unlimited), paper's 1-broker bottleneck
    io_rate_per_node: float | None = None
    #: replicas per topic partition (leader + followers on distinct nodes,
    #: acks=all): >= 2 makes acked records survive a broker-node loss with
    #: automatic leader failover; see docs/faults.md
    replication_factor: int = 1
    #: data plane: "log" (payloads in the partition log, the seed behavior)
    #: or "shm" (a shared-memory ring is mounted per topic and rf==1
    #: payloads travel as zero-copy slot handles; docs/transport.md). With
    #: rf > 1 the shm plane transparently copies out per record.
    transport: str = "log"
    #: ShmTransport kwargs (slot_bytes, n_slots) when transport == "shm"
    transport_options: dict = field(default_factory=dict)
    #: node-unit ElasticSpec (min_devices/max_devices count broker *nodes*)
    elastic: "ElasticSpec | None" = None

    def __post_init__(self):
        object.__setattr__(self, "transport_options",
                           _freeze_options(self.transport_options))


@dataclass(frozen=True)
class SourceSpec:
    """One MASS-style producer group feeding a topic.

    ``kind`` names a factory in the source registry — the built-in
    ``repro.miniapps.SOURCES`` kinds ("cluster", "static", "lightsource",
    "tokens") plus anything registered via ``repro.pipeline.register_source``.
    """

    topic: str
    kind: str = "cluster"
    rate_msgs_per_s: float | None = None
    total_messages: int | None = None
    n_producers: int = 1
    seed: int = 0
    #: factory kwargs beyond SourceConfig (e.g. n_clusters, dim)
    options: dict = field(default_factory=dict)
    #: optional [(duration_s, rate), ...] driven by a RateStepScenario
    rate_schedule: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "options", _freeze_options(self.options))
        object.__setattr__(
            self, "rate_schedule", tuple(tuple(s) for s in self.rate_schedule)
        )


@dataclass(frozen=True)
class ElasticSpec:
    """Per-stage elasticity: which policy watches the bus, and the
    controller's clamps. ``policy`` is one of POLICIES in
    :mod:`repro.pipeline.registry` ("threshold", "pid", "binpack",
    "latency", "slo"); ``params`` are the policy's constructor kwargs."""

    policy: str = "threshold"
    params: dict = field(default_factory=dict)
    interval: float = 0.5
    min_devices: int = 1
    max_devices: int | None = None
    devices_per_step: int = 1
    cooldown: float = 1.0
    #: hold rescales while the last keyed-state migration is still
    #: amortizing (see ``ElasticConfig.migration_cost_frac``); None = off
    migration_cost_frac: float | None = None
    #: opt the stage into checkpoint-then-kill preemption: when the arbiter
    #: drives it to zero devices, the runner checkpoints the stream, fences
    #: it and cancels the whole pilot (base included); the next grant
    #: resubmits the pilot and resumes from the pre-kill spool. Requires
    #: the continuous engine, ``checkpoint_every > 0`` and
    #: ``min_devices == 0`` (builder-validated); see docs/scheduler.md
    preemptible: bool = False

    def __post_init__(self):
        object.__setattr__(self, "params", _freeze_options(self.params))


@dataclass(frozen=True)
class StageSpec:
    """One processing stage: engine pilot + stream consuming ``topic``.

    ``processor`` names a factory in the processor registry — the built-in
    ``repro.miniapps.PROCESSORS`` ("kmeans", "gridrec", "mlem", "lm_train",
    "lm_serve") or anything registered via
    ``repro.pipeline.register_processor`` (including plain
    ``(state, msgs) -> state`` functions). When ``emits`` is true the
    processor returns ``(state, outputs)`` and outputs are produced to
    ``output_topic``.
    """

    name: str
    topic: str
    processor: str
    engine: str = "microbatch"  # "microbatch" | "continuous"
    nodes: int = 1
    cores_per_node: int = 1
    group: str | None = None  # consumer group (default: stage name)
    output_topic: str | None = None
    emits: bool = False
    # micro-batch knobs
    batch_interval: float = 0.5
    max_batch_records: int = 4096
    backpressure: bool = True
    # continuous knobs: {"window": "tumbling"|"sliding"|"session", "size": s,
    # "slide": s, "gap": s, "allowed_lateness": s}
    window: dict = field(default_factory=dict)
    #: size of the keyed-state partition ring (continuous engine only) —
    #: rescales migrate whole partitions, so more partitions = finer-grained
    #: (but chattier) state movement; see docs/state.md
    state_partitions: int = 64
    #: continuous engine execution mode: "inline" (in-process, the
    #: default) or "mp" (one supervised worker process per owner device,
    #: failure isolation + restart with state recovery; docs/workers.md)
    executor: str = "inline"
    #: records between crash checkpoints (continuous engine): > 0 spools
    #: full-stream checkpoints so a crashed stage pilot is reprovisioned by
    #: the StageReconciler and resumes mid-stream (docs/faults.md); 0 = off
    checkpoint_every: int = 0
    #: stage-side transport opt-in: "shm" puts a micro-batch stage's
    #: consumer in zero-copy mode (frame views, sound because the batch is
    #: fully processed before commit); None inherits safe copy-out.
    #: Requires broker.transport == "shm".
    transport: str | None = None
    #: continuous engine only: depth of the emit double-buffer — fired
    #: windows are produced downstream asynchronously so host-side routing
    #: overlaps device compute (docs/perf.md); 0 = synchronous emits
    async_emit: int = 0
    #: processor factory kwargs
    options: dict = field(default_factory=dict)
    elastic: ElasticSpec | None = None
    # arbitration attributes (repro.scheduler): strict priority tier,
    # proportional weight within a tier, and a placement hint
    priority: int = 0
    share: float = 1.0
    #: run on the same pilot as the named stage instead of provisioning a
    #: fresh one (spec-level co-location; engines must match)
    colocate_with: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "options", _freeze_options(self.options))
        object.__setattr__(self, "window", _freeze_options(self.window))

    @property
    def consumer_group(self) -> str:
        return self.group or self.name


@dataclass(frozen=True)
class SinkSpec:
    """A terminal consumer draining ``topic``. ``kind`` is "collect"
    (records kept on ``PipelineRun.sink(name).items``) or a registered
    sink callable applied per message."""

    name: str
    topic: str
    kind: str = "collect"
    options: dict = field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "options", _freeze_options(self.options))


@dataclass(frozen=True)
class PipelineSpec:
    """The whole topology. Construct via the fluent builder
    (``Pipeline.named(...)``) which validates before instantiating."""

    name: str
    broker: BrokerSpec = field(default_factory=BrokerSpec)
    sources: tuple = ()
    stages: tuple = ()
    sinks: tuple = ()
    #: pipeline-level fair-share weight: several runs on one service split
    #: contended devices proportionally to their shares (stage requests
    #: carry ``pipeline.share * stage.share``)
    share: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "sources", tuple(self.sources))
        object.__setattr__(self, "stages", tuple(self.stages))
        object.__setattr__(self, "sinks", tuple(self.sinks))

    # -- accessors ------------------------------------------------------------

    def stage(self, name: str) -> StageSpec:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"no stage named {name!r}")

    @property
    def topics(self) -> dict:
        return dict(self.broker.topics)

    # -- serde ---------------------------------------------------------------

    def to_dict(self) -> dict:
        return _to_dict(self)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PipelineSpec":
        d = dict(d)
        b = dict(d.pop("broker", {}))
        bel = b.pop("elastic", None)
        broker = BrokerSpec(**b, elastic=ElasticSpec(**bel) if bel is not None else None)
        sources = tuple(SourceSpec(**s) for s in d.pop("sources", ()))
        stages = []
        for s in d.pop("stages", ()):
            s = dict(s)
            el = s.pop("elastic", None)
            stages.append(
                StageSpec(**s, elastic=ElasticSpec(**el) if el is not None else None)
            )
        sinks = tuple(SinkSpec(**s) for s in d.pop("sinks", ()))
        return cls(broker=broker, sources=sources, stages=tuple(stages),
                   sinks=sinks, **d)

    @classmethod
    def from_json(cls, text: str) -> "PipelineSpec":
        return cls.from_dict(json.loads(text))

    # -- runner entry point ---------------------------------------------------

    def run(self, **kw):
        """Provision and start the pipeline; see
        :class:`repro.pipeline.runner.PipelineRun`."""
        from repro.pipeline.runner import PipelineRun

        return PipelineRun(self, **kw)


def _to_dict(obj: Any) -> Any:
    """Dataclass -> plain JSON-able structures (tuples become lists)."""
    if hasattr(obj, "__dataclass_fields__"):
        out = {}
        for f in fields(obj):
            v = getattr(obj, f.name)
            if v is None and f.name == "elastic":
                out[f.name] = None
            else:
                out[f.name] = _to_dict(v)
        return out
    if isinstance(obj, (list, tuple)):
        return [_to_dict(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _to_dict(v) for k, v in obj.items()}
    return obj


def with_elastic(stage: StageSpec, elastic: ElasticSpec) -> StageSpec:
    """Frozen-friendly update used by the builder."""
    return replace(stage, elastic=elastic)

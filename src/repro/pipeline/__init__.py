"""Declarative pipeline API (paper §4: one abstraction over broker, engine
and resource management).

Three layers, lowest first:

* :mod:`repro.pipeline.spec` — frozen, JSON-round-trippable topology
  (``PipelineSpec`` and friends);
* :mod:`repro.pipeline.builder` — fluent ``Pipeline.named(...)`` builder
  with build-time validation;
* :mod:`repro.pipeline.runner` — ``PipelineRun``, the context manager that
  provisions pilots/topics/streams/controllers from a spec and tears them
  down in reverse order.

The imperative API underneath is unchanged; see docs/pipeline.md.
"""
from repro.pipeline.builder import Pipeline, PipelineValidationError
from repro.pipeline.registry import (
    POLICIES,
    register_processor,
    register_sink,
    register_source,
)
from repro.pipeline.runner import PipelineRun, SinkRunner
from repro.pipeline.spec import (
    BrokerSpec,
    ElasticSpec,
    PipelineSpec,
    SinkSpec,
    SourceSpec,
    StageSpec,
)

__all__ = [
    "BrokerSpec",
    "ElasticSpec",
    "POLICIES",
    "Pipeline",
    "PipelineRun",
    "PipelineSpec",
    "PipelineValidationError",
    "SinkRunner",
    "SinkSpec",
    "SourceSpec",
    "StageSpec",
    "register_processor",
    "register_sink",
    "register_source",
]

"""Name -> factory registries backing the declarative specs.

Specs reference behavior (sources, processors, sinks, scaling policies) by
string so they stay serializable; this module resolves those strings. The
built-in MASS sources, MASA processors and elastic policies are pre-seeded;
``register_source`` / ``register_processor`` / ``register_sink`` add custom
entries, including plain functions.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.elastic.forecast import ForecastPolicy
from repro.elastic.policy import (
    BinPackingPolicy,
    BrokerSaturationPolicy,
    LatencyPolicy,
    PIDScalingPolicy,
    SLOPolicy,
    ThresholdHysteresisPolicy,
)

#: policy name (ElasticSpec.policy) -> ScalingPolicy class
POLICIES: dict[str, type] = {
    "threshold": ThresholdHysteresisPolicy,
    "pid": PIDScalingPolicy,
    "binpack": BinPackingPolicy,
    "latency": LatencyPolicy,
    "slo": SLOPolicy,
    "broker_saturation": BrokerSaturationPolicy,
    "forecast": ForecastPolicy,
}

_SOURCES: dict[str, Callable] = {}
_PROCESSORS: dict[str, Callable] = {}
_SINKS: dict[str, Callable] = {}


def register_source(name: str, factory: Callable | None = None):
    """Register a StreamSource factory ``(cluster, config, **options)``.
    Usable as a decorator: ``@register_source("mykind")``."""
    def deco(f):
        _SOURCES[name] = f
        return f
    return deco(factory) if factory is not None else deco


def register_processor(name: str, factory: Callable | None = None):
    """Register a stage processor. The factory may be

    * an app class/factory: ``factory(**options)`` returning an object with
      ``process(state, msgs)`` (MASA style), or
    * a plain ``(state, msgs) -> state`` function (``options`` must be
      empty) — what hand-written stages use.
    """
    def deco(f):
        _PROCESSORS[name] = f
        return f
    return deco(factory) if factory is not None else deco


def register_sink(name: str, fn: Callable | None = None):
    """Register a per-message sink callable ``fn(message)``."""
    def deco(f):
        _SINKS[name] = f
        return f
    return deco(fn) if fn is not None else deco


def _builtin_sources() -> dict:
    from repro.miniapps import SOURCES

    return dict(SOURCES)


def _builtin_processors() -> dict:
    from repro.miniapps import PROCESSORS

    return dict(PROCESSORS)


def resolve_source(kind: str) -> Callable:
    table = {**_builtin_sources(), **_SOURCES}
    if kind not in table:
        raise KeyError(
            f"unknown source kind {kind!r}; known: {sorted(table)} "
            "(register custom kinds via repro.pipeline.register_source)"
        )
    return table[kind]


def resolve_processor(name: str) -> Callable:
    table = {**_builtin_processors(), **_PROCESSORS}
    if name not in table:
        raise KeyError(
            f"unknown processor {name!r}; known: {sorted(table)} "
            "(register custom processors via repro.pipeline.register_processor)"
        )
    return table[name]


def resolve_sink(name: str) -> Callable:
    if name not in _SINKS:
        raise KeyError(
            f"unknown sink {name!r}; known: {sorted(_SINKS)} "
            "(register custom sinks via repro.pipeline.register_sink)"
        )
    return _SINKS[name]


def resolve_policy(name: str) -> type:
    if name not in POLICIES:
        raise KeyError(f"unknown elastic policy {name!r}; known: {sorted(POLICIES)}")
    return POLICIES[name]


def known_processors() -> set[str]:
    return set(_builtin_processors()) | set(_PROCESSORS)


def known_sources() -> set[str]:
    return set(_builtin_sources()) | set(_SOURCES)


def known_sinks() -> set[str]:
    return set(_SINKS)


def make_processor(name: str, options: dict, *, metrics: Any = None) -> Any:
    """Instantiate a processor: app factories get ``options`` kwargs; plain
    process/window functions — ``(state, msgs)`` or ``(key, window, msgs)``
    — are returned as-is.

    ``metrics`` (the runner's MetricsBus) is injected into factories that
    accept a ``metrics`` kwarg — this is how app-level gauges (serving page
    pool, app latency quantiles) reach the elastic loop without every spec
    having to plumb the bus through ``options``. An explicit
    ``options["metrics"]`` wins."""
    factory = resolve_processor(name)
    import inspect

    if not isinstance(factory, type):
        try:
            sig = inspect.signature(factory)
        except (TypeError, ValueError):
            sig = None
        if sig is not None:
            # count positional params regardless of defaults: a processor
            # like (state, msgs=()) must not be mistaken for a factory and
            # called with zero args
            positional = [
                p for p in sig.parameters.values()
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
            ]
            if len(positional) >= 2:
                if options:
                    raise TypeError(
                        f"processor {name!r} is a plain function; stage "
                        f"options {sorted(options)} have nowhere to go"
                    )
                return factory
    if metrics is not None and "metrics" not in options:
        target = factory.__init__ if isinstance(factory, type) else factory
        try:
            params = inspect.signature(target).parameters
        except (TypeError, ValueError):
            params = {}
        if "metrics" in params:
            options = dict(options, metrics=metrics)
    return factory(**options)

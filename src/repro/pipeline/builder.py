"""Fluent pipeline builder — compose, then validate, then get a frozen spec.

    pipe = (Pipeline.named("kmeans")
            .broker(nodes=2)
            .topic("points", partitions=8)
            .source("points", kind="cluster", rate_msgs_per_s=200,
                    n_clusters=10, dim=3)
            .stage("score", topic="points", processor="kmeans",
                   cores_per_node=2, batch_interval=0.05,
                   n_clusters=10, dim=3)
            .elastic("score", policy="threshold", high_lag=80, low_lag=15)
            .build())
    with pipe.run(devices=8) as run:
        run.await_batches("score", 10)

Validation happens in :meth:`Pipeline.build` — unknown topics, duplicate
names, topic cycles, unknown processors/sources/policies, engine/knob
mismatches — so misconfigurations fail before any pilot is provisioned,
not minutes into a run.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.pipeline import registry
from repro.pipeline.spec import (
    BrokerSpec,
    ElasticSpec,
    PipelineSpec,
    SinkSpec,
    SourceSpec,
    StageSpec,
)

_STAGE_FIELDS = {
    "engine", "nodes", "cores_per_node", "group", "output_topic", "emits",
    "batch_interval", "max_batch_records", "backpressure", "window",
    "state_partitions", "executor", "checkpoint_every", "priority", "share",
    "colocate_with", "transport", "async_emit",
}
_TRANSPORTS = {"log", "shm"}
_SOURCE_FIELDS = {
    "rate_msgs_per_s", "total_messages", "n_producers", "seed", "rate_schedule",
}
_ENGINES = {"microbatch", "continuous"}
_EXECUTORS = {"inline", "mp"}
_WINDOWS = {"tumbling", "sliding", "session"}


class PipelineValidationError(ValueError):
    """Raised by :meth:`Pipeline.build` with every problem found (not just
    the first)."""

    def __init__(self, errors: list[str]):
        self.errors = list(errors)
        super().__init__(
            "invalid pipeline:\n" + "\n".join(f"  - {e}" for e in errors)
        )


class Pipeline:
    """Mutable accumulator behind the fluent API; ``build()`` returns the
    immutable :class:`PipelineSpec`."""

    def __init__(self, name: str):
        self._name = name
        self._broker = BrokerSpec()
        self._broker_elastic: ElasticSpec | None = None
        self._topics: dict[str, int] = {}
        self._sources: list[SourceSpec] = []
        self._stages: list[StageSpec] = []
        self._sinks: list[SinkSpec] = []
        self._elastic: dict[str, ElasticSpec] = {}
        self._share = 1.0

    @classmethod
    def named(cls, name: str) -> "Pipeline":
        return cls(name)

    @classmethod
    def from_spec(cls, spec: PipelineSpec) -> "Pipeline":
        """Rehydrate a builder from a (possibly deserialized) spec so it can
        be re-validated — the ``repro-pipeline validate`` path for specs
        that never went through ``build()``."""
        p = cls(spec.name)
        p._broker = spec.broker
        p._broker_elastic = spec.broker.elastic
        p._topics = dict(spec.broker.topics)
        p._sources = list(spec.sources)
        p._stages = list(spec.stages)
        p._sinks = list(spec.sinks)
        p._elastic = {s.name: s.elastic for s in spec.stages if s.elastic is not None}
        p._share = spec.share
        return p

    def validate(self) -> list[str]:
        """Every problem in the accumulated topology (empty = valid)."""
        return self._validate()

    # -- broker ---------------------------------------------------------------

    def broker(self, *, nodes: int = 1, framework: str = "kafka",
               io_rate_per_node: float | None = None,
               replication_factor: int = 1,
               transport: str = "log",
               transport_options: dict | None = None) -> "Pipeline":
        self._broker = BrokerSpec(nodes=nodes, framework=framework,
                                  io_rate_per_node=io_rate_per_node,
                                  replication_factor=replication_factor,
                                  transport=transport,
                                  transport_options=transport_options or {})
        return self

    def broker_elastic(self, *, policy: str = "broker_saturation",
                       interval: float = 0.5, min_nodes: int = 1,
                       max_nodes: int | None = None, cooldown: float = 1.0,
                       **params) -> "Pipeline":
        """Make the *broker* elastic: a node-unit controller scales
        ``BrokerCluster`` membership through the arbiter, by default off the
        producer token-bucket saturation signal (``broker.stall_frac``)."""
        self._broker_elastic = ElasticSpec(
            policy=policy, params=params, interval=interval,
            min_devices=min_nodes, max_devices=max_nodes, cooldown=cooldown,
        )
        return self

    def share(self, weight: float) -> "Pipeline":
        """Pipeline-level fair-share weight against other runs on a shared
        service (default 1.0)."""
        self._share = weight
        return self

    def topic(self, name: str, partitions: int = 4) -> "Pipeline":
        self._topics[name] = partitions
        return self

    # -- components -----------------------------------------------------------

    def source(self, topic: str, *, kind: str = "cluster", **kw) -> "Pipeline":
        """Attach a producer group to ``topic``. Keyword args split between
        :class:`SourceSpec` fields and factory ``options``."""
        spec_kw = {k: kw.pop(k) for k in list(kw) if k in _SOURCE_FIELDS}
        self._sources.append(
            SourceSpec(topic=topic, kind=kind, options=kw, **spec_kw)
        )
        return self

    def stage(self, name: str, *, topic: str,
              processor: str | Callable[..., Any], **kw) -> "Pipeline":
        """Add a processing stage consuming ``topic``. ``processor`` is a
        registry name or a callable (auto-registered under its
        ``__name__``). Remaining kwargs split between :class:`StageSpec`
        fields and processor ``options``."""
        if callable(processor):
            # qualify with the defining module so two pipelines' same-named
            # local functions cannot silently overwrite each other
            ref = f"{processor.__module__}.{processor.__qualname__}"
            registry.register_processor(ref, processor)
            processor = ref
        spec_kw = {k: kw.pop(k) for k in list(kw) if k in _STAGE_FIELDS}
        self._stages.append(
            StageSpec(name=name, topic=topic, processor=processor,
                      options=kw, **spec_kw)
        )
        return self

    def sink(self, name: str, *, topic: str,
             fn: str | Callable | None = None, **options) -> "Pipeline":
        """Drain ``topic``: collect messages (default) or apply ``fn`` per
        message (a registry name or callable)."""
        kind = "collect"
        if fn is not None:
            if callable(fn):
                ref = f"{fn.__module__}.{fn.__qualname__}"
                registry.register_sink(ref, fn)
                fn = ref
            kind = fn
        self._sinks.append(SinkSpec(name=name, topic=topic, kind=kind,
                                    options=options))
        return self

    def elastic(self, stage: str, *, policy: str = "threshold",
                interval: float = 0.5, min_devices: int = 1,
                max_devices: int | None = None, devices_per_step: int = 1,
                cooldown: float = 1.0,
                migration_cost_frac: float | None = None,
                preemptible: bool = False,
                **params) -> "Pipeline":
        """Make ``stage`` elastic: ``policy`` + ``params`` select/configure
        the ScalingPolicy, the rest configure the controller.
        ``migration_cost_frac`` holds rescales while the last keyed-state
        migration is still amortizing (continuous stages).
        ``preemptible=True`` lets a zero-device grant park the whole stage
        via checkpoint-then-kill instead of keeping the base pilot's floor
        (continuous + checkpoint_every > 0 + min_devices == 0 only)."""
        self._elastic[stage] = ElasticSpec(
            policy=policy, params=params, interval=interval,
            min_devices=min_devices, max_devices=max_devices,
            devices_per_step=devices_per_step, cooldown=cooldown,
            migration_cost_frac=migration_cost_frac,
            preemptible=preemptible,
        )
        return self

    # -- finalize -------------------------------------------------------------

    def build(self) -> PipelineSpec:
        errors = self._validate()
        if errors:
            raise PipelineValidationError(errors)
        stages = tuple(
            s if s.name not in self._elastic
            else StageSpec(**{**_stage_kwargs(s), "elastic": self._elastic[s.name]})
            for s in self._stages
        )
        broker = BrokerSpec(
            nodes=self._broker.nodes,
            framework=self._broker.framework,
            topics=dict(self._topics),
            io_rate_per_node=self._broker.io_rate_per_node,
            replication_factor=self._broker.replication_factor,
            transport=self._broker.transport,
            transport_options=dict(self._broker.transport_options),
            elastic=self._broker_elastic,
        )
        return PipelineSpec(
            name=self._name,
            broker=broker,
            sources=tuple(self._sources),
            stages=stages,
            sinks=tuple(self._sinks),
            share=self._share,
        )

    def _validate(self) -> list[str]:
        errors: list[str] = []
        if not self._name:
            errors.append("pipeline needs a non-empty name")
        if self._broker.nodes < 1:
            errors.append(f"broker needs >= 1 node, got {self._broker.nodes}")
        if self._broker.replication_factor < 1:
            errors.append(
                "broker replication_factor must be >= 1, got "
                f"{self._broker.replication_factor}"
            )
        elif self._broker.replication_factor > self._broker.nodes:
            errors.append(
                f"broker replication_factor {self._broker.replication_factor} "
                f"exceeds node count {self._broker.nodes}: replicas live on "
                "distinct nodes"
            )
        if self._broker.transport not in _TRANSPORTS:
            errors.append(
                f"broker: unknown transport {self._broker.transport!r} "
                f"(expected one of {sorted(_TRANSPORTS)})"
            )
        for name, parts in self._topics.items():
            if parts < 1:
                errors.append(f"topic {name!r} needs >= 1 partition, got {parts}")

        seen_stage: set[str] = set()
        for s in self._stages:
            if s.name in seen_stage:
                errors.append(f"duplicate stage name {s.name!r}")
            seen_stage.add(s.name)
            if s.topic not in self._topics:
                errors.append(f"stage {s.name!r} consumes unknown topic {s.topic!r}")
            if s.output_topic is not None and s.output_topic not in self._topics:
                errors.append(
                    f"stage {s.name!r} emits to unknown topic {s.output_topic!r}"
                )
            if s.output_topic == s.topic:
                errors.append(
                    f"stage {s.name!r} reads and writes topic {s.topic!r} "
                    "(self-loop)"
                )
            if s.engine not in _ENGINES:
                errors.append(
                    f"stage {s.name!r}: unknown engine {s.engine!r} "
                    f"(expected one of {sorted(_ENGINES)})"
                )
            if s.executor not in _EXECUTORS:
                errors.append(
                    f"stage {s.name!r}: unknown executor {s.executor!r} "
                    f"(expected one of {sorted(_EXECUTORS)})"
                )
            elif s.executor == "mp" and s.engine != "continuous":
                errors.append(
                    f"stage {s.name!r}: executor='mp' requires the "
                    "continuous engine (the micro-batch engine has no "
                    "partition workers)"
                )
            if s.engine == "continuous":
                w = s.window.get("window", "tumbling")
                if w not in _WINDOWS:
                    errors.append(
                        f"stage {s.name!r}: unknown window kind {w!r} "
                        f"(expected one of {sorted(_WINDOWS)})"
                    )
                if s.emits:
                    errors.append(
                        f"stage {s.name!r}: emits=True requires the "
                        "micro-batch engine"
                    )
            elif s.window:
                errors.append(
                    f"stage {s.name!r}: window options only apply to the "
                    "continuous engine"
                )
            if s.emits and s.output_topic is None:
                errors.append(f"stage {s.name!r}: emits=True needs output_topic")
            if s.output_topic is not None and not s.emits:
                errors.append(
                    f"stage {s.name!r}: output_topic needs emits=True "
                    "(processor must return (state, outputs))"
                )
            if s.transport is not None:
                if s.transport not in _TRANSPORTS:
                    errors.append(
                        f"stage {s.name!r}: unknown transport {s.transport!r} "
                        f"(expected one of {sorted(_TRANSPORTS)})"
                    )
                elif s.transport == "shm" and self._broker.transport != "shm":
                    errors.append(
                        f"stage {s.name!r}: transport='shm' requires the "
                        "broker to mount the shm data plane "
                        "(broker(transport='shm'))"
                    )
            if s.processor not in registry.known_processors():
                errors.append(f"stage {s.name!r}: unknown processor {s.processor!r}")
            if s.share <= 0:
                errors.append(f"stage {s.name!r}: share must be > 0, got {s.share}")
            if s.state_partitions < 1:
                errors.append(
                    f"stage {s.name!r}: state_partitions must be >= 1, "
                    f"got {s.state_partitions}"
                )
            if s.checkpoint_every < 0:
                errors.append(
                    f"stage {s.name!r}: checkpoint_every must be >= 0, "
                    f"got {s.checkpoint_every}"
                )
            elif s.checkpoint_every and s.engine != "continuous":
                errors.append(
                    f"stage {s.name!r}: checkpoint_every only applies to the "
                    "continuous engine (the micro-batch engine checkpoints "
                    "per batch already)"
                )
            if s.async_emit < 0:
                errors.append(
                    f"stage {s.name!r}: async_emit must be >= 0, "
                    f"got {s.async_emit}"
                )
            elif s.async_emit and s.engine != "continuous":
                errors.append(
                    f"stage {s.name!r}: async_emit only applies to the "
                    "continuous engine (the micro-batch engine double-buffers "
                    "inside its apps; see docs/perf.md)"
                )
            elif s.async_emit and s.executor == "mp":
                errors.append(
                    f"stage {s.name!r}: async_emit requires the inline "
                    "executor (mp workers already overlap host routing with "
                    "device compute across processes)"
                )

        by_stage_name = {s.name: s for s in self._stages}
        for s in self._stages:
            if s.colocate_with is None:
                continue
            target = by_stage_name.get(s.colocate_with)
            if s.colocate_with == s.name:
                errors.append(f"stage {s.name!r} cannot colocate_with itself")
            elif target is None:
                errors.append(
                    f"stage {s.name!r}: unknown co-location target "
                    f"{s.colocate_with!r}"
                )
            elif target.engine != s.engine:
                errors.append(
                    f"stage {s.name!r} (engine {s.engine!r}) cannot colocate "
                    f"with {target.name!r} (engine {target.engine!r}): "
                    "co-located stages share one pilot"
                )
            elif target.colocate_with is not None:
                errors.append(
                    f"stage {s.name!r}: co-location target {target.name!r} is "
                    "itself co-located; point at the host stage directly"
                )
            if s.elastic is not None or s.name in self._elastic:
                errors.append(
                    f"stage {s.name!r}: a co-located stage cannot have its own "
                    "elastic policy (the host stage's controller owns the pilot)"
                )

        if self._share <= 0:
            errors.append(f"pipeline share must be > 0, got {self._share}")

        if self._broker_elastic is not None:
            el = self._broker_elastic
            try:
                cls = registry.resolve_policy(el.policy)
            except KeyError as e:
                errors.append(str(e.args[0]))
            else:
                try:
                    cls(**dict(el.params))
                except (TypeError, ValueError) as e:
                    errors.append(f"broker elastic policy {el.policy!r}: {e}")
            if el.min_devices < 1:
                errors.append("broker elastic: min_nodes must be >= 1")

        errors.extend(self._cycle_errors())

        for src in self._sources:
            if src.topic not in self._topics:
                errors.append(f"source feeds unknown topic {src.topic!r}")
            if src.kind not in registry.known_sources():
                errors.append(f"unknown source kind {src.kind!r}")
            if src.n_producers < 1:
                errors.append(
                    f"source on {src.topic!r} needs >= 1 producer, got "
                    f"{src.n_producers}"
                )

        seen_sink: set[str] = set()
        for sk in self._sinks:
            if sk.name in seen_sink:
                errors.append(f"duplicate sink name {sk.name!r}")
            seen_sink.add(sk.name)
            if sk.topic not in self._topics:
                errors.append(f"sink {sk.name!r} drains unknown topic {sk.topic!r}")
            if sk.kind != "collect" and sk.kind not in registry.known_sinks():
                errors.append(f"sink {sk.name!r}: unknown sink fn {sk.kind!r}")

        by_name = {s.name: s for s in self._stages}
        for stage_name, el in self._elastic.items():
            if stage_name not in by_name:
                errors.append(f"elastic policy attached to unknown stage {stage_name!r}")
            if el.preemptible and stage_name in by_name:
                target = by_name[stage_name]
                # parking cancels the base pilot; only a checkpointing
                # continuous stream can be resumed from a spool afterwards
                if target.engine != "continuous" or not target.checkpoint_every:
                    errors.append(
                        f"elastic on {stage_name!r}: preemptible=True requires "
                        "the continuous engine with checkpoint_every > 0 "
                        "(parking resumes from a crash checkpoint)"
                    )
                if el.min_devices != 0:
                    errors.append(
                        f"elastic on {stage_name!r}: preemptible=True requires "
                        f"min_devices == 0 (got {el.min_devices}) — a nonzero "
                        "floor means the stage is never driven to zero"
                    )
            try:
                cls = registry.resolve_policy(el.policy)
            except KeyError as e:
                errors.append(str(e.args[0]))
                continue
            params = dict(el.params)
            if el.policy in ("latency", "slo") and stage_name in by_name:
                # the inline continuous executor never publishes
                # latency_p50/p99, so a latency/slo policy on it would
                # silently hold forever; the mp executor publishes per-worker
                # and aggregate quantiles, so it may use one
                target = by_name[stage_name]
                if target.engine == "continuous" and target.executor != "mp":
                    errors.append(
                        f"elastic policy {el.policy!r} on {stage_name!r}: the "
                        "continuous engine's inline executor publishes no "
                        "latency quantiles; use executor='mp' or a "
                        "lag-based policy (threshold/pid/binpack)"
                    )
                    continue
                if el.policy == "latency":
                    # the runner injects the stage's batch interval the same way
                    params.setdefault("batch_interval", by_name[stage_name].batch_interval)
            try:
                cls(**params)
            except (TypeError, ValueError) as e:
                errors.append(f"elastic policy {el.policy!r} on {stage_name!r}: {e}")
        return errors

    def _cycle_errors(self) -> list[str]:
        """Topic-level DFS: stage edges topic -> output_topic must be acyclic."""
        edges: dict[str, list[str]] = {}
        for s in self._stages:
            if s.output_topic is not None:
                edges.setdefault(s.topic, []).append(s.output_topic)
        state: dict[str, int] = {}  # 0 visiting, 1 done

        def visit(t: str, path: tuple) -> list[str]:
            if state.get(t) == 1:
                return []
            if state.get(t) == 0:
                cyc = path[path.index(t):] + (t,)
                return [f"topic cycle: {' -> '.join(cyc)}"]
            state[t] = 0
            errs = []
            for nxt in edges.get(t, ()):
                errs += visit(nxt, path + (t,))
            state[t] = 1
            return errs

        errs: list[str] = []
        for t in list(edges):
            errs += visit(t, ())
        return errs


def _stage_kwargs(s: StageSpec) -> dict:
    return {
        "name": s.name, "topic": s.topic, "processor": s.processor,
        "engine": s.engine, "nodes": s.nodes, "cores_per_node": s.cores_per_node,
        "group": s.group, "output_topic": s.output_topic, "emits": s.emits,
        "batch_interval": s.batch_interval,
        "max_batch_records": s.max_batch_records,
        "backpressure": s.backpressure, "window": dict(s.window),
        "state_partitions": s.state_partitions,
        "executor": s.executor,
        "checkpoint_every": s.checkpoint_every,
        "transport": s.transport,
        "async_emit": s.async_emit,
        "options": dict(s.options),
        "priority": s.priority, "share": s.share,
        "colocate_with": s.colocate_with,
    }

"""ElasticController — per-consumer reconciler, now also a demand estimator.

Watches the :class:`MetricsBus` and asks a :class:`ScalingPolicy` for a
resource delta. What happens next depends on the mode:

* **direct** (no arbiter — the pre-scheduler behavior, unchanged): the
  controller actuates itself. Growth is
  ``PilotComputeService.submit_pilot(parent=base)`` (paper Listing 4 — an
  extension pilot whose lease the plugin folds in, firing the stream's
  ``on_rescale`` re-sharding hook), shrink is ``Pilot.cancel()`` on the
  most recent extension.
* **arbitrated** (``arbiter=`` + ``request=`` given): the controller only
  *estimates demand* — it folds the policy's delta into a target resource
  count and files it via ``ResourceArbiter.update``. The arbiter decides
  what is actually granted (weighted fair share across every consumer of
  the pool) and actuates through :meth:`scale_to`.

Either way the controller owns only the extensions it created; the base
pilot is never cancelled. ``unit="nodes"`` makes the same reconciler manage
broker nodes (logical host slots) instead of devices — extension pilots on
the broker pilot add/remove ``BrokerCluster`` nodes through the plugin.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable

from repro.core.description import PilotComputeDescription
from repro.elastic.events import EventLog, ScalingEvent
from repro.elastic.metrics import MetricsBus, MetricsSnapshot
from repro.elastic.policy import HOLD, ScalingDecision, ScalingPolicy


@dataclass
class PreemptionHooks:
    """Checkpoint-then-kill wiring for whole-pilot preemption.

    When the arbiter drives a checkpointing continuous stage to zero
    devices, revoking the pilot out from under the stream would lose
    everything since its consumer's last commit — and a plain
    ``stream.stop()`` would delete the very spools a later resume needs
    (teardown cleans up). These three callbacks let the controller *park*
    the stage instead:

    * ``checkpoint()`` — force an ``sckpt_*`` spool of the live stream
      (a consistent cut: state partitions + consumer positions + counters);
    * ``kill()`` — fence the stream (detach it from its plugin so the
      pilot cancel below cannot ``stop()`` it, then ``crash()`` it) —
      after this the old incarnation cannot emit;
    * ``resume(pilot)`` — attach the stream to the replacement pilot's
      plugin and ``recover()`` it from the pre-kill spool (exactly-once:
      replayed firings re-fire with their emit suppressed).

    Built by the pipeline runner for continuous stages with
    ``checkpoint_every > 0`` and ``min_devices == 0``; usable by hand for
    imperative wiring (see tests/test_preemption.py).
    """

    checkpoint: Callable[[], None]
    kill: Callable[[], None]
    resume: Callable[[object], None]


@dataclass
class ElasticConfig:
    interval: float = 0.5  # seconds between reconcile passes
    min_devices: int = 1  # never shrink the pipeline below this
    max_devices: int | None = None  # None = whatever the pool can give
    devices_per_step: int = 1  # lease size of one extension pilot
    cooldown: float = 1.0  # seconds between scaling actions
    #: migration-cost gate (None = off): when the last keyed-state
    #: migration took more than this fraction of a reconcile interval, the
    #: controller holds further rescales until the cost has amortized —
    #: i.e. until ``cost / time_since_migration <= migration_cost_frac``.
    #: The deferral decays on its own (time passes), so an expensive
    #: migration delays scaling; it can never wedge it permanently.
    migration_cost_frac: float | None = None


class ElasticController:
    """Reconcile loop: probe -> snapshot -> decide -> grow/shrink.

    Use ``start()/stop()`` for the background thread, or call ``step()``
    directly for deterministic (test) driving.
    """

    def __init__(
        self,
        service,
        pilot,
        bus: MetricsBus,
        policy: ScalingPolicy,
        *,
        config: ElasticConfig | None = None,
        lag_probe: Callable[[], float] | None = None,
        probes: dict[str, Callable[[], float]] | None = None,
        stream: str | None = None,
        arbiter=None,
        request=None,
        unit: str = "devices",
        hooks: PreemptionHooks | None = None,
    ):
        self.service = service
        self.pilot = pilot  # base pilot; extensions hang off it
        self.bus = bus
        self.policy = policy
        self.config = config or ElasticConfig()
        #: "devices" (engine pilots) or "nodes" (broker pilots — the lease's
        #: logical host slots; BrokerPlugin.extend/shrink add/remove nodes)
        self.unit = unit
        #: repro.scheduler.ResourceArbiter — when set, the controller files
        #: demand instead of actuating, and ``request`` is its live handle
        self.arbiter = arbiter
        self.request = request
        #: published to ``elastic.lag`` each pass — authoritative when the
        #: engine is too stalled to publish its own ``stream.lag``
        self.lag_probe = lag_probe
        #: stream label narrowing this controller's snapshot to one stage —
        #: without it a shared bus mixes every stream's latency/busy gauges
        self.stream = stream
        self.probes = dict(probes or {})
        #: checkpoint-then-kill preemption (None = the pre-existing
        #: behavior: scale_to(0) shrinks extensions and keeps the base)
        self.hooks = hooks
        #: True while the whole stage is preempted: no pilot, no devices,
        #: state parked in its last sckpt spool awaiting a regrant
        self.parked = False
        self.events = EventLog()
        self.extensions: list = []  # pilots we created, newest last
        self._last_action_t = -float("inf")
        self._ticks = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._last_error: BaseException | None = None
        # reentrant: _shrink reads the devices property while holding it
        self._lock = threading.RLock()
        if arbiter is not None:
            if request is None:
                raise ValueError("arbiter mode needs a ResourceRequest")
            request.actuator = self.scale_to
            request.current_fn = lambda: self.devices
            request.set_target(max(self.devices, request.min_devices))
            arbiter.submit(request)

    # -- observed state -------------------------------------------------------

    def _lease_size(self, pilot) -> int:
        lease = pilot.lease
        return len(lease.nodes) if self.unit == "nodes" else len(lease.devices)

    @property
    def devices(self) -> int:
        """Resources currently serving the consumer (base + live
        extensions) — devices for engine pilots, nodes for the broker."""
        with self._lock:
            return self._lease_size(self.pilot) + sum(
                self._lease_size(p) for p in self.extensions
            )

    @property
    def ticks(self) -> int:
        return self._ticks

    # -- one reconcile pass ---------------------------------------------------

    def step(self) -> ScalingDecision:
        now = time.monotonic()
        self._ticks += 1
        labels = {} if self.stream is None else {"stream": self.stream}
        if self.lag_probe is not None:
            self.bus.publish("elastic.lag", self.lag_probe(), t=now, **labels)
        for name, fn in self.probes.items():
            self.bus.publish(name, fn(), t=now, **labels)
        snap = MetricsSnapshot.capture(self.bus, self.service.pool,
                                       pipeline_devices=self.devices,
                                       stream=self.stream)
        # gate on cooldown BEFORE consulting the policy: a decision dropped
        # here would consume its hysteresis counters / integral for nothing,
        # adding up_stable*interval of latency after every cooldown collision
        if now - self._last_action_t < self.config.cooldown:
            applied = HOLD
        elif self._migration_deferred(now, snap):
            # the last state migration was expensive relative to the
            # reconcile cadence: let it amortize before paying for another
            self.bus.publish("elastic.rescale_deferred", 1.0, t=now, **labels)
            applied = HOLD
        elif self.arbiter is not None:
            applied = self._submit_demand(self.policy.decide(snap), now)
        else:
            applied = self._apply(self.policy.decide(snap), snap, now)
        self.bus.publish("elastic.devices", self.devices, t=now, **labels)
        self.bus.publish("elastic.decision", applied.delta_devices, t=now, **labels)
        return applied

    def _labels(self) -> dict:
        return {} if self.stream is None else {"stream": self.stream}

    def _migration_deferred(self, now: float, snap: MetricsSnapshot) -> bool:
        """True while the last keyed-state migration is still amortizing
        (``MetricsSnapshot.state_migration_ms`` consumer). The gauge is
        latched — the engine republishes the *last* migration's cost
        forever — so the gate keys off the sample's timestamp
        (``state_migration_t``): defer only until ``cost / (now - t)``
        drops to ``migration_cost_frac``. Reads the snapshot, not the bus:
        the gate must see the same stream-filtered view the policy decided
        on, never a newer (or other stage's) sample published since the
        capture.
        """
        frac = self.config.migration_cost_frac
        if frac is None or frac <= 0:
            return False
        if snap.state_migration_ms <= 0.0:
            return False
        cost_s = snap.state_migration_ms / 1e3
        if cost_s <= frac * self.config.interval:
            return False  # cheap migration: never worth deferring for
        return now < snap.state_migration_t + cost_s / frac

    def _desired(self, decision: ScalingDecision) -> int | None:
        """Fold a policy delta into an absolute resource target (the same
        lease-rounding rules ``_apply`` uses), clamped to the controller's
        own band. ``None`` = hold."""
        if decision.delta_devices == 0:
            return None
        step = max(self.config.devices_per_step, 1)
        n = abs(decision.delta_devices)
        if decision.absolute:
            want = (-(-n // step) if decision.scale_up else n // step) * step
        else:
            want = n * step
        if want <= 0:
            return None
        cur = self.devices
        target = cur + want if decision.scale_up else cur - want
        target = max(target, self.config.min_devices)
        if self.config.max_devices is not None:
            target = min(target, self.config.max_devices)
        return target

    def _submit_demand(self, decision: ScalingDecision, now: float) -> ScalingDecision:
        """Arbiter mode: the policy's verdict becomes a demand revision, not
        an actuation — the arbiter owns the pool and will call
        :meth:`scale_to` with whatever is actually granted."""
        target = self._desired(decision)
        if target is None or target == self.request.target:
            return HOLD
        before = self.devices
        self.arbiter.update(self.request.name, target)
        self._last_action_t = now  # cooldown paces demand revisions too
        self.bus.publish("elastic.target", target, t=now, **self._labels())
        return ScalingDecision(target - before, decision.reason)

    def scale_to(self, n: int) -> int:
        """Idempotent absolute actuator (the arbiter's grant callback):
        grow/shrink extension pilots until ``n`` resources serve the
        consumer. Returns the count actually reached.

        With :class:`PreemptionHooks` wired and ``min_devices == 0``, a
        grant of 0 *parks* the whole stage — checkpoint, fence, cancel
        every pilot including the base — and the next non-zero grant
        resubmits the base pilot and resumes the stream from its pre-kill
        spool (exactly-once). Without hooks, 0 shrinks extensions only and
        the base pilot keeps its floor, as before."""
        t0 = time.perf_counter()
        with self._lock:
            before = self.devices
            if self.parked:
                if n > 0:
                    self._unpark()  # base pilot back; stream resumed
            elif (n <= 0 and self.hooks is not None
                    and self.config.min_devices == 0 and before > 0):
                self._park()
            cur = self.devices
            if not self.parked and n > cur:
                want = n - cur
                if self.unit == "devices":
                    want = min(want, self.service.pool.free_devices)
                if want > 0:
                    self._grow(want)
            elif not self.parked and n < cur:
                self._shrink(cur - n)
            after = self.devices
        if after != before:
            now = time.monotonic()
            action = "scale_up" if after > before else "scale_down"
            labels = self._labels()
            self.events.record(ScalingEvent(now, action, after - before,
                                            before, after, f"granted {n}"))
            self.bus.publish("elastic.event",
                             1.0 if after > before else -1.0, t=now, **labels)
            self.bus.publish("elastic.devices", after, t=now, **labels)
            # grow/shrink is synchronous through plugin.extend/shrink ->
            # stream.rescale, so this includes any keyed-state migration the
            # grant triggered (quiesce + snapshot + restore) — the end-to-end
            # disruption cost of the scaling action
            self.bus.publish("elastic.actuation_ms",
                             (time.perf_counter() - t0) * 1e3, t=now, **labels)
        return after

    def _park(self) -> None:
        """Checkpoint-then-kill: spool the stream's state, fence it, then
        cancel every pilot (extensions and base). Caller holds the lock.
        Order matters — the kill hook detaches the stream from the base
        pilot's plugin *before* the cancels, so ``plugin.cancel`` cannot
        ``stop()`` it (stop deletes the spools the resume needs)."""
        now = time.monotonic()
        before = self.devices
        self.hooks.checkpoint()
        self.hooks.kill()
        exts, self.extensions = list(self.extensions), []
        for p in reversed(exts):
            try:
                p.cancel()
            except Exception:
                self.bus.publish("elastic.errors", 1.0)
                self.service._release(p)
        try:
            self.pilot.cancel()
        except Exception:
            self.bus.publish("elastic.errors", 1.0)
            self.service._release(self.pilot)
        self.parked = True
        self.events.record(ScalingEvent(now, "park", -before, before, 0,
                                        "preempted to zero: checkpoint-then-kill"))
        self.bus.publish("elastic.parked", 1.0, t=now, **self._labels())

    def _unpark(self) -> None:
        """Reverse of :meth:`_park`: resubmit the base pilot (same PCD,
        possibly different devices) and resume the stream from its pre-kill
        spool. Caller holds the lock."""
        now = time.monotonic()
        self.pilot = self.service.submit_pilot(self.pilot.pcd)
        self.parked = False
        self.hooks.resume(self.pilot)
        after = self.devices
        self.events.record(ScalingEvent(now, "unpark", after, 0, after,
                                        "regranted: resumed from checkpoint"))
        self.bus.publish("elastic.parked", 0.0, t=now, **self._labels())

    def _apply(self, decision: ScalingDecision, snap: MetricsSnapshot, now: float) -> ScalingDecision:
        if decision.delta_devices == 0:
            return decision
        before = self.devices
        # relative deltas count lease-sized actions; absolute deltas are
        # exact device counts, rounded up on grow but DOWN on shrink so a
        # target between lease multiples holds rather than flapping
        step = max(self.config.devices_per_step, 1)
        n = abs(decision.delta_devices)
        if decision.absolute:
            want = (-(-n // step) if decision.scale_up else n // step) * step
        else:
            want = n * step
        if want <= 0:
            return HOLD
        t0 = time.perf_counter()
        if decision.scale_up:
            want = min(want, self.service.pool.free_devices)
            if self.config.max_devices is not None:
                want = min(want, self.config.max_devices - before)
            if want <= 0:
                self.events.record(ScalingEvent(now, "rejected", 0, before, before,
                                                f"no headroom ({decision.reason})"))
                return HOLD
            self._grow(want)
            action = "scale_up"
        else:
            removed = self._shrink(want)
            if removed == 0:
                return HOLD
            action = "scale_down"
        self._last_action_t = now
        after = self.devices
        event = ScalingEvent(now, action, after - before, before, after, decision.reason)
        self.events.record(event)
        self.bus.publish("elastic.event", 1.0 if action == "scale_up" else -1.0,
                         t=now, **self._labels())
        # includes any keyed-state migration the rescale triggered (see
        # scale_to) — direct mode pays the same disruption cost
        self.bus.publish("elastic.actuation_ms", (time.perf_counter() - t0) * 1e3,
                         t=now, **self._labels())
        return ScalingDecision(after - before, decision.reason)

    def _grow(self, n: int) -> None:
        if self.unit == "nodes":
            # broker growth: the extension's *host slots* become cluster
            # nodes (BrokerPlugin.extend); no devices are consumed
            pcd = PilotComputeDescription(
                number_of_nodes=n,
                cores_per_node=1,
                framework=self.pilot.pcd.framework,
                parent=self.pilot,
            )
        else:
            pcd = PilotComputeDescription(
                number_of_nodes=1,
                cores_per_node=n,
                framework=self.pilot.pcd.framework,
                parent=self.pilot,
            )
        ext = self.service.submit_pilot(pcd)
        with self._lock:
            self.extensions.append(ext)

    def _shrink(self, n_devices: int) -> int:
        """Cancel newest-first extensions until ~n_devices are returned,
        honoring ``min_devices``. The base pilot is never touched."""
        removed = 0
        while removed < n_devices:
            with self._lock:
                if not self.extensions:
                    break
                candidate = self.extensions[-1]
                size = self._lease_size(candidate)
                if size == 0:  # already drained elsewhere: just drop it
                    self.extensions.pop()
                    continue
                if self.devices - size < self.config.min_devices:
                    break
                self.extensions.pop()
            # once popped, the shrink must be accounted for even if the
            # cancel hits a churn race — lease release is idempotent
            try:
                candidate.cancel()
            except Exception:
                self.bus.publish("elastic.errors", 1.0)
                self.service._release(candidate)
            removed += size
        return removed

    # -- background loop ------------------------------------------------------

    def start(self) -> "ElasticController":
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.config.interval):
            try:
                self.step()
            except Exception as e:  # pilot churn races are survivable
                self.bus.publish("elastic.errors", 1.0)
                self._last_error = e

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def shutdown(self, *, release_extensions: bool = True) -> None:
        self.stop()
        if self.arbiter is not None and self.request is not None:
            self.arbiter.withdraw(self.request.name)
        if release_extensions:
            with self._lock:
                exts, self.extensions = list(self.extensions), []
            for p in reversed(exts):
                try:
                    p.cancel()
                except Exception:
                    pass

"""ForecastPolicy — predictive, cost-aware scaling from a fitted model.

Every other policy in :mod:`repro.elastic.policy` is reactive: it scales
from lag the pipeline has *already incurred*. This one follows the
performance-modeling formulation of arXiv:1909.06055 — fit an online
throughput model to the telemetry stream and size the pool from the
model's *forecast* over a horizon — and gates the resulting rescale on
the migration cost it would pay (``MetricsSnapshot.state_migration_ms``,
captured since the keyed-state PR but never consumed by a policy until
now).

The model is deliberately small, because the snapshot gives exactly two
load-bearing observables per tick:

* **Per-device service rate** ``mu`` (records/s/device): scalar recursive
  least squares with a forgetting factor over ``(pipeline_devices,
  records_per_sec)`` pairs — ``records_per_sec ~= mu * devices`` while the
  pipeline is saturated. Samples are only fed to RLS when the pipeline is
  demonstrably *capacity-limited* (backlogged or busy): an idle pipeline's
  throughput equals its offered load, and learning from it would bias
  ``mu`` toward whatever trickle is arriving.
* **Arrival rate** ``a`` (records/s): flow conservation,
  ``a = throughput + d(lag)/dt``, smoothed by an EWMA. This reads the
  offered load even while the pipeline is falling behind, which is the
  regime where reacting to raw lag is already too late.

Sizing then solves the drain equation over ``horizon`` seconds::

    n* = ceil( (a * (1 + headroom) + max(lag - target_lag, 0) / horizon)
               / mu )

i.e. enough devices to absorb the predicted arrivals *and* work off the
excess backlog within the horizon. The decision is returned as an
absolute device count (``ScalingDecision(..., absolute=True)``), like
:class:`BinPackingPolicy`.

**Migration gate.** A rescale of a stateful stage pays a quiesce +
snapshot + restore pause; during it, arrivals pile up. The policy holds
(reason ``"migration gate"``) unless the expected gain over the horizon —
``mu * |delta| * horizon`` records of extra (or surplus) service capacity
— exceeds ``migration_gain_ratio`` times the predicted pile-up,
``a * state_migration_ms / 1e3`` records. A stateless stage publishes no
migration cost and is never gated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.elastic.metrics import MetricsSnapshot
from repro.elastic.policy import HOLD, ScalingDecision, ScalingPolicy


@dataclass
class ForecastPolicy(ScalingPolicy):
    """Size the pool from predicted lag over ``horizon`` seconds.

    Pure decider like every other policy: snapshot in, absolute device
    target out. The controller/arbiter still clamp and actuate.
    """

    #: backlog (records) the pipeline is allowed to carry at steady state
    target_lag: float = 0.0
    #: seconds over which predicted excess backlog must drain
    horizon: float = 5.0
    #: spare service capacity provisioned above the predicted arrivals
    headroom: float = 0.1
    #: RLS forgetting factor (1.0 = infinite memory; lower tracks drift)
    forgetting: float = 0.95
    #: EWMA smoothing on the flow-conservation arrival estimate
    arrival_alpha: float = 0.4
    #: snapshots consumed before the model is trusted to act
    min_observations: int = 3
    #: expected gain must exceed this multiple of the predicted migration
    #: pile-up before a rescale is released (0 disables the gate)
    migration_gain_ratio: float = 1.0
    #: busy_frac at or above which a lag-free pipeline still counts as
    #: capacity-limited for the RLS update
    busy_saturated: float = 0.8
    #: floor on the learned service rate (guards the division)
    min_mu: float = 1e-3

    # -- fitted state (not constructor params in spirit, but dataclass
    # fields so repr/tests can introspect the model) --
    _mu: float = field(default=0.0, repr=False)
    _P: float = field(default=1e6, repr=False)  # RLS covariance
    _arrival: float = field(default=0.0, repr=False)
    _have_arrival: bool = field(default=False, repr=False)
    _prev_t: float | None = field(default=None, repr=False)
    _prev_lag: float = field(default=0.0, repr=False)
    _n_obs: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.horizon <= 0:
            raise ValueError("horizon must be positive")
        if not 0 < self.forgetting <= 1:
            raise ValueError("forgetting must be in (0, 1]")
        if not 0 < self.arrival_alpha <= 1:
            raise ValueError("arrival_alpha must be in (0, 1]")

    # -- model ---------------------------------------------------------------

    @property
    def service_rate(self) -> float:
        """The fitted per-device service rate (records/s/device)."""
        return max(self._mu, self.min_mu)

    @property
    def arrival_rate(self) -> float:
        """The smoothed arrival-rate estimate (records/s)."""
        return self._arrival

    def _observe(self, snap: MetricsSnapshot) -> None:
        # arrival by flow conservation needs two snapshots
        if self._prev_t is not None:
            dt = snap.t - self._prev_t
            if dt > 0:
                inst = max(snap.records_per_sec
                           + (snap.lag - self._prev_lag) / dt, 0.0)
                if self._have_arrival:
                    self._arrival += self.arrival_alpha * (inst - self._arrival)
                else:
                    self._arrival = inst
                    self._have_arrival = True
        self._prev_t = snap.t
        self._prev_lag = snap.lag

        # RLS on (devices, throughput) — capacity-limited samples only
        saturated = snap.lag > 0 or snap.busy_frac >= self.busy_saturated
        x = float(max(snap.pipeline_devices, 1))
        if saturated and snap.records_per_sec > 0:
            lam = self.forgetting
            k = self._P * x / (lam + x * self._P * x)
            self._mu += k * (snap.records_per_sec - self._mu * x)
            self._P = (self._P - k * x * self._P) / lam
            self._mu = max(self._mu, 0.0)
        self._n_obs += 1

    def _desired(self, snap: MetricsSnapshot) -> int:
        mu = self.service_rate
        drain = max(snap.lag - self.target_lag, 0.0) / self.horizon
        need = self._arrival * (1.0 + self.headroom) + drain
        return max(int(math.ceil(need / mu)), 1) if need > 0 else 1

    def predicted_lag(self, snap: MetricsSnapshot, devices: int | None = None) -> float:
        """Forecast backlog ``horizon`` seconds out at ``devices`` (default:
        the pipeline's current size) — what the sizing inverts."""
        n = snap.pipeline_devices if devices is None else devices
        return max(snap.lag + (self._arrival - self.service_rate * n)
                   * self.horizon, 0.0)

    # -- decider -------------------------------------------------------------

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        self._observe(snap)
        if self._n_obs < self.min_observations:
            return HOLD
        desired = self._desired(snap)
        delta = desired - snap.pipeline_devices
        if delta == 0:
            return HOLD
        # migration gate: expected gain over the horizon vs the pile-up the
        # rescale pause would cost (finally consuming state_migration_ms
        # from the snapshot itself)
        cost_s = snap.state_migration_ms / 1e3
        if cost_s > 0 and self.migration_gain_ratio > 0:
            gain = self.service_rate * abs(delta) * self.horizon
            pileup = self._arrival * cost_s
            if gain < self.migration_gain_ratio * pileup:
                return ScalingDecision(
                    0,
                    f"migration gate: gain {gain:.0f} rec < "
                    f"{self.migration_gain_ratio:.1f} x pile-up {pileup:.0f} rec "
                    f"(cost {snap.state_migration_ms:.0f}ms)",
                )
        return ScalingDecision(
            delta,
            f"forecast wants {desired} devices (mu={self.service_rate:.1f} rec/s/dev, "
            f"arrival={self._arrival:.1f} rec/s, lag={snap.lag:.0f}, "
            f"pred_lag={self.predicted_lag(snap):.0f}@{self.horizon:.0f}s)",
            absolute=True,
        )

"""Scaling policies: snapshot in, device-delta out.

Three families, per the stream-elasticity literature:

* :class:`ThresholdHysteresisPolicy` — lag high/low watermarks with
  consecutive-observation hysteresis and a busy-fraction guard so the
  scale-down leg cannot oscillate against a still-loaded pipeline
  (de Assunção et al., arXiv:1709.01363 §4: lag/throughput elasticity).
* :class:`PIDScalingPolicy` — closed-loop control on consumer lag, the
  same PID idiom as ``streaming/rate_control.py`` but actuating devices
  instead of ingestion rate.
* :class:`BinPackingPolicy` — first-fit-decreasing packing of per-stage
  demand onto fixed-capacity devices (Stein et al., arXiv:2001.10865:
  online bin-packing for stream autoscaling).

Policies are pure deciders: they never touch the pool or pilots. The
:class:`ElasticController` clamps and applies their deltas.
"""
from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field

from repro.elastic.metrics import MetricsSnapshot


@dataclass(frozen=True)
class ScalingDecision:
    delta_devices: int  # >0 grow, <0 shrink, 0 hold
    reason: str = ""
    #: False: delta counts scaling *actions* (one extension-pilot lease each,
    #: threshold/PID style). True: delta is an exact device count (bin-packing
    #: style) — the controller rounds grows up to whole leases and shrinks
    #: down, so a target between lease multiples holds instead of flapping.
    absolute: bool = False

    @property
    def scale_up(self) -> bool:
        return self.delta_devices > 0

    @property
    def scale_down(self) -> bool:
        return self.delta_devices < 0


HOLD = ScalingDecision(0, "hold")


class ScalingPolicy(abc.ABC):
    @abc.abstractmethod
    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        """Map one reconcile-time snapshot to a device delta."""


@dataclass
class ThresholdHysteresisPolicy(ScalingPolicy):
    """Scale up when lag stays above ``high_lag``; scale down when lag stays
    below ``low_lag`` AND the pipeline is mostly idle (``busy_frac`` below
    ``max_busy_for_down`` — without this guard a drained-but-saturated
    pipeline immediately gives back the devices it still needs)."""

    high_lag: float
    low_lag: float
    up_stable: int = 2  # consecutive observations before acting
    down_stable: int = 3
    max_busy_for_down: float = 0.5
    step: int = 1  # lease-sized scaling actions per decision (relative delta)

    _above: int = field(default=0, repr=False)
    _below: int = field(default=0, repr=False)

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        # inclusive up-leg (>=), like every other hysteresis policy here: a
        # signal sitting exactly on the watermark must accumulate toward
        # up_stable, not fall into the in-band else and zero both counters
        if snap.lag >= self.high_lag:
            self._above += 1
            self._below = 0
        elif snap.lag < self.low_lag and snap.busy_frac < self.max_busy_for_down:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.up_stable:
            self._above = 0
            return ScalingDecision(self.step, f"lag {snap.lag:.0f} >= {self.high_lag:.0f} "
                                              f"for {self.up_stable} observations")
        if self._below >= self.down_stable:
            self._below = 0
            return ScalingDecision(-self.step, f"lag {snap.lag:.0f} < {self.low_lag:.0f}, "
                                               f"busy {snap.busy_frac:.2f}")
        return HOLD


@dataclass
class PIDScalingPolicy(ScalingPolicy):
    """PID on consumer lag. Lag integrates (ingress − throughput), so the
    proportional term already acts like an integral of rate error — gains
    stay small and the integral is clamped (anti-windup), mirroring
    ``PIDRateController``'s first-update initialization idiom."""

    target_lag: float
    kp: float = 1.0
    ki: float = 0.1
    kd: float = 0.0
    #: control units per device: u == lag_per_device means "one device short"
    lag_per_device: float = 100.0
    deadband: float = 0.25  # hold while |u| (already in device units) is below this
    integral_limit: float = 10.0  # in device units

    _latest_error: float = 0.0
    _integral: float = 0.0
    _last_t: float = 0.0
    _initialized: bool = False

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        error = snap.lag - self.target_lag
        if not self._initialized:
            self._initialized = True
            self._latest_error = error
            self._last_t = snap.t
            return HOLD
        dt = max(snap.t - self._last_t, 1e-6)
        self._integral += error * dt / self.lag_per_device
        self._integral = max(-self.integral_limit, min(self.integral_limit, self._integral))
        d_error = (error - self._latest_error) / dt
        u = (self.kp * error / self.lag_per_device
             + self.ki * self._integral
             + self.kd * d_error / self.lag_per_device)
        self._latest_error = error
        self._last_t = snap.t
        if abs(u) < self.deadband:
            return HOLD
        delta = int(math.copysign(max(1, min(abs(u), 4)), u))
        if delta < 0 and snap.busy_frac >= 0.75:
            return HOLD  # draining but saturated: keep the devices
        if delta < 0:
            self._integral = min(self._integral, 0.0)  # release wound-up surplus
        return ScalingDecision(delta, f"pid u={u:.2f} lag={snap.lag:.0f}")


@dataclass
class LatencyPolicy(ScalingPolicy):
    """React to per-batch compute-latency quantiles *before* they surface as
    lag (ROADMAP "make a scaling policy actually consume latency_p50/p99").

    A micro-batch pipeline saturates when batch compute time approaches the
    batch interval: at ``p99 >= up_frac * batch_interval`` the stream is about
    to fall behind even if lag still reads low, so scale up. Scale down only
    when the *median* is comfortably below ``down_frac * batch_interval`` AND
    lag is drained — p50 is used for the down leg so one slow straggler batch
    (a p99 artifact) cannot hold surplus devices forever. Both legs require
    consecutive observations, mirroring :class:`ThresholdHysteresisPolicy`.
    """

    batch_interval: float
    up_frac: float = 0.8
    down_frac: float = 0.3
    max_lag_for_down: float = 10.0
    up_stable: int = 2
    down_stable: int = 3
    step: int = 1

    _above: int = field(default=0, repr=False)
    _below: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.batch_interval <= 0:
            raise ValueError("batch_interval must be positive")

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        high = self.up_frac * self.batch_interval
        low = self.down_frac * self.batch_interval
        if snap.latency_p99 >= high:
            self._above += 1
            self._below = 0
        elif (
            0.0 < snap.latency_p50 <= low and snap.lag <= self.max_lag_for_down
        ):
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.up_stable:
            self._above = 0
            return ScalingDecision(
                self.step,
                f"p99 {snap.latency_p99 * 1e3:.0f}ms >= {high * 1e3:.0f}ms "
                f"({self.up_frac:.0%} of batch interval)",
            )
        if self._below >= self.down_stable:
            self._below = 0
            return ScalingDecision(
                -self.step,
                f"p50 {snap.latency_p50 * 1e3:.0f}ms <= {low * 1e3:.0f}ms, "
                f"lag {snap.lag:.0f}",
            )
        return HOLD


@dataclass
class SLOPolicy(ScalingPolicy):
    """Hold an *absolute* latency SLO instead of a fraction of the batch
    interval (ROADMAP item 3: the serving tail-latency loop).

    :class:`LatencyPolicy` asks "is the pipeline about to fall behind?";
    this policy asks "is the p99 the user sees above the contract?" — the
    right question for serving, where admission control keeps lag near zero
    by shedding load and the SLO is the only signal that the engine is
    degrading (1909.06055: drive scaling from the latency model, not
    incurred lag). Scale up when ``latency_p99`` (the ``stream.latency_p99``
    gauge, fed by the serving engine) sits above ``slo_p99``; scale down
    only when the p99 — not the median: a tail breach with a healthy median
    is exactly the case serving must react to — is far below the SLO
    (``down_margin``) and lag is drained. Consecutive-observation hysteresis
    on both legs, as everywhere else in this module.
    """

    slo_p99: float  # seconds: the contract
    up_margin: float = 1.0  # scale up when p99 >= up_margin * slo
    down_margin: float = 0.4  # scale down when p99 <= down_margin * slo
    max_lag_for_down: float = 10.0
    up_stable: int = 2
    down_stable: int = 4
    step: int = 1

    _above: int = field(default=0, repr=False)
    _below: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.slo_p99 <= 0:
            raise ValueError("slo_p99 must be positive")
        if not 0 < self.down_margin < self.up_margin:
            raise ValueError("need 0 < down_margin < up_margin")

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        p99 = snap.latency_p99
        if p99 >= self.up_margin * self.slo_p99:
            self._above += 1
            self._below = 0
        elif 0.0 < p99 <= self.down_margin * self.slo_p99 and snap.lag <= self.max_lag_for_down:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.up_stable:
            self._above = 0
            return ScalingDecision(
                self.step,
                f"p99 {p99 * 1e3:.0f}ms breaches SLO {self.slo_p99 * 1e3:.0f}ms "
                f"for {self.up_stable} observations",
            )
        if self._below >= self.down_stable:
            self._below = 0
            return ScalingDecision(
                -self.step,
                f"p99 {p99 * 1e3:.0f}ms <= {self.down_margin:.0%} of SLO, "
                f"lag {snap.lag:.0f}",
            )
        return HOLD


@dataclass
class BrokerSaturationPolicy(ScalingPolicy):
    """Broker-node elasticity from the producer-side token-bucket signal.

    When producers spend a sustained fraction of wall-clock time blocked in
    the broker nodes' token buckets (``snap.broker_stall_frac`` — the
    paper's 1-broker-bottleneck effect, Figs. 8/9), the cluster needs more
    nodes; when the buckets are idle, it can give nodes back. Same
    consecutive-observation hysteresis as
    :class:`ThresholdHysteresisPolicy`, but the actuation unit is broker
    *nodes*, not devices (the controller runs with ``unit="nodes"``).
    """

    high_stall: float = 0.3  # fraction of time producers sit in buckets
    low_stall: float = 0.02
    up_stable: int = 2
    down_stable: int = 4
    step: int = 1

    _above: int = field(default=0, repr=False)
    _below: int = field(default=0, repr=False)

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        stall = snap.broker_stall_frac
        if stall >= self.high_stall:
            self._above += 1
            self._below = 0
        elif stall <= self.low_stall:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.up_stable:
            self._above = 0
            return ScalingDecision(self.step,
                                   f"broker stall {stall:.0%} >= {self.high_stall:.0%}")
        if self._below >= self.down_stable:
            self._below = 0
            return ScalingDecision(-self.step,
                                   f"broker stall {stall:.0%} <= {self.low_stall:.0%}")
        return HOLD


def first_fit_decreasing(items: dict[str, float], capacity: float) -> list[list[str]]:
    """Pack named demands into the fewest ``capacity``-sized bins (FFD).

    Items larger than one bin get a bin of their own (they are pipeline
    stages that will saturate a device regardless of placement).
    """
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    bins: list[tuple[float, list[str]]] = []  # (used, members)
    for name in sorted(items, key=lambda n: (-items[n], n)):
        demand = items[name]
        for i, (used, members) in enumerate(bins):
            if used + demand <= capacity:
                bins[i] = (used + demand, members + [name])
                break
        else:
            bins.append((demand, [name]))
    return [members for _, members in bins]


@dataclass
class BinPackingPolicy(ScalingPolicy):
    """Size the pool to the FFD bin count of per-stage demand.

    Each stage's demand is its observed records/sec (from the snapshot's
    ``stage_demands``), inflated by ``headroom`` plus a lag-proportional
    catch-up term so a backlogged pipeline packs into more bins than its
    steady state needs.
    """

    device_records_per_sec: float
    headroom: float = 0.2  # fraction of spare capacity per stage
    lag_weight: float = 0.5  # extra demand fraction per (lag / lag_norm)
    lag_norm: float = 1000.0
    min_devices: int = 1

    def desired_devices(self, snap: MetricsSnapshot) -> int:
        if not snap.stage_demands:
            return self.min_devices
        boost = 1.0 + self.headroom + self.lag_weight * (snap.lag / self.lag_norm)
        demands = {k: v * boost for k, v in snap.stage_demands.items() if v > 0}
        if not demands:
            return self.min_devices
        bins = first_fit_decreasing(demands, self.device_records_per_sec)
        # an oversized stage still only saturates whole devices
        extra = sum(
            math.ceil(sum(demands[m] for m in b) / self.device_records_per_sec) - 1
            for b in bins
        )
        return max(self.min_devices, len(bins) + extra)

    def decide(self, snap: MetricsSnapshot) -> ScalingDecision:
        desired = self.desired_devices(snap)
        # sized against the controlled pipeline, not pool-wide leases —
        # unrelated pilots sharing the service must not skew the delta
        delta = desired - snap.pipeline_devices
        if delta == 0:
            return HOLD
        return ScalingDecision(delta, f"ffd wants {desired} devices "
                                      f"(pipeline {snap.pipeline_devices}, lag {snap.lag:.0f})",
                               absolute=True)

"""MetricsBus — the shared telemetry sink of the elastic control plane.

Everything that used to live in per-engine silos (``StreamStats`` /
``BatchMetrics`` in the micro-batch engine, ``ContinuousStats`` in the
continuous engine) now has one home: engines, consumers and the broker
publish named samples here, and the :class:`ElasticController` /
``ScalingPolicy`` read a coherent :class:`MetricsSnapshot` back out.

Conventions (all optional — the bus is schemaless):

* ``stream.lag``             gauge, per-stream label — broker records behind
* ``stream.records``         counter, per-stream — total records processed
* ``stream.records_per_sec`` gauge, per-stream — last-batch throughput
* ``stream.processing_delay``/``stream.scheduling_delay`` gauges (seconds)
* ``stream.busy_frac``       gauge — processing_delay / batch_interval
* ``pool.devices_total``/``pool.devices_leased``/``pool.utilization`` gauges
* ``elastic.devices``/``elastic.lag``/``elastic.decision`` — controller
* ``elastic.actuation_ms``      gauge — wall-clock of one grow/shrink
  actuation, *including* any keyed-state migration it triggered
* ``state.migrated_partitions``/``state.migration_ms``/``state.bytes_moved``
  gauges, per-stream — published by the continuous engine's StateMigrator
  on every rescale (docs/state.md)
* ``workers.alive``/``workers.restarts`` gauges, per-stream — the mp
  executor's worker-process health (docs/workers.md)
* ``workers.restart_backoff_ms`` gauge, per-stream — the delay the most
  recent supervised respawn waited (restart-storm throttling)
* ``broker.retries``/``broker.failovers``/``broker.lost_records`` —
  fault-tolerance counters: producer/consumer retries through failover
  blackouts, leader promotions after a broker-node loss, and retained
  acked records dropped because a partition's only replica died (stays 0
  with ``replication_factor >= 2``); docs/faults.md
* ``broker.shed_records`` counter, per-member — records skipped by a
  ``max_lag``-bounded consumer's degraded mode instead of unbounded lag
* ``stream.recoveries``/``stream.recovery_ms`` and
  ``pipeline.stage_recoveries``/``pipeline.stage_recovery_ms`` —
  crash-recovery counts and latency (ContinuousStream.recover /
  StageReconciler)
* ``stream.latency_p50``/``stream.latency_p99`` gauges (seconds) — rolling
  per-batch compute-latency quantiles. The micro-batch engine publishes
  per-stream; the continuous engine's mp executor publishes per *worker*
  (labels ``stream`` + ``worker``) and then a per-stream aggregate, so
  per-stream readers resolve to the aggregate
* ``elastic.rescale_deferred`` — the controller held a tick because the
  last state migration is still amortizing (``migration_cost_frac``)
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable


@dataclass(frozen=True)
class Sample:
    name: str
    value: float
    t: float
    labels: tuple = ()  # sorted ((key, value), ...) pairs

    def label(self, key: str, default: str | None = None) -> str | None:
        for k, v in self.labels:
            if k == key:
                return v
        return default


class MetricsBus:
    """Thread-safe pub/sub metrics sink with bounded history.

    ``publish`` is cheap (deque append + dict put under one lock) so hot
    paths — the micro-batch loop, consumer polls — can call it per batch.
    """

    def __init__(self, max_history: int = 16384):
        self._lock = threading.Lock()
        self._history: deque[Sample] = deque(maxlen=max_history)
        self._latest: dict[tuple[str, tuple], Sample] = {}
        self._subscribers: list[Callable[[Sample], None]] = []

    # -- write side ----------------------------------------------------------

    def publish(self, name: str, value: float, *, t: float | None = None, **labels: str) -> Sample:
        s = Sample(name, float(value), time.monotonic() if t is None else t,
                   tuple(sorted(labels.items())))
        with self._lock:
            self._history.append(s)
            self._latest[(s.name, s.labels)] = s
            subs = list(self._subscribers)
        for fn in subs:  # outside the lock: subscribers may publish back
            try:
                fn(s)
            except Exception:
                pass  # a broken observer must never take down the data plane
        return s

    def subscribe(self, fn: Callable[[Sample], None]) -> Callable[[], None]:
        with self._lock:
            self._subscribers.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                if fn in self._subscribers:
                    self._subscribers.remove(fn)

        return unsubscribe

    # -- read side -----------------------------------------------------------

    def latest(self, name: str, **labels: str) -> Sample | None:
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            if labels or key in self._latest:
                return self._latest.get(key)
            # no labels given: most recent sample across all label sets
            best = None
            for (n, _), s in self._latest.items():
                if n == name and (best is None or s.t >= best.t):
                    best = s
            return best

    def value(self, name: str, default: float = 0.0, **labels: str) -> float:
        s = self.latest(name, **labels)
        return default if s is None else s.value

    def sum_latest(self, name: str) -> float:
        """Sum the latest sample of every label set of ``name`` (e.g. total
        lag across streams)."""
        with self._lock:
            return sum(s.value for (n, _), s in self._latest.items() if n == name)

    def latest_by_label(self, name: str, label: str) -> dict[str, float]:
        """Latest value per distinct value of ``label`` (e.g. per-stage
        demand for the bin-packing policy)."""
        return {k: s.value for k, s in self.samples_by_label(name, label).items()}

    def samples_by_label(self, name: str, label: str) -> dict[str, Sample]:
        """Like :meth:`latest_by_label` but whole samples — for readers
        that need the timestamp too (e.g. migration-cost amortization)."""
        out: dict[str, Sample] = {}
        with self._lock:
            for (n, _), s in self._latest.items():
                if n == name:
                    out[s.label(label, "")] = s
        return out

    def history(self, name: str | None = None, since: float = 0.0) -> list[Sample]:
        with self._lock:
            return [s for s in self._history
                    if (name is None or s.name == name) and s.t >= since]

    def series(self, name: str, since: float = 0.0) -> list[tuple[float, float]]:
        return [(s.t, s.value) for s in self.history(name, since)]

    def rate(self, name: str, window: float = 5.0, **labels: str) -> float:
        """Per-second rate of a counter over its last ``window`` seconds."""
        pts = [s for s in self.history(name) if not labels or
               s.labels == tuple(sorted(labels.items()))]
        if len(pts) < 2:
            return 0.0
        cutoff = pts[-1].t - window
        pts = [s for s in pts if s.t >= cutoff] or pts[-2:]
        dt = pts[-1].t - pts[0].t
        return (pts[-1].value - pts[0].value) / dt if dt > 0 else 0.0

    def clear(self) -> None:
        with self._lock:
            self._history.clear()
            self._latest.clear()


# ---------------------------------------------------------------------------
# per-engine stat records (moved here from engines/{microbatch,continuous}.py
# so both engines and the control plane share one vocabulary)
# ---------------------------------------------------------------------------


@dataclass
class BatchMetrics:
    batch_id: int
    n_records: int
    bytes: int
    processing_delay: float
    scheduling_delay: float
    end_to_end_latency: float  # now - oldest record timestamp


@dataclass
class StreamStats:
    batches: int = 0
    records: int = 0
    bytes: int = 0
    processing_time: float = 0.0
    history: list = field(default_factory=list)

    @property
    def records_per_sec(self) -> float:
        return self.records / self.processing_time if self.processing_time else 0.0


@dataclass
class ContinuousStats:
    records: int = 0
    fired_windows: int = 0
    late_records: int = 0
    per_record_latency: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# the read-side view policies consume
# ---------------------------------------------------------------------------


@dataclass
class MetricsSnapshot:
    """One coherent reconcile-time view assembled from the bus."""

    t: float
    lag: float  # total records behind, summed over streams
    records_per_sec: float
    processing_delay: float
    scheduling_delay: float
    busy_frac: float  # processing_delay / batch_interval (max over streams)
    devices_total: int
    devices_leased: int  # pool-wide, across ALL pilots in the service
    utilization: float  # leased / total
    #: devices serving the controlled pipeline (base + extensions) — what
    #: sizing policies must compare against; devices_leased counts unrelated
    #: pilots' leases too
    pipeline_devices: int = 0
    stage_demands: dict[str, float] = field(default_factory=dict)  # stream -> rec/s
    #: rolling per-batch compute-latency quantiles (max over streams,
    #: ``stream.latency_p50/p99`` gauges) — lets policies react to compute
    #: latency creep before it surfaces as lag
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    #: fraction of wall-clock time producers spent blocked in broker
    #: token buckets (``broker.stall_frac`` gauge) — the broker
    #: controller's saturation signal
    broker_stall_frac: float = 0.0
    #: duration of the last keyed-state migration (``state.migration_ms``
    #: gauge, max over streams) — lets policies weigh rescale benefit
    #: against the disruption it costs
    state_migration_ms: float = 0.0
    #: bus timestamp of the sample behind ``state_migration_ms`` — the
    #: controller's amortization gate keys off it (the gauge is latched:
    #: the engine republishes the *last* migration's cost forever), and
    #: carrying it here keeps the gate on the same stream-filtered view
    #: the policy decided on instead of re-reading the bus
    state_migration_t: float = 0.0

    @classmethod
    def capture(cls, bus: MetricsBus, pool: Any | None = None,
                pipeline_devices: int | None = None,
                stream: str | None = None) -> "MetricsSnapshot":
        """``pool`` is duck-typed (``DevicePool``): total/leased/utilization
        are read live when given, else from ``pool.*`` gauges on the bus.

        ``stream`` narrows the view to one stream label: without it, the
        latency/busy gauges take the max over ALL streams on the bus, which
        is wrong for a controller that manages just one stage of a
        multi-stage pipeline (another stage's saturation would trigger it).
        """

        def _per_stream(name: str) -> dict[str, float]:
            vals = bus.latest_by_label(name, "stream")
            if stream is not None:
                vals = {k: v for k, v in vals.items() if k == stream}
            return vals

        # a controller's lag probe is authoritative (fresh even when the
        # engine is too stalled to publish). Filtered captures look for the
        # probe sample labeled with their stream; unfiltered ones take any.
        if stream is None:
            probe_lag = bus.latest("elastic.lag")
        else:
            probe_lag = bus.latest("elastic.lag", stream=stream)
        if probe_lag is not None:
            lag = probe_lag.value
        else:
            lag = sum(_per_stream("stream.lag").values())
        if pool is not None:
            total = pool.total_devices
            leased = pool.leased_devices
            util = pool.utilization
        else:
            total = int(bus.value("pool.devices_total"))
            leased = int(bus.value("pool.devices_leased"))
            util = bus.value("pool.utilization")
        busy = max(_per_stream("stream.busy_frac").values(), default=0.0)
        stall = max(_per_stream("broker.stall_frac").values(), default=0.0)
        migr_samples = bus.samples_by_label("state.migration_ms", "stream")
        if stream is not None:
            migr_samples = {k: v for k, v in migr_samples.items() if k == stream}
        migr_sample = max(migr_samples.values(), key=lambda s: s.value, default=None)
        migr = 0.0 if migr_sample is None else migr_sample.value
        migr_t = 0.0 if migr_sample is None else migr_sample.t
        p50 = max(_per_stream("stream.latency_p50").values(), default=0.0)
        p99 = max(_per_stream("stream.latency_p99").values(), default=0.0)
        demands = _per_stream("stream.records_per_sec")
        if stream is None:
            proc_delay = bus.value("stream.processing_delay")
            sched_delay = bus.value("stream.scheduling_delay")
        else:
            proc_delay = _per_stream("stream.processing_delay").get(stream, 0.0)
            sched_delay = _per_stream("stream.scheduling_delay").get(stream, 0.0)
        return cls(
            t=time.monotonic(),
            lag=lag,
            records_per_sec=sum(demands.values()),
            processing_delay=proc_delay,
            scheduling_delay=sched_delay,
            busy_frac=busy,
            devices_total=total,
            devices_leased=leased,
            utilization=util,
            pipeline_devices=leased if pipeline_devices is None else pipeline_devices,
            stage_demands=demands,
            latency_p50=p50,
            latency_p99=p99,
            broker_stall_frac=stall,
            state_migration_ms=migr,
            state_migration_t=migr_t,
        )

"""Elastic autoscaling control plane (paper §4.2 "dynamically respond to
resource requirements by adding/removing resources at runtime").

bus (``MetricsBus``) -> policy (``ScalingPolicy``) -> reconciler
(``ElasticController``) -> pilots (``submit_pilot(parent=...)`` / ``cancel``).
See docs/elastic.md for the architecture and a quickstart.
"""
from repro.elastic.controller import (
    ElasticConfig,
    ElasticController,
    PreemptionHooks,
)
from repro.elastic.events import EventLog, ScalingEvent, timeline
from repro.elastic.forecast import ForecastPolicy
from repro.elastic.metrics import (
    BatchMetrics,
    ContinuousStats,
    MetricsBus,
    MetricsSnapshot,
    Sample,
    StreamStats,
)
from repro.elastic.policy import (
    HOLD,
    BinPackingPolicy,
    BrokerSaturationPolicy,
    LatencyPolicy,
    PIDScalingPolicy,
    ScalingDecision,
    ScalingPolicy,
    SLOPolicy,
    ThresholdHysteresisPolicy,
    first_fit_decreasing,
)

__all__ = [
    "BatchMetrics",
    "BinPackingPolicy",
    "BrokerSaturationPolicy",
    "ContinuousStats",
    "ElasticConfig",
    "ElasticController",
    "EventLog",
    "ForecastPolicy",
    "HOLD",
    "LatencyPolicy",
    "MetricsBus",
    "MetricsSnapshot",
    "PIDScalingPolicy",
    "PreemptionHooks",
    "Sample",
    "SLOPolicy",
    "ScalingDecision",
    "ScalingEvent",
    "ScalingPolicy",
    "StreamStats",
    "ThresholdHysteresisPolicy",
    "first_fit_decreasing",
    "timeline",
]

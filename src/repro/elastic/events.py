"""Scaling events and timeline export (the paper's Fig. 8 data product).

``timeline`` folds the MetricsBus history plus the controller's event log
into one JSON-serializable dict — lag / devices / throughput vs. time —
consumed by ``benchmarks/elasticity.py`` and ``docs/elastic.md`` plots.
"""
from __future__ import annotations

from dataclasses import dataclass, field

DEFAULT_SERIES = (
    "stream.lag",
    "stream.records_per_sec",
    "elastic.devices",
    "elastic.lag",
)


@dataclass(frozen=True)
class ScalingEvent:
    t: float
    action: str  # "scale_up" | "scale_down" | "rejected"
    delta: int
    devices_before: int
    devices_after: int
    reason: str = ""

    def to_dict(self) -> dict:
        return {
            "t": self.t,
            "action": self.action,
            "delta": self.delta,
            "devices_before": self.devices_before,
            "devices_after": self.devices_after,
            "reason": self.reason,
        }


@dataclass
class EventLog:
    events: list[ScalingEvent] = field(default_factory=list)

    def record(self, event: ScalingEvent) -> ScalingEvent:
        self.events.append(event)
        return event

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def of(self, action: str) -> list[ScalingEvent]:
        return [e for e in self.events if e.action == action]


def timeline(bus, events=(), *, names=DEFAULT_SERIES, t0: float | None = None) -> dict:
    """Bus history + events -> ``{"series": {name: [[t, v], ...]}, "events": [...]}``.

    Times are made relative to ``t0`` (default: earliest point) so the JSON
    is stable across runs and plottable as seconds-from-start.
    """
    series = {name: bus.series(name) for name in names}
    series = {n: pts for n, pts in series.items() if pts}
    ev = sorted(events, key=lambda e: e.t)
    if t0 is None:
        starts = [pts[0][0] for pts in series.values()] + [e.t for e in ev]
        t0 = min(starts) if starts else 0.0
    return {
        "t0": t0,
        "series": {
            n: [[round(t - t0, 4), v] for t, v in pts] for n, pts in series.items()
        },
        "events": [
            {**e.to_dict(), "t": round(e.t - t0, 4)} for e in ev
        ],
    }

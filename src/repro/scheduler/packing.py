"""OnlinePacker — incremental bin maintenance for arbiter placement.

The arbiter used to re-run first-fit-decreasing from scratch every
``placement()`` call, which has two costs at production scale: the packing
is O(groups x bins) per tick even when nothing changed, and — worse — FFD
is *unstable*: a one-device demand change can reshuffle every group into a
different bin, which at the runner layer would mean pointless pilot/state
movement. The online bin-packing formulation (Stein et al.,
arXiv:2001.10865) amends the existing packing instead:

* an unchanged group stays exactly where it is (zero relocations is the
  steady state);
* a resized group first tries to grow/shrink *in place*; only when its bin
  overflows is it relocated, first-fit, to another bin (counted in
  :attr:`relocations` — the instability metric the per-tick-FFD design
  couldn't even report);
* a new group is placed first-fit into the existing bins, else opens a
  fresh bin;
* a departed group is removed, and emptied bins are dropped.

Bin *identity* is positional and sticky: at the runner layer a bin maps to
a host/pilot, so "group stayed in bin 2" is exactly "no state moved".
"""
from __future__ import annotations


class OnlinePacker:
    """Maintains ``group name -> bin`` across repeated demand revisions.

    Not thread-safe; the arbiter calls it under its own lock.
    """

    def __init__(self, capacity: float):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = float(capacity)
        #: bin index -> {group: size}; bins keep their index across calls
        self._bins: list[dict[str, float]] = []
        #: cumulative groups moved to a different bin by a resize (new
        #: placements and capacity resets don't count — only churn does)
        self.relocations = 0

    # -- introspection -------------------------------------------------------

    @property
    def bins(self) -> list[dict[str, float]]:
        return [dict(b) for b in self._bins]

    def bin_of(self, group: str) -> int | None:
        for i, b in enumerate(self._bins):
            if group in b:
                return i
        return None

    def _load(self, b: dict[str, float]) -> float:
        return sum(b.values())

    def _first_fit(self, size: float) -> int:
        """Index of the first bin with room, appending a new one if none
        (an oversized group still gets a bin of its own, like FFD)."""
        for i, b in enumerate(self._bins):
            if self._load(b) + size <= self.capacity:
                return i
        self._bins.append({})
        return len(self._bins) - 1

    # -- the amendment pass --------------------------------------------------

    def repack(self, demands: dict[str, float]) -> list[list[str]]:
        """Amend the packing to ``demands`` (group -> size; zero/negative
        sizes mean the group holds nothing and is unplaced). Returns the
        bins as ordered group-name lists, empty bins elided."""
        live = {g: float(s) for g, s in demands.items() if s > 0}

        # departures (and zero-size groups) leave their bins
        for b in self._bins:
            for g in [g for g in b if g not in live]:
                del b[g]

        # resizes: in place when the bin still fits, relocate otherwise.
        # Shrinks always fit; process growths largest-first so a bin's
        # survivors are judged against the post-shrink load.
        for g in sorted(live, key=lambda g: -live[g]):
            i = self.bin_of(g)
            if i is None:
                continue  # new group, placed below
            b = self._bins[i]
            if b[g] == live[g]:
                continue
            grew = live[g] > b[g]
            b[g] = live[g]
            if grew and self._load(b) > self.capacity and len(b) > 1:
                del b[g]
                self._bins[self._first_fit(live[g])][g] = live[g]
                self.relocations += 1

        # arrivals: first-fit, largest first (the FFD ordering, but only
        # over the new groups — incumbents don't move for an arrival)
        placed = {g for b in self._bins for g in b}
        for g in sorted(live.keys() - placed, key=lambda g: (-live[g], g)):
            self._bins[self._first_fit(live[g])][g] = live[g]

        self._bins = [b for b in self._bins if b]
        return [list(b) for b in self._bins]

    def reset(self, capacity: float | None = None) -> None:
        """Forget the packing (e.g. the bin size changed — positions keyed
        to the old capacity are meaningless)."""
        if capacity is not None:
            if capacity <= 0:
                raise ValueError("capacity must be positive")
            self.capacity = float(capacity)
        self._bins = []

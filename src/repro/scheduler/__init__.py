"""Unified resource arbitration (paper §4.2, promoted to cluster level).

One :class:`ResourceArbiter` per :class:`PilotComputeService` mediates every
consumer's demand — pipeline stages, the broker, training drivers — against
the shared ``DevicePool``: weighted fair share within priority tiers, FFD
bin-packing for placement, preemption under pressure. Consumers file
:class:`ResourceRequest`\\ s instead of acquiring pilots themselves; see
docs/scheduler.md for the request/grant lifecycle.
"""
from repro.scheduler.arbiter import PoolTenant, ResourceArbiter, weighted_fair_share
from repro.scheduler.request import DEVICES, HOSTS, ResourceRequest

__all__ = [
    "DEVICES",
    "HOSTS",
    "PoolTenant",
    "ResourceArbiter",
    "ResourceRequest",
    "weighted_fair_share",
]

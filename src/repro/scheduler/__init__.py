"""Unified resource arbitration (paper §4.2, promoted to cluster level).

One :class:`ResourceArbiter` per :class:`PilotComputeService` mediates every
consumer's demand — pipeline stages, the broker, training drivers — against
the shared ``DevicePool``: weighted fair share within priority tiers, gang
(all-or-nothing) grants for ``colocate_with`` groups, online bin-packing
for placement (:class:`OnlinePacker` — bins are amended, not recomputed),
preemption under pressure. Consumers file :class:`ResourceRequest`\\ s
instead of acquiring pilots themselves; see docs/scheduler.md for the
request/grant lifecycle.
"""
from repro.scheduler.arbiter import (
    PoolTenant,
    ResourceArbiter,
    colocation_groups,
    weighted_fair_share,
)
from repro.scheduler.packing import OnlinePacker
from repro.scheduler.request import DEVICES, HOSTS, ResourceRequest

__all__ = [
    "DEVICES",
    "HOSTS",
    "OnlinePacker",
    "PoolTenant",
    "ResourceArbiter",
    "ResourceRequest",
    "colocation_groups",
    "weighted_fair_share",
]

"""ResourceArbiter — one scheduler over the shared DevicePool.

The paper's application-level resource management, promoted from per-stage
autoscaling to cluster-level scheduling: every consumer (stage controller,
broker controller, training driver) files a :class:`ResourceRequest`, and
each reconcile tick the arbiter

1. reads every request's ``demand`` (the estimator-set target clamped to
   its [min, max] band),
2. computes a **weighted fair-share** allocation of the arbitrable device
   capacity — strict priority tiers, stride-scheduled proportional shares
   within a tier (Stein et al., arXiv:2001.10865; de Assunção et al.,
   arXiv:1709.01363),
3. actuates the diff — shrinks (revocations/preemptions) before grows so
   freed devices are available to the grants that need them; co-located
   groups actuate as atomic **gang units** (all-or-nothing, rolled back on
   partial success),
4. publishes every decision to the MetricsBus as ``scheduler.*`` gauges
   and records grant/revoke/preempt events in an :class:`EventLog`.

``placement()`` additionally packs the granted sizes into host-sized bins,
honoring ``colocate_with`` hints — the spec-level placement story
(co-located stages share one bin, and, at the runner layer, one pilot).
Since the predictive-scheduling PR the packing is *online*
(:class:`repro.scheduler.packing.OnlinePacker`): bins are amended
incrementally across ticks instead of re-running FFD from scratch, so an
unchanged group never moves hosts.
"""
from __future__ import annotations

import threading
import time
from typing import Iterable

from repro.elastic.events import EventLog, ScalingEvent
from repro.elastic.metrics import MetricsBus
from repro.scheduler.packing import OnlinePacker
from repro.scheduler.request import DEVICES, HOSTS, ResourceRequest


def colocation_groups(
    requests: Iterable[ResourceRequest],
) -> dict[str, list[ResourceRequest]]:
    """Union ``colocate_with`` chains onto their (non-colocated) root:
    root name -> member requests (singletons included, cycles tolerated).
    The gang-scheduling and placement unit."""
    reqs = {r.name: r for r in requests}
    root: dict[str, str] = {}
    for name in reqs:
        t, seen = name, set()
        while (reqs.get(t) is not None and reqs[t].colocate_with in reqs
               and t not in seen):
            seen.add(t)
            t = reqs[t].colocate_with
        root[name] = t
    groups: dict[str, list[ResourceRequest]] = {}
    for name, r in reqs.items():
        groups.setdefault(root[name], []).append(r)
    return groups


def weighted_fair_share(
    requests: Iterable[ResourceRequest], capacity: int
) -> dict[str, int]:
    """Pure allocation: name -> granted devices.

    Floors first (every request keeps its ``min_devices`` — the base pilot
    already holds them), then the remaining capacity is handed out one
    device at a time, highest priority tier first; within a tier the next
    device goes to the request with the smallest ``allocated / weight``
    ratio (stride scheduling), so sustained contention converges to a
    weight-proportional split.
    """
    reqs = list(requests)
    # floors are unconditional: the base pilots physically hold them already
    alloc = {r.name: r.min_devices for r in reqs}
    remaining = capacity - sum(alloc.values())
    for tier in sorted({r.priority for r in reqs}, reverse=True):
        if remaining <= 0:
            break
        active = [r for r in reqs if r.priority == tier and alloc[r.name] < r.demand]
        while remaining > 0 and active:
            r = min(active, key=lambda q: (alloc[q.name] / q.weight, q.name))
            alloc[r.name] += 1
            remaining -= 1
            if alloc[r.name] >= r.demand:
                active.remove(r)
    return alloc


class PoolTenant:
    """Minimal actuator for consumers that hold raw pool leases rather than
    pilots — arriving tenants in benchmarks/tests, external frameworks,
    batch drivers. ``scale_to`` is the grant callback; leases are acquired
    and released against the service's real DevicePool so the arbiter's
    capacity accounting stays honest."""

    def __init__(self, service):
        self.service = service
        self.leases: list = []

    @property
    def devices(self) -> int:
        return sum(len(l.devices) for l in self.leases)

    def scale_to(self, n: int) -> int:
        from repro.core.plugin import Lease

        cur = self.devices
        if n > cur:
            take = min(n - cur, self.service.pool.free_devices)
            if take > 0:
                self.leases.append(self.service.pool.acquire(take, 0))
        elif n < cur:
            excess = cur - n
            while excess > 0 and self.leases:
                lease = self.leases[-1]
                if len(lease.devices) <= excess:
                    excess -= len(lease.devices)
                    self.leases.pop()
                    self.service.pool.release(lease)
                else:
                    # carve the excess off the newest lease (release is
                    # per-device, so a sub-lease hands back exactly those)
                    give = lease.devices[-excess:]
                    del lease.devices[-excess:]
                    self.service.pool.release(Lease(lease.lease_id, give, []))
                    excess = 0
        return self.devices

    def request(self, name: str, **kw) -> ResourceRequest:
        """A ResourceRequest wired to this tenant's actuator."""
        return ResourceRequest(name, actuator=self.scale_to,
                               current_fn=lambda: self.devices, **kw)

    def close(self) -> None:
        for lease in self.leases:
            self.service.pool.release(lease)
        self.leases = []


class ResourceArbiter:
    """The single decision point between demand estimators and the pool.

    One arbiter per :class:`PilotComputeService`; several ``PipelineRun``\\ s
    sharing a service share the arbiter, so their requests are fair-shared
    against each other instead of racing first-come-first-served.

    Drive it with ``start()/stop()`` (background loop, woken early by
    ``update``) or call ``reconcile()`` directly for deterministic tests.
    """

    def __init__(self, service, bus: MetricsBus | None = None, *,
                 interval: float = 0.25):
        self.service = service
        self.bus = bus if bus is not None else MetricsBus()
        self.interval = interval
        self.events = EventLog()
        self._requests: dict[str, ResourceRequest] = {}
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._refs = 0
        self._ticks = 0
        self.preemptions = 0
        #: incremental placement state (OnlinePacker), created on first
        #: placement() call; sticky across ticks by design
        self._packer: OnlinePacker | None = None

    # -- request book ---------------------------------------------------------

    def submit(self, request: ResourceRequest) -> ResourceRequest:
        """File (or replace, by name) a request. Returns the live handle."""
        with self._lock:
            self._requests[request.name] = request
        self.bus.publish("scheduler.requests", len(self._requests))
        self._wake.set()
        return request

    def withdraw(self, name: str) -> None:
        with self._lock:
            self._requests.pop(name, None)
        self.bus.publish("scheduler.requests", len(self._requests))

    def update(self, name: str, target: int) -> None:
        """Estimator entry point: revise one request's demand and wake the
        reconcile loop so the grant lands within (at most) one interval."""
        with self._lock:
            req = self._requests.get(name)
        if req is None:
            raise KeyError(f"no request named {name!r}")
        req.set_target(target)
        self.bus.publish("scheduler.demand", req.demand, request=name)
        self._wake.set()

    def request(self, name: str) -> ResourceRequest:
        with self._lock:
            return self._requests[name]

    @property
    def requests(self) -> list[ResourceRequest]:
        with self._lock:
            return list(self._requests.values())

    @property
    def ticks(self) -> int:
        return self._ticks

    # -- allocation -----------------------------------------------------------

    def _device_capacity(self, device_reqs: list[ResourceRequest]) -> int:
        """Devices the arbiter may hand out: the pool's free devices plus
        whatever its own participants currently hold. Leases of
        non-participant pilots are off the table."""
        return self.service.pool.free_devices + sum(r.current for r in device_reqs)

    def allocate(self) -> dict[str, int]:
        """The sizing decision alone (no actuation) — name -> devices."""
        with self._lock:
            reqs = list(self._requests.values())
        return self._allocate(reqs)

    def _allocate(self, reqs: list[ResourceRequest]) -> dict[str, int]:
        """Fair share with **gang feasibility**: a ``colocate_with`` group
        is all-or-nothing. If contention leaves any member of a multi-member
        gang below a runnable grant (``max(1, min_devices)``) while a
        sibling would run, the whole gang is withheld to its floors and the
        freed capacity redistributed — no partial co-located group is ever
        granted. Iterates until every surviving gang is whole (bounded by
        the number of gangs)."""
        device_reqs = [r for r in reqs if r.unit == DEVICES]
        capacity = self._device_capacity(device_reqs)
        active = list(device_reqs)
        withheld: dict[str, int] = {}
        while True:
            alloc = weighted_fair_share(active, capacity - sum(withheld.values()))
            infeasible: list[list[ResourceRequest]] = []
            for members in colocation_groups(active).values():
                if len(members) < 2:
                    continue
                runnable = [m for m in members
                            if alloc.get(m.name, 0) >= max(1, m.min_devices)]
                # all runnable = whole gang placed; none runnable = gang
                # atomically at zero (nothing placed) — both are fine.
                if runnable and len(runnable) < len(members):
                    infeasible.append(members)
            if not infeasible:
                break
            for members in infeasible:
                for m in members:
                    withheld[m.name] = m.min_devices
                    active.remove(m)
        alloc.update(withheld)
        # host-unit requests (broker nodes) are logical slots: clamp, don't
        # contend — the DevicePool's host slots are unbounded
        for r in reqs:
            if r.unit == HOSTS:
                alloc[r.name] = r.demand
        return alloc

    # -- reconcile ------------------------------------------------------------

    def reconcile(self) -> dict[str, int]:
        """One scheduling pass: allocate, then actuate the diff.

        Actuation is by **gang unit**: a ``colocate_with`` group's members
        actuate together (shrinks first within the unit), and if any member
        fails — its actuator raises, or reaches less than the allocation —
        every member already actuated in that unit is rolled back to its
        pre-pass size. A co-located group is therefore never left partially
        granted, no matter where mid-flight contention bites. Singleton
        units keep the old per-request semantics (a clamped grant stands).

        Units with net shrinks run before net grows (freed devices fund the
        grants), and actuators are only invoked on a changed allocation, so
        repeated reconciles with unchanged demand are no-ops (grant
        idempotence).

        One snapshot of the request book feeds both sizing and actuation:
        a request submitted mid-pass is simply not scheduled until the
        next tick (never actuated against an allocation it was absent
        from), and one withdrawn mid-pass is skipped at actuation time.
        """
        now = time.monotonic()
        self._ticks += 1
        with self._lock:
            reqs = list(self._requests.values())
        alloc = self._allocate(reqs)
        granted: dict[str, int] = {}

        def delta(r: ResourceRequest) -> int:
            return alloc.get(r.name, 0) - r.current

        units = sorted(colocation_groups(reqs).values(),
                       key=lambda unit: sum(delta(r) for r in unit))
        for unit in units:  # most negative net delta (biggest shrink) first
            gang = len(unit) > 1
            done: list[tuple[ResourceRequest, int]] = []  # (req, prior size)
            rollback = False
            for r in sorted(unit, key=delta):
                with self._lock:
                    if self._requests.get(r.name) is not r:
                        continue  # withdrawn (or replaced) since the snapshot
                want = alloc.get(r.name, 0)
                cur = r.current
                if r.actuator is None or want == cur:
                    r.granted = want if r.actuator is None else cur
                    granted[r.name] = r.granted
                    continue
                try:
                    reached = r.actuator(want)
                except Exception:
                    self.bus.publish("scheduler.errors", 1.0, request=r.name)
                    granted[r.name] = cur
                    if gang:
                        rollback = True
                        break
                    continue
                done.append((r, cur))
                if gang and reached != want:
                    rollback = True  # partial gang: undo the whole unit
                    break
                r.granted = reached
                granted[r.name] = reached
                action = "grant" if want > cur else (
                    # a shrink below the consumer's own demand was forced by
                    # someone else's priority/weight — that is a preemption
                    "preempt" if r.demand > want else "revoke"
                )
                if action == "preempt":
                    self.preemptions += 1
                    self.bus.publish("scheduler.preemptions", self.preemptions)
                self.events.record(ScalingEvent(
                    now, action, reached - cur, cur, reached,
                    f"alloc {want} (demand {r.demand}, weight {r.weight}, "
                    f"priority {r.priority})",
                ))
                self.bus.publish("scheduler.event", float(reached - cur),
                                 request=r.name, action=action)
            if rollback:
                for r, prior in reversed(done):
                    try:
                        r.actuator(prior)
                    except Exception:
                        self.bus.publish("scheduler.errors", 1.0, request=r.name)
                    r.granted = r.current
                    granted[r.name] = r.granted
                    self.events.record(ScalingEvent(
                        now, "gang_rollback", 0, prior, r.current,
                        f"co-located group partially grantable only — "
                        f"alloc {alloc.get(r.name, 0)} undone",
                    ))
                    self.bus.publish("scheduler.event", 0.0, request=r.name,
                                     action="gang_rollback")
        for name, n in granted.items():
            self.bus.publish("scheduler.granted", n, request=name)
        self.bus.publish("scheduler.capacity", self.service.pool.total_devices)
        self.bus.publish("scheduler.free", self.service.pool.free_devices)
        return granted

    # -- placement ------------------------------------------------------------

    def placement(self, allocation: dict[str, int] | None = None, *,
                  bin_size: int | None = None) -> list[list[str]]:
        """Pack the granted sizes into ``bin_size``-device bins, with
        ``colocate_with`` groups merged so co-located requests always land
        in the same bin. Default bin size: the whole pool (one host).

        Packing is **online** (:class:`OnlinePacker`): the previous call's
        bins are amended — unchanged groups never move, resizes relocate a
        group only when its bin overflows — instead of re-running FFD from
        scratch each tick. Bin indices are therefore sticky across calls,
        and the churn is observable as the ``scheduler.relocations``
        counter (cumulative groups moved)."""
        alloc = self.allocate() if allocation is None else allocation
        with self._lock:
            reqs = [r for r in self._requests.values() if r.unit == DEVICES]
        demands: dict[str, float] = {}
        members: dict[str, list[str]] = {}
        for g, group in colocation_groups(reqs).items():
            demands[g] = float(sum(alloc.get(r.name, 0) for r in group))
            members[g] = sorted(r.name for r in group)
        cap = float(bin_size or max(self.service.pool.total_devices, 1))
        with self._lock:
            if self._packer is None:
                self._packer = OnlinePacker(cap)
            elif self._packer.capacity != cap:
                self._packer.reset(cap)  # repositioning wholesale, not churn
            bins = self._packer.repack(demands)
            relocations = self._packer.relocations
        self.bus.publish("scheduler.relocations", relocations)
        return [[m for g in b for m in members[g]] for b in bins]

    # -- lifecycle ------------------------------------------------------------

    def retain(self) -> "ResourceArbiter":
        """Refcounted start: each PipelineRun (or driver) retains the shared
        arbiter; the loop stops when the last one releases it."""
        with self._lock:
            self._refs += 1
            start = self._refs == 1
        if start:
            self.start()
        return self

    def release(self) -> None:
        with self._lock:
            self._refs = max(self._refs - 1, 0)
            stop = self._refs == 0
        if stop:
            self.stop()

    def start(self) -> "ResourceArbiter":
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(self.interval)
            self._wake.clear()
            if self._stop.is_set():
                return
            try:
                self.reconcile()
            except Exception:
                self.bus.publish("scheduler.errors", 1.0)

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

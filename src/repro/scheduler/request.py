"""ResourceRequest — the declarative demand unit the arbiter trades in.

Consumers (stage controllers, the broker controller, training drivers) no
longer acquire pilots themselves; they file one request each —
``min``/``target``/``max`` resource counts plus ``weight``, ``priority``
and an optional co-location hint — and receive *grants* back. The request
object is the live handle: estimators mutate ``target`` (via
``ResourceArbiter.update``), the arbiter mutates ``granted``, and the
``actuator`` callback is how a grant becomes actual pilots.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable

#: request units. DEVICES are arbitrated against the shared DevicePool
#: (scarce, fair-shared); HOSTS are logical broker slots (plentiful —
#: clamped to [min, max] but never contended).
DEVICES = "devices"
HOSTS = "hosts"


@dataclass
class ResourceRequest:
    """One consumer's standing demand against the shared pool.

    ``target`` is the estimator's current wish; the arbiter only ever
    grants within ``[min_devices, max_devices]``. ``weight`` sets the
    proportional share among equal-priority requests; ``priority`` is
    strict — a higher tier is filled to its demand before a lower tier
    sees anything beyond its floor (that is what preemption means here).
    """

    name: str
    min_devices: int = 0
    max_devices: int | None = None
    weight: float = 1.0
    priority: int = 0
    #: name of another request whose placement bin this one must share
    colocate_with: str | None = None
    unit: str = DEVICES
    #: ``actuator(n)`` must (idempotently) scale the consumer to exactly
    #: ``n`` resources and return the count actually reached. ``None`` =
    #: a static reservation: capacity accounting only, no actuation.
    actuator: Callable[[int], int] | None = None
    #: live resource count as the consumer sees it (base pilot included);
    #: falls back to ``granted`` when unset
    current_fn: Callable[[], int] | None = None
    target: int = 0
    granted: int = field(default=0)

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"request {self.name!r}: weight must be > 0")
        if self.min_devices < 0:
            raise ValueError(f"request {self.name!r}: min_devices must be >= 0")
        if self.max_devices is not None and self.max_devices < self.min_devices:
            raise ValueError(
                f"request {self.name!r}: max_devices {self.max_devices} < "
                f"min_devices {self.min_devices}"
            )
        self._lock = threading.Lock()

    # -- demand --------------------------------------------------------------

    @property
    def demand(self) -> int:
        """``target`` clamped into the request's own [min, max] band."""
        with self._lock:
            t = max(self.target, self.min_devices)
            if self.max_devices is not None:
                t = min(t, self.max_devices)
            return t

    def set_target(self, n: int) -> None:
        with self._lock:
            self.target = int(n)

    @property
    def current(self) -> int:
        """Resources this request actually *holds*: the live view when a
        ``current_fn`` is wired, the last actuated grant when only an
        actuator is, and 0 for a pure reservation (neither) — a
        reservation holds nothing, so counting its grant as arbitrable
        capacity would double-count free devices and erode the floor it
        exists to protect."""
        if self.current_fn is not None:
            return self.current_fn()
        if self.actuator is not None:
            return self.granted
        return 0

"""Streaming K-Means (paper §5/§6.4): MASS cluster source -> broker -> MASA,
declared as one pipeline spec. The "kmeans" processor and "cluster" source
are the built-in Mini-Apps, referenced by name.

    PYTHONPATH=src python examples/streaming_kmeans.py
"""
from repro.miniapps import StreamingKMeans
from repro.pipeline import Pipeline, register_processor

inertias = []


@register_processor("kmeans_traced")
class TracedKMeans(StreamingKMeans):
    """The built-in MASA app, recording inertia per batch for the
    convergence check below."""

    def process(self, state, msgs):
        state = super().process(state, msgs)
        inertias.append(self.inertia)
        return state


pipe = (Pipeline.named("streaming-kmeans")
        .broker(nodes=2)
        .topic("points", partitions=8)
        .source("points", kind="cluster", rate_msgs_per_s=200,
                total_messages=40, n_producers=4,
                n_clusters=10, dim=3, points_per_msg=2000)
        .stage("cluster", topic="points", processor="kmeans_traced",
               batch_interval=0.05, max_batch_records=4,
               n_clusters=10, dim=3, decay=0.7)
        .build())

with pipe.run(devices=4) as run:
    run.await_batches("cluster", 10, timeout=60)
    app, stream = run.processor("cluster"), run.stream("cluster")

print(f"batches: {stream.stats.batches}, points: {app.stats.items}")
print("inertia trajectory:", " -> ".join(f"{x:.1f}" for x in inertias[:10]))
print(f"throughput: {app.stats.msgs_per_sec:.1f} msgs/s (compute-side)")
assert inertias[-1] < inertias[0], "centroids should improve with streaming updates"
print("streaming kmeans OK")

"""Streaming K-Means (paper §5/§6.4): MASS cluster source -> broker -> MASA.

Shows model convergence (inertia drops) and PID backpressure keeping the
pipeline balanced.

    PYTHONPATH=src python examples/streaming_kmeans.py
"""
import numpy as np

from repro.core import PilotComputeService
from repro.miniapps import KMeansClusterSource, SourceConfig, StreamingKMeans

svc = PilotComputeService()
cluster = svc.submit_pilot({"number_of_nodes": 2, "type": "kafka"}).get_context()
cluster.create_topic("points", 8)
ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()

source = KMeansClusterSource(
    cluster,
    SourceConfig("points", total_messages=40, n_producers=4, rate_msgs_per_s=200),
    n_clusters=10, dim=3, points_per_msg=2000,
)
app = StreamingKMeans(n_clusters=10, dim=3, decay=0.7)

inertias = []

def process(state, msgs):
    state = app.process(state, msgs)
    inertias.append(app.inertia)
    return state

stream = ctx.stream(cluster, "points", group="kmeans", process_fn=process,
                    batch_interval=0.05, max_batch_records=4).start()
source.start()
stream.await_batches(10, timeout=60)
stream.stop()
source.stop()

print(f"batches: {stream.stats.batches}, points: {app.stats.items}")
print("inertia trajectory:", " -> ".join(f"{x:.1f}" for x in inertias[:10]))
print(f"throughput: {app.stats.msgs_per_sec:.1f} msgs/s (compute-side)")
assert inertias[-1] < inertias[0], "centroids should improve with streaming updates"
svc.cancel()
print("streaming kmeans OK")

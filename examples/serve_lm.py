"""Batched LM serving from a request stream (deliverable (b), serving kind).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    sys.argv = ["serve", "--arch", "smollm-135m", "--reduced", "--requests", "6",
                "--batch", "2", "--prompt-len", "32", "--gen-tokens", "8"]
    serve_mod.main()

"""Light-source pipeline with **elastic scaling** (the paper's headline
capability): a template source streams sinogram frames; ML-EM reconstruction
falls behind (backpressure/lag builds); extending the processing pilot at
runtime rebalances the pipeline.

    PYTHONPATH=src python examples/lightsource_pipeline.py
"""
import time

from repro.core import PilotComputeDescription, PilotComputeService
from repro.miniapps import LightsourceTemplateSource, ReconstructionApp, SourceConfig

svc = PilotComputeService()
kafka = svc.submit_pilot({"number_of_nodes": 2, "type": "kafka"})
cluster = kafka.get_context()
cluster.create_topic("frames", 4)
spark = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
ctx = spark.get_context()

source = LightsourceTemplateSource(
    cluster, SourceConfig("frames", total_messages=12, n_producers=2),
    n_angles=48, n_det=64,
)
app = ReconstructionApp("mlem", n=64, mlem_iters=2)

stream = ctx.stream(cluster, "frames", group="recon", process_fn=app.process,
                    batch_interval=0.05, max_batch_records=1).start()
source.start()
stream.await_batches(2, timeout=120)
lag_before = sum(stream.lag().values())

# runtime extension (paper Listing 4): add processing resources mid-stream
ext = svc.submit_pilot(PilotComputeDescription(number_of_nodes=1, framework="spark",
                                               parent=spark))
print(f"extended processing pilot; engine devices: {len(spark.get_context().devices)}")

stream.await_batches(6, timeout=240)
stream.stop()
source.stop()
lag_after = sum(stream.lag().values())
print(f"reconstructed {app.stats.batches} batches; lag {lag_before} -> {lag_after}")
print(f"last reconstruction shape: {stream.state.shape}")
svc.cancel()
print("lightsource pipeline OK")

"""Closed-loop elastic streaming pipeline (paper §4.2, Fig. 8).

MASS source -> broker pilot -> micro-batch pilot, with the new
``repro.elastic`` control plane on top: the stream publishes lag and
throughput to a MetricsBus, a threshold policy watches it, and the
ElasticController grows the pilot with an extension pilot when the producer
rate doubles — then shrinks back once the burst passes.

    PYTHONPATH=src python examples/elastic_pipeline.py
"""
import time

import numpy as np

from repro.core import PilotComputeService
from repro.elastic import (
    ElasticConfig,
    ElasticController,
    MetricsBus,
    ThresholdHysteresisPolicy,
)
from repro.miniapps import RateStepScenario, SourceConfig, StreamSource


class PointSource(StreamSource):
    def make_message(self, rng, i):
        return rng.normal(size=(16,))


svc = PilotComputeService(devices=list(range(8)))
bus = MetricsBus()

cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
cluster.create_topic("points", 4)
engine = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
ctx = engine.get_context()

# a data-parallel stage: per-message cost shrinks as devices are added, and
# on_rescale re-reads the device count (the paper's resharding hook)
capacity = {"n": 2}

def process(state, msgs):
    time.sleep(len(msgs) * 0.01 / capacity["n"])
    return (state or 0) + len(msgs)

stream = ctx.stream(cluster, "points", group="elastic", process_fn=process,
                    batch_interval=0.05, max_batch_records=32,
                    backpressure=False, metrics=bus)
stream.on_rescale = lambda devices: (capacity.update(n=max(len(devices), 1)),
                                     stream.state)[1]

controller = ElasticController(
    svc, engine, bus,
    ThresholdHysteresisPolicy(high_lag=80, low_lag=15, up_stable=2, down_stable=3),
    config=ElasticConfig(interval=0.1, min_devices=2, max_devices=6,
                         devices_per_step=2, cooldown=1.2),
    lag_probe=lambda: sum(stream.lag().values()),
)

source = PointSource(cluster, SourceConfig("points", rate_msgs_per_s=60))
burst = RateStepScenario(source, [(1.0, 60), (5.0, 300), (5.0, 40)])

stream.start()
source.start()
controller.start()
burst.start()

t0 = time.monotonic()
while not (burst.finished and controller.devices == 2):
    lag = sum(stream.lag().values())
    print(f"t={time.monotonic() - t0:5.1f}s  rate={source.config.rate_msgs_per_s or 0:5.0f}/s  "
          f"lag={lag:4.0f}  devices={controller.devices}")
    if time.monotonic() - t0 > 30:
        break
    time.sleep(0.5)

burst.stop()
source.stop()
controller.shutdown()
stream.stop()
svc.cancel()

ups, downs = controller.events.of("scale_up"), controller.events.of("scale_down")
print(f"\nprocessed {stream.stats.records} records in {stream.stats.batches} batches")
for e in list(ups) + list(downs):
    print(f"  {e.action}: {e.devices_before} -> {e.devices_after} devices ({e.reason})")
assert ups and downs, "expected the burst to trigger a scale-up and a scale-down"
print("elastic pipeline OK")

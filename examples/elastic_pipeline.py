"""Closed-loop elastic streaming under the resource arbiter (paper §4.2).

Same Fig. 8 scenario as before — a MASS burst overloads a micro-batch
stage, the threshold policy grows it, then shrinks once the burst passes —
but the pool is now *shared*: a second, lower-priority pipeline
("scavenger") greedily soaks up spare devices. Both file demand with the
service's single ResourceArbiter (docs/scheduler.md); when the burst hits,
the high-priority pipeline's demand **preempts** the scavenger instead of
finding the pool already taken.

    PYTHONPATH=src python examples/elastic_pipeline.py
"""
import time

from repro.core import PilotComputeService
from repro.elastic import MetricsBus
from repro.miniapps import StreamSource
from repro.pipeline import Pipeline, register_processor, register_source


@register_source("points16")
class PointSource(StreamSource):
    def make_message(self, rng, i):
        return rng.normal(size=(16,))


@register_processor("slow_count")
class SlowCount:
    """Data-parallel stage: per-message cost shrinks as devices are added;
    on_rescale re-reads the device count (the paper's resharding hook)."""

    def __init__(self):
        self.devices, self.count = 2, 0

    def process(self, state, msgs):
        time.sleep(len(msgs) * 0.01 / self.devices)
        self.count += len(msgs)
        return self.count

    def on_rescale(self, devices):
        self.devices = max(len(devices), 1)
        return self.count


@register_processor("bg_count")
def bg_count(state, msgs):
    return (state or 0) + len(msgs)


primary = (Pipeline.named("elastic-demo").share(2.0)
           .topic("points", partitions=4)
           .source("points", kind="points16", rate_msgs_per_s=60,
                   rate_schedule=[(1.0, 60), (5.0, 300), (5.0, 40)])
           .stage("work", topic="points", processor="slow_count",
                  cores_per_node=2, priority=1,
                  batch_interval=0.05, max_batch_records=32,
                  backpressure=False)
           .elastic("work", policy="threshold", high_lag=80, low_lag=15,
                    up_stable=2, down_stable=3, interval=0.1, cooldown=1.2,
                    min_devices=2, max_devices=6, devices_per_step=2)
           .build())

# the scavenger always wants more devices (any lag > -1 reads as "high"),
# but at priority 0 / share 1 it only ever gets what the demo leaves over
scavenger = (Pipeline.named("scavenger").share(1.0)
             .topic("bg", partitions=2)
             .source("bg", kind="points16", rate_msgs_per_s=40)
             .stage("soak", topic="bg", processor="bg_count",
                    batch_interval=0.05, backpressure=False)
             .elastic("soak", policy="threshold", high_lag=-1.0, low_lag=-2.0,
                      up_stable=1, interval=0.2, cooldown=0.3,
                      min_devices=1, max_devices=8)
             .build())

bus = MetricsBus()
svc = PilotComputeService(devices=list(range(8)), metrics=bus)
with primary.run(service=svc, bus=bus) as run, \
        scavenger.run(service=svc, bus=bus) as bg:
    ctl, soak, t0 = run.controller("work"), bg.controller("soak"), time.monotonic()
    while not (run.scenario("points").finished and ctl.devices == 2):
        print(f"t={time.monotonic() - t0:5.1f}s  lag={run.lag('work'):4.0f}  "
              f"devices: demo={ctl.devices} scavenger={soak.devices}")
        if time.monotonic() - t0 > 40:
            break
        time.sleep(0.5)
    ups, downs = ctl.events.of("scale_up"), ctl.events.of("scale_down")
    stats = run.stream("work").stats
    print(f"\nprocessed {stats.records} records in {stats.batches} batches")
    for e in list(ups) + list(downs):
        print(f"  {e.action}: {e.devices_before} -> {e.devices_after} devices ({e.reason})")
    print(f"arbiter: {svc.arbiter.preemptions} preemption(s), "
          f"{len(svc.arbiter.events)} scheduling events")
    assert ups and downs, "expected the burst to trigger a scale-up and a scale-down"
    assert svc.arbiter.preemptions >= 1, \
        "the burst should preempt the scavenger, not queue behind it"
svc.cancel()
print("elastic pipeline OK")

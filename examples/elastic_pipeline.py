"""Closed-loop elastic streaming pipeline (paper §4.2, Fig. 8) — declarative.

Same scenario as before (MASS burst overloads a micro-batch stage, the
threshold policy grows the pilot, then shrinks once the burst passes), but
the ~80 lines of hand-wiring are now one spec: ``repro.pipeline`` provisions
broker + engine pilots, wires the MetricsBus and ElasticController, and
tears everything down on exit.

    PYTHONPATH=src python examples/elastic_pipeline.py
"""
import time

from repro.miniapps import StreamSource
from repro.pipeline import Pipeline, register_processor, register_source


@register_source("points16")
class PointSource(StreamSource):
    def make_message(self, rng, i):
        return rng.normal(size=(16,))


@register_processor("slow_count")
class SlowCount:
    """Data-parallel stage: per-message cost shrinks as devices are added;
    on_rescale re-reads the device count (the paper's resharding hook)."""

    def __init__(self):
        self.devices, self.count = 2, 0

    def process(self, state, msgs):
        time.sleep(len(msgs) * 0.01 / self.devices)
        self.count += len(msgs)
        return self.count

    def on_rescale(self, devices):
        self.devices = max(len(devices), 1)
        return self.count


pipe = (Pipeline.named("elastic-demo")
        .topic("points", partitions=4)
        .source("points", kind="points16", rate_msgs_per_s=60,
                rate_schedule=[(1.0, 60), (5.0, 300), (5.0, 40)])
        .stage("work", topic="points", processor="slow_count", cores_per_node=2,
               batch_interval=0.05, max_batch_records=32, backpressure=False)
        .elastic("work", policy="threshold", high_lag=80, low_lag=15,
                 up_stable=2, down_stable=3, interval=0.1, cooldown=1.2,
                 min_devices=2, max_devices=6, devices_per_step=2)
        .build())

with pipe.run(devices=8) as run:
    ctl, t0 = run.controller("work"), time.monotonic()
    while not (run.scenario("points").finished and ctl.devices == 2):
        print(f"t={time.monotonic() - t0:5.1f}s  lag={run.lag('work'):4.0f}  "
              f"devices={ctl.devices}")
        if time.monotonic() - t0 > 30:
            break
        time.sleep(0.5)
    ups, downs = ctl.events.of("scale_up"), ctl.events.of("scale_down")
    stats = run.stream("work").stats
    print(f"\nprocessed {stats.records} records in {stats.batches} batches")
    for e in list(ups) + list(downs):
        print(f"  {e.action}: {e.devices_before} -> {e.devices_after} devices ({e.reason})")
    assert ups and downs, "expected the burst to trigger a scale-up and a scale-down"
print("elastic pipeline OK")

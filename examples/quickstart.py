"""Quickstart — the paper's Listings 2-6 through the declarative API.

One spec declares broker ("kafka"), topic, source and a micro-batch
("spark") stage; ``run()`` provisions the pilots and wires the streams.
The imperative Pilot API is still there underneath — the tail of the
script uses it for a framework-agnostic Compute-Unit (Listing 5) and a
runtime cluster extension (Listing 4).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import PilotComputeDescription
from repro.pipeline import Pipeline


def running_sum(state, msgs):
    return (state or 0.0) + float(sum(m.value.sum() for m in msgs))


pipe = (Pipeline.named("quickstart")
        .broker(nodes=2)
        .topic("numbers", partitions=4)
        .source("numbers", kind="static", rate_msgs_per_s=400,
                total_messages=32, dim=8, points_per_msg=1)
        .stage("sum", topic="numbers", processor=running_sum,
               batch_interval=0.05)
        .build())

with pipe.run(devices=4) as run:
    cluster = run.cluster  # Listing 6: native client, same object as before
    print(f"broker up: {cluster.n_nodes} nodes")
    run.await_batches("sum", 1, timeout=10)
    stream = run.stream("sum")
    print(f"stream processed {stream.stats.records} messages, state={stream.state}")

    # -- Listing 5: framework-agnostic Compute-Unit on a dask pilot ----------
    dask_pilot = run.service.submit_pilot(
        {"number_of_nodes": 1, "cores_per_node": 2, "type": "dask"})
    print("CU result:", dask_pilot.submit(lambda x: x * x, 2).wait(10))

    # -- Listing 4: extend the broker at runtime -----------------------------
    ext = run.service.submit_pilot(PilotComputeDescription(
        number_of_nodes=2, framework="kafka", parent=run.broker_pilot))
    print(f"broker extended to {cluster.n_nodes} nodes")
    ext.cancel()
    print(f"broker shrunk back to {cluster.n_nodes} nodes")

print("quickstart OK")

"""Quickstart — the paper's Listings 2-6 in one script.

Provision a broker ("kafka") and a processing engine ("spark") through the
Pilot API, stream data through a topic, run an interoperable Compute-Unit,
and extend a running cluster.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PilotComputeService, PilotComputeDescription

svc = PilotComputeService()

# -- Listing 2/3: create a broker cluster ------------------------------------
pilot_compute_description = {
    "resource": "local://localhost",
    "working_directory": "/tmp/pilot-streaming",
    "number_of_nodes": 2,
    "type": "kafka",
}
kafka_pilot = svc.submit_pilot(pilot_compute_description)
cluster = kafka_pilot.get_context()  # Listing 6: native client
cluster.create_topic("numbers", n_partitions=4)
print(f"broker up: {cluster.n_nodes} nodes, startup {kafka_pilot.startup_time:.3f}s")

# -- produce / consume --------------------------------------------------------
from repro.broker import Producer

producer = Producer(cluster, "numbers", serializer="npy")
for i in range(32):
    producer.send(np.arange(8) + i)

# -- a micro-batch ("spark") engine processing the stream ----------------------
spark_pilot = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
ctx = spark_pilot.get_context()

def running_sum(state, msgs):
    return (state or 0.0) + float(sum(m.value.sum() for m in msgs))

stream = ctx.stream(cluster, "numbers", group="quickstart", process_fn=running_sum,
                    batch_interval=0.05).start()
stream.await_batches(1, timeout=10)
stream.stop()
print(f"stream processed {stream.stats.records} messages, state={stream.state}")

# -- Listing 5: framework-agnostic Compute-Unit --------------------------------
dask_pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "dask"})

def compute(x):
    return x * x

compute_unit = dask_pilot.submit(compute, 2)
print("CU result:", compute_unit.wait(10))

# -- Listing 4: extend the broker at runtime ------------------------------------
ext = svc.submit_pilot(PilotComputeDescription(number_of_nodes=2, framework="kafka",
                                               parent=kafka_pilot))
print(f"broker extended to {cluster.n_nodes} nodes")
ext.cancel()
print(f"broker shrunk back to {cluster.n_nodes} nodes")

svc.cancel()
print("quickstart OK")

"""End-to-end streaming LM training (deliverable (b)'s training driver).

Streams synthetic token batches through the broker into micro-batch train
steps with periodic checkpoints. Defaults to a reduced config so it runs on
CPU in seconds; ``--full`` selects the real smollm-135m (~135M params —
the "~100M model" scale; expect minutes/step on CPU, realtime on a pod).

    PYTHONPATH=src python examples/train_lm_stream.py --steps 30
    PYTHONPATH=src python examples/train_lm_stream.py --full --steps 300
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] if len(sys.argv) > 1 else [])
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    argv = [
        "train", "--arch", "smollm-135m", "--steps", str(args.steps),
        "--seq-len", "128" if not args.full else "512",
        "--batch", "8",
    ]
    if not args.full:
        argv.append("--reduced")
    sys.argv = argv
    train_mod.main()


if __name__ == "__main__":
    main()

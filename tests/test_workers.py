"""repro.workers — the multiprocess partition execution runtime.

Fast tests cover the protocol pieces in isolation (channel correlation,
worker command round trips, heartbeat lifecycle) and a small end-to-end
``executor="mp"`` run against the inline executor. The ``slow``-marked
tests exercise the failure machinery for real: SIGKILL mid-stream with
exact recovery, hang detection via stale heartbeats, restart exhaustion,
and cross-process rescale. Bit-identical chaos comparisons live in
tests/test_chaos_rescale.py.
"""
import multiprocessing as mp
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.broker import Producer
from repro.broker.consumer import Message
from repro.core import PilotComputeService
from repro.core.failure import HeartbeatMonitor
from repro.elastic import MetricsBus
from repro.streaming import TumblingWindow
from repro.workers import (
    CONFIGURE,
    PROCESS_BATCH,
    SNAPSHOT,
    STATS,
    BatchResult,
    Reply,
    WorkerChannel,
    WorkerCrash,
    WorkerSupervisor,
    WorkerUnresponsive,
)
from repro.workers.proto import OP_APPEND, OP_OBSERVE

pytestmark = pytest.mark.skipif(
    "fork" not in mp.get_all_start_methods(),
    reason='executor="mp" requires the fork start method',
)

_CTX = mp.get_context("fork")


# -- channel ------------------------------------------------------------------


def test_channel_drops_stale_replies_and_correlates_by_seq():
    ch = WorkerChannel(_CTX)
    s1 = ch.send("A")
    s2 = ch.send("B")
    # replies arrive out of an abandoned earlier exchange first
    ch.replies.put(Reply(s1, True, "old"))
    ch.replies.put(Reply(s2, True, "new"))
    got = ch.recv(s2, timeout=5)
    assert got.payload == "new"  # stale s1 silently dropped
    ch.close()


def test_channel_drain_discards_inflight_leftovers():
    ch = WorkerChannel(_CTX)
    for i in range(3):
        ch.replies.put(Reply(i, True, BatchResult([], 0, 0.0)))
    time.sleep(0.2)  # let the feeder thread flush
    assert ch.drain() == 3
    seq = ch.send("Q")
    ch.replies.put(Reply(seq, True, "idle"))
    assert ch.recv(seq, timeout=5).payload == "idle"
    ch.close()


def test_channel_recv_raises_on_dead_and_hung_worker():
    ch = WorkerChannel(_CTX)
    seq = ch.send("X")
    with pytest.raises(WorkerCrash):
        ch.recv(seq, timeout=5, alive_fn=lambda: False)
    with pytest.raises(WorkerUnresponsive):
        ch.recv(seq, timeout=5, alive_fn=lambda: True,
                responsive_fn=lambda: False)
    with pytest.raises(WorkerUnresponsive):
        ch.recv(seq, timeout=0.2)  # hard deadline
    ch.close()


# -- heartbeat monitor lifecycle (satellite: idempotent close) ----------------


def test_monitor_close_joins_all_threads_and_is_idempotent():
    m = HeartbeatMonitor(interval=0.05, timeout=2.0)
    targets = [object() for _ in range(3)]
    for t in targets:
        m.watch(t)
    threads = list(m._agent_threads.values()) + [m._monitor]
    assert all(t.is_alive() for t in threads)
    m.close()
    assert all(not t.is_alive() for t in threads)  # joined, not leaked
    m.close()  # idempotent
    m.stop()  # legacy alias


def test_monitor_pull_based_staleness_detects_stopped_source():
    m = HeartbeatMonitor(interval=0.05, timeout=0.3)
    failed = []
    m.on_failure(failed.append)
    beat = {"t": time.monotonic()}
    target = object()
    m.watch(target, beat_fn=lambda: beat["t"])
    time.sleep(0.5)  # source keeps a stale value: no fresh stamps
    assert not m.is_alive(target)
    assert failed == [target]
    m.close()


def test_monitor_pull_based_live_source_stays_alive():
    m = HeartbeatMonitor(interval=0.05, timeout=0.3)
    target = object()
    m.watch(target, beat_fn=time.monotonic)
    time.sleep(0.5)
    assert m.is_alive(target)
    m.close()


def test_service_cancel_closes_monitor():
    svc = PilotComputeService(devices=[0, 1])
    monitor = svc.monitor
    svc.cancel()
    assert monitor._closed
    assert not monitor._monitor.is_alive()


# -- worker protocol round trip ----------------------------------------------


def _spawned(window_fn, monitor=None):
    monitor = monitor or HeartbeatMonitor(interval=0.05, timeout=1.0)
    sup = WorkerSupervisor(0, "dev0", window_fn, monitor=monitor, ctx=_CTX,
                           batch_timeout=10.0)
    return sup.spawn(), monitor


def test_worker_process_batch_snapshot_restore_stats():
    sup, monitor = _spawned(lambda k, w, msgs: (k, w, sum(float(m.value) for m in msgs)))
    try:
        assert sup.request(CONFIGURE, {"pids": [0, 1]}) == [0, 1]
        ops = [
            (OP_OBSERVE, 0, 0.5),
            (OP_APPEND, 0, "a", (0.0, 1.0), Message(0, 0, 0.5, 2.0)),
            (OP_OBSERVE, 1, 0.7),
            (OP_APPEND, 1, "b", (0.0, 1.0), Message(0, 1, 0.7, 3.0)),
        ]
        r = sup.request(PROCESS_BATCH, {"ops": ops, "watermark": 0.5})
        assert r.fired == [] and r.buffered_windows == 2  # windows still open
        r = sup.request(PROCESS_BATCH, {"ops": [], "watermark": 1.0})
        # canonical order: same window -> pid breaks the tie
        assert [(pid, key, out[2]) for pid, key, _w, out in r.fired] == [
            (0, "a", 2.0), (1, "b", 3.0)]
        stats = sup.request(STATS)
        assert stats["records"] == 2 and stats["buffered_windows"] == 0
        snap = sup.request(SNAPSHOT, {"pids": [0, 1], "release": False})
        assert set(snap) == {0, 1}  # serialized partitions came back
    finally:
        sup.stop()
        monitor.close()


def test_worker_error_propagates_without_restart():
    def bad(k, w, msgs):
        raise ValueError("deterministic user bug")

    sup, monitor = _spawned(bad)
    try:
        sup.request(CONFIGURE, {"pids": [0]})
        ops = [(OP_APPEND, 0, "k", (0.0, 1.0), Message(0, 0, 0.5, 1.0))]
        from repro.workers import WorkerError
        with pytest.raises(WorkerError, match="deterministic user bug"):
            sup.request(PROCESS_BATCH, {"ops": ops, "watermark": 2.0})
        assert sup.alive()  # the worker survives its reply
        assert sup.restarts == 0
    finally:
        sup.stop()
        monitor.close()


def test_supervisor_respawn_replaces_incarnation():
    sup, monitor = _spawned(lambda k, w, msgs: len(msgs))
    try:
        sup.request(CONFIGURE, {"pids": [0]})
        pid1 = sup.process.pid
        os.kill(pid1, signal.SIGKILL)
        sup.process.join(timeout=5)
        assert not sup.alive()
        sup.respawn()
        assert sup.alive() and sup.process.pid != pid1
        assert sup.restarts == 1
        assert sup.request(CONFIGURE, {"pids": [0]}) == [0]  # fresh + serving
    finally:
        sup.stop()
        monitor.close()


# -- engine integration (small, fast) -----------------------------------------


@pytest.fixture
def svc():
    s = PilotComputeService(devices=list(range(16)))
    yield s
    s.cancel()


def _window_fn(k, w, msgs):
    return (k, w, sum(float(m.value[0]) for m in msgs), len(msgs))


def _stream(svc, topic, *, executor, bus=None, cores=2, worker_options=None, **kw):
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic(topic, 1)
    flink = svc.submit_pilot(
        {"number_of_nodes": 1, "cores_per_node": cores, "type": "flink"})
    outs = []
    stream = flink.get_context().stream(
        cluster, topic, group="g",
        assigner=TumblingWindow(1.0),
        window_fn=kw.pop("window_fn", _window_fn),
        key_fn=lambda m: int(m.value[1]) % 5,
        emit=outs.append, metrics=bus, executor=executor,
        worker_options=worker_options, **kw,
    )
    return cluster, stream, outs


def _send(cluster, topic, lo, hi):
    prod = Producer(cluster, topic, serializer="npy")
    for i in range(lo, hi):
        prod.send(np.array([float(i), i]), timestamp=100.0 + i * 0.2)


def test_mp_executor_matches_inline_and_publishes_worker_gauges(svc):
    bus = MetricsBus()
    cluster, s_mp, outs_mp = _stream(
        svc, "mp1", executor="mp", bus=bus,
        worker_options={"snapshot_every": 4})
    s_mp.start()
    assert s_mp.runtime is not None and s_mp.runtime.n_workers == 2
    _send(cluster, "mp1", 0, 40)
    s_mp.await_windows(21, timeout=30)
    assert bus.value("workers.alive", stream="mp1") == 2
    assert bus.value("workers.restarts", stream="mp1") == 0
    # per-worker + aggregate latency quantiles: the loop thread publishes
    # them after the firing that woke await_windows, so poll briefly
    deadline = time.monotonic() + 5
    while (bus.value("stream.latency_p50", stream="mp1") <= 0
           and time.monotonic() < deadline):
        time.sleep(0.02)
    assert bus.value("stream.latency_p50", stream="mp1") > 0
    assert bus.value("stream.latency_p99", stream="mp1", worker="0") > 0
    s_mp.stop()
    assert bus.value("workers.alive", stream="mp1") == 0

    cluster2, s_in, outs_in = _stream(svc, "in1", executor="inline")
    s_in.start()
    _send(cluster2, "in1", 0, 40)
    s_in.await_windows(21, timeout=30)
    s_in.stop()
    assert outs_mp == outs_in  # bit-identical, including np.sum float order


def test_unknown_executor_rejected(svc):
    with pytest.raises(ValueError, match="unknown executor"):
        _stream(svc, "bad", executor="threads")


def test_mp_rescale_drains_stale_replies_before_quiesce(svc):
    """Satellite regression: a leftover BatchResult sitting in a worker's
    reply queue (an abandoned in-flight batch) must not alias the QUIESCE
    reply — rescale drains data queues first, and the seq correlation
    would reject it anyway."""
    cluster, stream, outs = _stream(
        svc, "mpq", executor="mp", worker_options={"snapshot_every": 64})
    stream.start()
    _send(cluster, "mpq", 0, 20)
    stream.await_windows(11, timeout=30)
    for sup in stream.runtime._sups:  # forge an in-flight leftover
        sup.channel.replies.put(
            Reply(sup.channel._seq, True, BatchResult([], 99, 1.0)))
    time.sleep(0.2)  # let the queue feeder deliver the forgeries
    report = stream.rescale([0, 1, 2, 3])
    assert report is not None and report.moved
    assert stream.runtime.n_workers == 4
    _send(cluster, "mpq", 20, 40)
    stream.await_windows(21, timeout=30)
    stream.stop()
    # same totals as an uninterrupted run: the forged reply changed nothing
    assert stream.stats.records == 40
    assert [o for o in outs] == sorted(outs, key=lambda o: (o[1][1], o[1][0]))


# -- failure machinery (slow) -------------------------------------------------


@pytest.mark.slow
def test_sigkill_mid_stream_recovers_exactly(svc):
    bus = MetricsBus()
    cluster, stream, outs = _stream(
        svc, "kill", executor="mp", cores=4, bus=bus,
        worker_options={"snapshot_every": 8})
    stream.start()
    _send(cluster, "kill", 0, 30)
    stream.await_windows(10, timeout=30)
    victim = stream.runtime._sups[1]
    os.kill(victim.process.pid, signal.SIGKILL)
    _send(cluster, "kill", 30, 60)
    stream.await_windows(33, timeout=60)
    stream.stop()
    assert stream.runtime.restarts >= 1
    assert bus.value("workers.restarts", stream="kill") >= 1

    cluster2, ref, outs_ref = _stream(svc, "ref", executor="inline")
    ref.start()
    _send(cluster2, "ref", 0, 60)
    ref.await_windows(33, timeout=60)
    ref.stop()
    assert outs == outs_ref  # zero lost, zero duplicated, same order


@pytest.mark.slow
def test_hung_worker_detected_and_restarted(svc, tmp_path):
    """A window_fn wedged in user code stops stamping heartbeats; the
    supervisor flags it stale, kills the process and replays. The wedge is
    one-shot (flag file), so the replayed call completes."""
    flag = str(tmp_path / "wedged-once")

    def wedge_once(k, w, msgs):
        if not os.path.exists(flag):
            open(flag, "w").close()
            time.sleep(300)  # never stamps another beat: reads as a hang
        return (k, w, len(msgs))

    cluster, stream, outs = _stream(
        svc, "hang", executor="mp", cores=1, window_fn=wedge_once,
        worker_options={"snapshot_every": 8, "heartbeat_timeout": 0.6,
                        "heartbeat_interval": 0.05})
    stream.start()
    _send(cluster, "hang", 0, 30)
    stream.await_windows(14, timeout=60)
    stream.stop()
    assert stream.runtime.restarts == 1
    # exactly one firing per closed (key, window): the wedged call's window
    # fired once via replay, never twice
    assert len(outs) == len({o[:2] for o in outs})
    assert len(outs) >= 14


@pytest.mark.slow
def test_restart_exhaustion_surfaces_as_stream_error(svc):
    def suicide(k, w, msgs):
        os.kill(os.getpid(), signal.SIGKILL)

    cluster, stream, _ = _stream(
        svc, "die", executor="mp", cores=1, window_fn=suicide,
        worker_options={"max_restarts": 2, "snapshot_every": 8})
    stream.start()
    _send(cluster, "die", 0, 10)
    with pytest.raises(WorkerCrash, match="failed to recover"):
        stream.await_windows(1, timeout=60)
    with pytest.raises(WorkerCrash):
        stream.stop()


@pytest.mark.slow
def test_mp_rescale_moves_partitions_between_processes(svc):
    cluster, stream, outs = _stream(
        svc, "mig", executor="mp", worker_options={"snapshot_every": 64})
    stream.start()
    _send(cluster, "mig", 0, 30)
    stream.await_windows(10, timeout=30)
    pids_before = {s.process.pid for s in stream.runtime._sups}
    report = stream.rescale([10, 11, 12, 13])  # all-new owner set
    assert report.moved and len(report.moved) == stream.store.n_partitions
    pids_after = {s.process.pid for s in stream.runtime._sups}
    assert len(pids_after) == 4 and pids_before.isdisjoint(pids_after)
    _send(cluster, "mig", 30, 60)
    stream.await_windows(33, timeout=30)
    stream.stop()

    cluster2, ref, outs_ref = _stream(svc, "migref", executor="inline")
    ref.start()
    _send(cluster2, "migref", 0, 60)
    ref.await_windows(33, timeout=30)
    ref.stop()
    assert outs == outs_ref  # buffered state crossed processes losslessly

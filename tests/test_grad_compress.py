"""Cross-pod gradient compression: math, HLO wire format, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.grad_compress import (
    compression_wire_bytes,
    dequantize_int8,
    quantize_int8,
)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (2048,)) * 10
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    blockmax = jnp.max(jnp.abs(x.reshape(-1, 256)), axis=1)
    rel = jnp.abs(deq - x).reshape(-1, 256).max(axis=1) / jnp.maximum(blockmax, 1e-30)
    assert q.dtype == jnp.int8
    assert float(rel.max()) <= 1 / 250


def test_error_feedback_unbiased_over_time():
    true_sum = jnp.zeros(512)
    qsum = jnp.zeros(512)
    resid = jnp.zeros(512)
    for i in range(100):
        g = jax.random.normal(jax.random.key(i), (512,)) * 0.01
        true_sum = true_sum + g
        corrected = g + resid
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape)
        resid = corrected - deq
        qsum = qsum + deq
    # drift stays bounded by a single-step quantization error (not O(steps))
    assert float(jnp.abs(qsum - true_sum).max()) < 5e-4


def test_wire_format_compression_ratio():
    comp, full = compression_wire_bytes(1_000_000)
    assert 3.5 < full / comp < 4.0


def test_compressed_pod_reduction_lowers_with_s8_collectives(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime.grad_compress import quantized_psum, resid_len
from repro.utils.jax_compat import shard_map

mesh = jax.make_mesh((2,), ("pod",))

def step(g, r):
    # per-pod partials enter with a leading pod dim; exchange inside shard_map
    def local(g, r):
        red, nr = quantized_psum(g[0], r[0], "pod")
        return red[None], nr[None]
    return shard_map(local, mesh=mesh, in_specs=(P("pod"), P("pod")),
                         out_specs=(P(None), P("pod")), check_vma=False)(g, r)

g = jnp.stack([jnp.ones((4, 256)) * 0.5, jnp.ones((4, 256)) * 0.25])
r = jnp.zeros((2, resid_len(1024)))
with mesh:
    compiled = jax.jit(step).lower(
        jax.ShapeDtypeStruct(g.shape, g.dtype), jax.ShapeDtypeStruct(r.shape, r.dtype)
    ).compile()
txt = compiled.as_text()
assert "s8[" in txt and "all-gather" in txt, "int8 payload missing from wire"
with mesh:
    red, new_r = jax.jit(step)(g, r)
np.testing.assert_allclose(np.asarray(red[0]), 0.75, atol=0.02)  # 0.5 + 0.25
print("S8 WIRE OK")
""",
        n_devices=8,
    )


def test_compressed_dp_training_converges(subproc):
    """Pure data-parallel across 2 'pods': compressed grad exchange reaches
    the same loss as exact f32 within tolerance."""
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = jax.make_mesh((2,), ("pod",))
key = jax.random.key(0)
Xw = jax.random.normal(key, (64, 16))
y = Xw @ jax.random.normal(jax.random.key(1), (16,))

def loss_fn(w, X, y):
    return jnp.mean((X @ w - y) ** 2)

from repro.runtime.grad_compress import quantized_psum, resid_len
from repro.utils.jax_compat import shard_map

def make_step(compressed):
    def step(w, resid, X, y):
        def per_pod(X, y, r):
            g = jax.grad(loss_fn)(w, X, y) / 2  # local half-batch grad
            if compressed:
                red, nr = quantized_psum(g, r[0], "pod")
                return red, nr[None]
            return jax.lax.psum(g, "pod"), r
        g, resid = shard_map(per_pod, mesh=mesh,
                                 in_specs=(P("pod"), P("pod"), P("pod")),
                                 out_specs=(P(None), P("pod")), check_vma=False)(X, y, resid)
        return w - 0.05 * g, resid
    return jax.jit(step)

for compressed in (False, True):
    w = jnp.zeros((16,))
    resid = jnp.zeros((2, resid_len(16)))
    step = make_step(compressed)
    with mesh:
        for i in range(300):
            w, resid = step(w, resid, Xw, y)
    final = float(loss_fn(w, Xw, y))
    print("compressed" if compressed else "exact", final)
    assert final < 1e-3, final
print("CONVERGES OK")
""",
        n_devices=8,
    )

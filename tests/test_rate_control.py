"""PIDRateController (streaming backpressure): convergence under a capacity
step, the min_rate floor, and first-update initialization."""
import pytest

from repro.streaming import PIDRateController


def _drive(pid, capacity, iters, overhead=0.05):
    """Closed loop: each batch ingests what the controller allows and takes
    ``overhead + n / capacity`` seconds; time past the batch window shows up
    as scheduling delay, exactly as in the micro-batch engine loop."""
    rate = None
    for _ in range(iters):
        n = pid.max_records_per_batch
        dt = overhead + n / capacity
        rate = pid.update(n, dt, scheduling_delay=max(0.0, dt - pid.batch_interval))
    return rate


def test_first_update_initializes_to_processing_rate():
    pid = PIDRateController(batch_interval=0.1)
    rate = pid.update(n_records=500, processing_delay=0.5)
    assert rate == pytest.approx(1000.0)  # exactly the observed rate
    assert pid.max_records_per_batch == 100  # rate * interval


def test_empty_or_instant_batches_do_not_move_the_rate():
    pid = PIDRateController(batch_interval=0.1)
    assert pid.update(0, 1.0) == pid.min_rate  # nothing observed yet -> floor
    pid.update(500, 0.5)
    rate = pid.update(0, 0.5)  # empty batch: keep last estimate
    assert rate == pytest.approx(1000.0)
    assert pid.update(100, 0.0) == pytest.approx(1000.0)  # degenerate delay


def test_converges_to_capacity_after_step_down():
    # sustainable rate with 0.05s fixed overhead in a 0.5s window is
    # 0.9 * capacity: the controller should find it, not the raw capacity
    pid = PIDRateController(batch_interval=0.5)
    assert _drive(pid, capacity=1000.0, iters=15) == pytest.approx(900.0, rel=0.15)
    # capacity step: the processor suddenly runs at 300 rec/s (e.g. lost
    # devices) -- the controller must come down to it instead of queueing
    rate = _drive(pid, capacity=300.0, iters=40)
    assert rate == pytest.approx(270.0, rel=0.15)
    # and back up after recovery
    rate = _drive(pid, capacity=1000.0, iters=40)
    assert rate == pytest.approx(900.0, rel=0.15)


def test_scheduling_delay_acts_as_accumulated_error():
    # same observation, but one controller saw records queued behind the batch
    a = PIDRateController(batch_interval=0.5)
    b = PIDRateController(batch_interval=0.5)
    for pid in (a, b):
        pid.update(500, 0.5)
    ra = a.update(500, 0.5, scheduling_delay=0.0)
    rb = b.update(500, 0.5, scheduling_delay=1.0)
    assert rb < ra


def test_min_rate_floor_under_collapse():
    pid = PIDRateController(batch_interval=0.1, min_rate=10.0)
    pid.update(1000, 0.1)
    for _ in range(20):
        # pathological processor: 1000x slower than the target interval
        rate = pid.update(pid.max_records_per_batch, 100.0, scheduling_delay=50.0)
    assert rate == pid.min_rate
    assert pid.max_records_per_batch >= 1  # never wedges the stream at zero

"""Streaming hot-path overhaul: shape-bucketed dispatch, masked bucket
padding, async double-buffering, kernel-vs-ref parity, and the engine's
latency publishing (ISSUE 2 / docs/perf.md)."""
import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kmeans import minibatch_update, minibatch_update_masked, update_ref, update_scatter
from repro.kernels.tomo import gridrec, gridrec_batch, mlem, mlem_batch, project_ref, shepp_logan
from repro.miniapps import ReconstructionApp, StreamingKMeans
from repro.streaming.dispatch import (
    AsyncWindow,
    LatencyWindow,
    ShapeBuckets,
    compile_count,
    pad_rows,
)


@dataclass
class Msg:
    value: Any
    timestamp: float = 0.0


# ---------------------------------------------------------------------------
# dispatch primitives
# ---------------------------------------------------------------------------


def test_shape_buckets_fit():
    b = ShapeBuckets(min_size=512, max_size=4096)
    assert b.sizes == (512, 1024, 2048, 4096)
    assert b.fit(1) == 512
    assert b.fit(512) == 512
    assert b.fit(513) == 1024
    assert b.fit(4096) == 4096
    assert b.fit(5000) == 8192  # beyond max: next multiple of max
    assert b.fit(9000) == 12288
    assert len(b) == 4


def test_pad_rows():
    x = np.arange(6, dtype=np.float32).reshape(3, 2)
    p = pad_rows(x, 5)
    assert p.shape == (5, 2)
    assert (p[:3] == x).all() and (p[3:] == 0).all()
    assert pad_rows(x, 3) is x  # no-op when already at size


def test_async_window_bounds_in_flight_and_syncs():
    w = AsyncWindow(depth=2, latency=LatencyWindow())
    done = []
    for i in range(5):
        done += w.push(jnp.full((4,), i), meta=i)
        assert w.in_flight <= 2
    assert [m for _, m, _ in done] == [0, 1, 2]
    done += w.sync()
    assert [m for _, m, _ in done] == [0, 1, 2, 3, 4]
    assert w.in_flight == 0
    assert len(w.latency) == 5 and w.latency.p99 >= w.latency.p50 >= 0.0


def test_async_window_depth_zero_is_synchronous():
    w = AsyncWindow(depth=0)
    done = w.push(jnp.ones((2,)), meta="a")
    assert len(done) == 1 and w.in_flight == 0


def test_latency_window_quantiles():
    lw = LatencyWindow()
    for v in [0.1, 0.2, 0.3, 0.4, 10.0]:
        lw.record(v)
    assert lw.p50 == pytest.approx(0.3)
    assert lw.p99 > 1.0


# ---------------------------------------------------------------------------
# bucket padding correctness (masked update == unpadded, bit-identical)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,bucket", [(97, 128), (513, 1024), (1769, 2048), (5000, 8192)])
def test_masked_update_bit_identical_to_unpadded(n, bucket):
    rng = np.random.default_rng(n)
    pts = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    cen = jnp.asarray(rng.normal(size=(10, 3)), jnp.float32)
    c_ref, l_ref, i_ref = minibatch_update(pts, cen, decay=0.8)
    padded = jnp.zeros((bucket, 3), jnp.float32).at[:n].set(pts)
    c_pad, l_pad, i_pad = minibatch_update_masked(padded, cen, n, decay=0.8)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pad))
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pad[:n]))
    assert (np.asarray(l_pad[n:]) == -1).all()  # padding rows are flagged
    np.testing.assert_allclose(float(i_ref), float(i_pad), rtol=1e-6)


def test_update_scatter_matches_matmul_oracle():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.normal(size=(300, 4)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, 300), jnp.int32)
    s1, c1 = update_scatter(pts, labels, 7)
    s2, c2 = update_ref(pts, labels, 7)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_bucketed_kmeans_app_bit_identical_to_legacy():
    """The whole app path: padded bucketed dispatch + async double-buffering
    must reproduce the legacy block-every-batch centroids exactly."""
    rng = np.random.default_rng(1)
    batches = [[Msg(rng.normal(size=(int(rng.integers(100, 1500)), 3)))] for _ in range(10)]
    new = StreamingKMeans(n_clusters=6, dim=3, seed=2)
    old = StreamingKMeans(n_clusters=6, dim=3, seed=2, bucketed=False, async_depth=0)
    s_new = s_old = None
    for b in batches:
        s_new = new.process(s_new, b)
        s_old = old.process(s_old, b)
    new.sync()
    np.testing.assert_array_equal(np.asarray(s_new), np.asarray(s_old))
    assert new.inertia == pytest.approx(old.inertia)
    assert new.stats.messages == old.stats.messages == 10


def test_kmeans_recompile_count_bounded_by_buckets():
    """N variable-sized batches must compile at most len(buckets) times."""
    buckets = ShapeBuckets(min_size=256, max_size=2048)
    app = StreamingKMeans(n_clusters=5, dim=3, buckets=buckets)
    rng = np.random.default_rng(3)
    state = None
    sizes = rng.integers(50, 2000, size=20)
    for n in sizes:
        state = app.process(state, [Msg(rng.normal(size=(int(n), 3)))])
    app.sync()
    assert app.compiles <= len(buckets)
    assert app.compiles == len({buckets.fit(int(n)) for n in sizes})
    # legacy comparison: one compile per distinct size
    assert len(set(int(n) for n in sizes)) > len(buckets)


# ---------------------------------------------------------------------------
# use_kernel plumbing: kernel and ref paths agree (interpret mode on CPU)
# ---------------------------------------------------------------------------


def test_kmeans_streaming_kernel_matches_ref_path():
    rng = np.random.default_rng(5)
    batches = [[Msg(rng.normal(size=(int(rng.integers(100, 900)), 3)))] for _ in range(4)]
    kern = StreamingKMeans(n_clusters=6, dim=3, seed=4, use_kernel=True, interpret=True)
    ref = StreamingKMeans(n_clusters=6, dim=3, seed=4, use_kernel=False)
    s_k = s_r = None
    for b in batches:
        s_k = kern.process(s_k, b)
        s_r = ref.process(s_r, b)
    kern.sync(); ref.sync()
    assert kern.use_kernel and not ref.use_kernel
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("algorithm", ["gridrec", "mlem"])
def test_tomo_streaming_kernel_matches_ref_path(algorithm):
    n, a, nd = 32, 16, 48
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = np.asarray(project_ref(img, angles, nd))
    msgs = [Msg(sino), Msg(sino * 0.5)]
    kern = ReconstructionApp(algorithm, n=n, mlem_iters=2, use_kernel=True, interpret=True)
    ref = ReconstructionApp(algorithm, n=n, mlem_iters=2, use_kernel=False)
    out_k = kern.process(None, msgs)
    out_r = ref.process(None, msgs)
    kern.sync(); ref.sync()
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-3)


@pytest.mark.parametrize("fn_batch,fn_one,kw", [
    (gridrec_batch, gridrec, {}),
    (mlem_batch, mlem, {"iters": 2}),
])
def test_batched_reconstruction_matches_sequential(fn_batch, fn_one, kw):
    n, a, nd = 24, 8, 32
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = project_ref(img, angles, nd)
    stack = jnp.stack([sino, sino * 2.0, sino * 0.1])
    outs = fn_batch(stack, angles, n, **kw)
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(outs[i]), np.asarray(fn_one(stack[i], angles, n, **kw)),
            rtol=1e-5, atol=1e-5)


def test_reconstruction_batched_app_matches_loop_and_caches_angles():
    n, a, nd = 32, 16, 48
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = np.asarray(project_ref(img, angles, nd))
    msgs = [Msg(sino * (1 + 0.1 * i)) for i in range(3)]
    batched = ReconstructionApp("gridrec", n=n)
    loop = ReconstructionApp("gridrec", n=n, batched=False, async_depth=0)
    out_b = batched.process(None, msgs)
    out_l = loop.process(None, msgs)
    batched.sync()
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_l), rtol=1e-5, atol=1e-6)
    # angles hoisted into the per-shape cache: same jnp array object reused
    assert list(batched._angles_cache) == [a]
    first = batched._angles(a)
    batched.process(None, msgs)
    batched.sync()
    assert batched._angles(a) is first


def test_reconstruction_mixed_shapes_grouped():
    """A micro-batch with two sinogram shapes reconstructs both groups, and
    the returned state is the LAST message's reconstruction (the documented
    contract) even though its shape group was seen first."""
    n = 24
    img = shepp_logan(n)
    frames = []
    for a, nd in [(8, 32), (16, 32), (8, 32)]:
        angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
        frames.append(Msg(np.asarray(project_ref(img, angles, nd))))
    app = ReconstructionApp("gridrec", n=n)
    out = app.process(None, frames)
    app.sync()
    assert out.shape == (n, n)
    assert sorted(app._angles_cache) == [8, 16]
    legacy = ReconstructionApp("gridrec", n=n, batched=False, async_depth=0)
    expected = legacy.process(None, frames)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# engine integration: sync contract + latency publishing
# ---------------------------------------------------------------------------


def _pipeline(svc, app, n_msgs=8, **stream_kw):
    from repro.broker import Producer

    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("t", 2)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    prod = Producer(cluster, "t", serializer="npy")
    rng = np.random.default_rng(0)
    for _ in range(n_msgs):
        prod.send(rng.normal(size=(int(rng.integers(50, 400)), 3)))
    s = ctx.stream(cluster, "t", group="g", process_fn=app.process,
                   batch_interval=0.02, max_batch_records=2, backpressure=False,
                   **stream_kw)
    return s


@pytest.fixture
def svc():
    from repro.core import PilotComputeService

    s = PilotComputeService()
    yield s
    s.cancel()


def test_stream_auto_wires_sync_fn_from_bound_processor(svc):
    app = StreamingKMeans(n_clusters=4, dim=3)
    s = _pipeline(svc, app)
    assert s.sync_fn == app.sync
    s.start()
    s.await_batches(2, timeout=30)
    s.stop()  # stop() syncs: all dispatched batches must have landed
    assert app.in_flight == 0
    assert s.state.shape == (4, 3)


def test_engine_publishes_latency_quantiles_to_bus(svc):
    from repro.elastic.metrics import MetricsBus, MetricsSnapshot

    bus = MetricsBus()
    app = StreamingKMeans(n_clusters=4, dim=3)
    s = _pipeline(svc, app, metrics=bus)
    s.start()
    s.await_batches(2, timeout=30)
    s.stop()
    p50 = bus.value("stream.latency_p50", default=-1.0, stream="t")
    p99 = bus.value("stream.latency_p99", default=-1.0, stream="t")
    assert p50 >= 0.0 and p99 >= p50
    snap = MetricsSnapshot.capture(bus)
    assert snap.latency_p50 == p50 and snap.latency_p99 == p99


def test_checkpoint_boundary_syncs_in_flight_work(svc):
    order = []
    app = StreamingKMeans(n_clusters=4, dim=3)
    real_sync = app.sync

    def tracked_sync():
        order.append("sync")
        real_sync()

    def ckpt(state, offsets):
        order.append("ckpt")
        assert app.in_flight == 0  # the contract: drained before snapshot

    s = _pipeline(svc, app, checkpoint_fn=ckpt, sync_fn=tracked_sync)
    s.start()
    s.await_batches(2, timeout=30)
    s.stop()
    assert "sync" in order and "ckpt" in order
    assert order.index("sync") < order.index("ckpt")


def test_rescale_drains_window_before_reshard(svc):
    app = StreamingKMeans(n_clusters=4, dim=3)
    s = _pipeline(svc, app)
    s.on_rescale = lambda devices: app.on_rescale(devices)(s.state)
    s.start()
    s.await_batches(2, timeout=30)
    s.rescale(jax.devices())
    assert app.in_flight == 0
    s.stop()
    assert s.state.shape == (4, 3)


def test_app_publishes_latency_to_bus():
    from repro.elastic.metrics import MetricsBus

    bus = MetricsBus()
    app = StreamingKMeans(n_clusters=4, dim=3, metrics=bus)
    rng = np.random.default_rng(0)
    state = None
    for _ in range(4):
        state = app.process(state, [Msg(rng.normal(size=(200, 3)))])
    app.sync()
    assert bus.value("app.latency_p50", default=-1.0, app="kmeans") >= 0.0
    assert bus.value("app.latency_p99", default=-1.0, app="kmeans") >= 0.0


def test_compile_count_helper():
    f = jax.jit(lambda x: x * 2)
    assert compile_count(f) == 0
    f(jnp.ones((2,)))
    f(jnp.ones((3,)))
    assert compile_count(f) == 2
    assert compile_count(lambda x: x) == -1  # not a jitted fn

import subprocess
import sys
import textwrap

import pytest


def run_with_devices(code: str, n_devices: int = 8, timeout: float = 600) -> str:
    """Run a snippet in a subprocess with N forced host devices.

    Device count must be fixed before jax initializes, so multi-device tests
    cannot run in the main pytest process (which sees 1 CPU device).
    """
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        + textwrap.dedent(code)
    )
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert res.returncode == 0, f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


@pytest.fixture
def subproc():
    return run_with_devices

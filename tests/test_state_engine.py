"""Always-run tests for repro.state + the continuous engine's use of it.

Mirror of the hypothesis suite in tests/test_state.py (which skips where
hypothesis isn't installed) plus what properties can't express: the engine
integration (rescale mid-stream fires the same windows), the automatic
migration on extension-pilot grow/shrink, the migration gauges, and the
regression test for the quiesce race — ``rescale()`` used to run while a
``window_fn`` call was in flight.
"""
import os
import random
import threading
import time

import numpy as np
import pytest

from repro.broker import Producer
from repro.broker.consumer import Message
from repro.core import PilotComputeService
from repro.elastic import MetricsBus, MetricsSnapshot
from repro.state import (
    LOCAL_OWNER,
    PartitionedStateStore,
    StateMigrator,
    deserialize_partition,
    moved_partitions,
    partition_for,
    range_assignment,
    serialize_partition,
)
from repro.streaming import SessionWindow, TumblingWindow


# -- partitioner / assignment (deterministic mirror of the property suite) ----


def test_partitioner_stability_and_numeric_folding():
    for key in [None, True, 0, -7, 2**70, 3.5, -0.0, "k", b"k", ("a", 1), ()]:
        p = partition_for(key, 64)
        assert 0 <= p < 64 and partition_for(key, 64) == p
    assert partition_for(3, 64) == partition_for(3.0, 64) == partition_for(np.int64(3), 64)
    assert partition_for(True, 64) == partition_for(1, 64)
    assert partition_for(2**53, 64) == partition_for(float(2**53), 64)
    assert partition_for(-0.0, 64) == partition_for(0, 64)


def test_range_assignment_covers_ring_exactly_once():
    for n in (1, 7, 64):
        for k in (1, 2, 3, 5, n + 3):
            a = range_assignment(n, [f"o{i}" for i in range(k)])
            assert sorted(a) == list(range(n))
    with pytest.raises(ValueError):
        range_assignment(8, [])


def test_grow_shrink_moves_only_the_diff():
    old = range_assignment(64, [0, 1])
    new = range_assignment(64, [0, 1, 2])
    moved = moved_partitions(old, new)
    assert moved and len(moved) < 64  # strictly partial movement
    assert all(old[p] != new[p] for p in moved)
    assert moved_partitions(new, new) == []


def _state_of(store):
    return {kw: [(m.offset, m.timestamp) for m in msgs] for kw, msgs in store.items()}


def test_seeded_migration_fuzz_no_loss_no_dup():
    """The core rescale-safety invariant, driven by stdlib random so it runs
    in every environment (the hypothesis twin lives in test_state.py)."""
    for seed in range(30):
        rnd = random.Random(seed)
        n = rnd.choice([1, 8, 32, 64])
        store = PartitionedStateStore(n)
        for j in range(rnd.randint(1, 50)):
            key = rnd.choice([None, j % 7, f"k{j % 5}", (j % 3, "x"), float(j % 4), b"b"])
            w = (float(j % 5), float(j % 5) + 1.0)
            store.append(key, w, Message(0, j, 0.5 + j, np.array([float(j)])))
        snap = _state_of(store)
        migrator = StateMigrator()
        for _ in range(rnd.randint(1, 8)):
            owners = rnd.sample(range(10), rnd.randint(1, 6))
            report = migrator.migrate(store, owners)
            assert _state_of(store) == snap  # nothing lost/duplicated/reordered
            for (key, _w) in snap:  # exactly one live owner per key
                assert store.owner_of(key) in owners
            for pid, part in store.partitions.items():  # keys in home partitions
                for (k, _w) in part.buffers:
                    assert partition_for(k, n) == pid
            assert set(report.moved) <= set(range(n))
        migrator.cleanup()


def test_unmoved_partitions_keep_identity():
    store = PartitionedStateStore(32, owners=[0, 1])
    for j in range(40):
        store.append(f"k{j}", (0.0, 1.0), Message(0, j, 0.5, float(j)))
    before = dict(store.partitions)
    mig = StateMigrator()
    report = mig.migrate(store, [0, 1, 2])
    assert report.moved  # something moved...
    for pid in range(32):
        if pid in report.moved:
            assert store.partitions[pid] is not before[pid]  # full serde round trip
        else:
            assert store.partitions[pid] is before[pid]  # ...the rest untouched
    mig.cleanup()


def test_partition_counters_count_records_not_window_assignments():
    store = PartitionedStateStore(8)
    msg = Message(0, 0, 1.5, 1.0)
    store.observe("k", msg.timestamp)
    store.append("k", (0.0, 2.0), msg)
    store.append("k", (1.0, 3.0), msg)  # sliding: same record, two windows
    part = store.partitions[store.partition_of("k")]
    assert part.records == 1  # one record...
    assert part.buffered_records == 2  # ...buffered twice
    assert part.max_event_time == 1.5


def test_serde_roundtrip_counters_and_values():
    store = PartitionedStateStore(4)
    store.append("k", (0.0, 1.0), Message(1, 7, 0.5, np.arange(6, dtype=np.float32)))
    store.append("k", (0.0, 1.0), Message(1, 8, 0.6, {"a": [1, 2], "b": "x"}))
    store.append(("t", 2), (1.0, 2.0), Message(0, 9, 1.5, (1, "y", b"z")))
    store.record_late("k")
    pid = store.partition_of("k")
    part = deserialize_partition(serialize_partition(store.partitions[pid]))
    assert part.records == store.partitions[pid].records
    assert part.late_records == store.partitions[pid].late_records
    assert part.max_event_time == store.partitions[pid].max_event_time
    msgs = part.buffers[("k", (0.0, 1.0))]
    assert msgs[0].value.dtype == np.float32 and np.array_equal(msgs[0].value, np.arange(6, dtype=np.float32))
    assert msgs[1].value == {"a": [1, 2], "b": "x"}
    pid2 = store.partition_of(("t", 2))
    part2 = deserialize_partition(serialize_partition(store.partitions[pid2]))
    assert part2.buffers[(("t", 2), (1.0, 2.0))][0].value == (1, "y", b"z")


def test_session_merge_order_is_migration_invariant():
    """Folding overlapping session buffers must produce the same message
    order whether or not a migration (which rebuilds buffers in canonical
    serde order) happened in between — an order-sensitive window_fn would
    otherwise see rescale-dependent aggregates."""
    def build():
        s = PartitionedStateStore(8)
        # two disjoint sessions arriving out of order, then a bridge
        s.append("k", (25.0, 35.0), Message(0, 2, 25.0, np.array([2.0])))
        s.append("k", (0.0, 18.0), Message(0, 0, 0.0, np.array([0.5])))
        s.append("k", (0.0, 18.0), Message(0, 1, 8.0, np.array([1.5])))
        return s
    plain = build()
    plain.merge_session("k", (0.0, 35.0))
    migrated = build()
    mig = StateMigrator()
    mig.migrate(migrated, [0, 1])  # buffers -> canonical order
    mig.cleanup()
    migrated.merge_session("k", (0.0, 35.0))
    order = lambda s: [m.offset for m in s.partitions[s.partition_of("k")].buffers[("k", (0.0, 35.0))]]
    assert order(plain) == order(migrated) == [0, 1, 2]


def test_arbitrary_hashable_keys_route_and_migrate():
    """The engine's key_fn contract predates repro.state: any hashable key
    must keep working (routing + serde), not kill the record loop."""
    exotic = [frozenset({1, 2}), frozenset(), ("nested", frozenset({"x"}))]
    store = PartitionedStateStore(16)
    for j, key in enumerate(exotic):
        assert store.partition_of(key) == store.partition_of(key)
        store.append(key, (0.0, 1.0), Message(0, j, 0.5, float(j)))
    snap = _state_of(store)
    mig = StateMigrator()
    mig.migrate(store, [0, 1, 2])
    mig.cleanup()
    assert _state_of(store) == snap  # pickled keys round-trip to equal objects
    fired = store.pop_ready(1.0)
    assert sorted(m for (_, _, msgs) in fired for m in [msgs[0].offset]) == [0, 1, 2]


def test_structured_dtype_values_survive_migration():
    """Structured arrays must keep field metadata (they bypass the
    columnar fast path, whose dtype.str would flatten them to raw void)."""
    rec = np.zeros(3, dtype=[("a", "<f4"), ("b", "<i4")])
    rec["a"] = [1.5, 2.5, 3.5]
    rec["b"] = [1, 2, 3]
    store = PartitionedStateStore(8)
    store.append("k", (0.0, 1.0), Message(0, 0, 0.5, rec))
    mig = StateMigrator()
    mig.migrate(store, [0, 1])
    mig.cleanup()
    ((_, msgs),) = list(store.items())
    got = msgs[0].value
    assert got.dtype == rec.dtype
    assert np.array_equal(got["a"], rec["a"]) and np.array_equal(got["b"], rec["b"])


def test_empty_owner_set_falls_back_to_local():
    store = PartitionedStateStore(8)
    assert store.owners == [LOCAL_OWNER]
    StateMigrator().migrate(store, [])
    assert store.owners == [LOCAL_OWNER]


def test_migrator_spool_is_atomic_and_bounded(tmp_path):
    store = PartitionedStateStore(16, owners=[0])
    for j in range(20):
        store.append(f"k{j}", (0.0, 1.0), Message(0, j, 0.5, float(j)))
    mig = StateMigrator(directory=str(tmp_path), keep_last=2)
    for owners in ([0, 1], [0, 1, 2], [0], [0, 3]):
        mig.migrate(store, owners)
    names = sorted(os.listdir(tmp_path))
    assert all(not n.endswith(".tmp") for n in names)  # every spool committed
    assert len([n for n in names if n.startswith("migration_")]) <= 2  # gc'd
    mig.cleanup()
    assert os.path.isdir(tmp_path)  # caller-provided directory is kept


def test_migrator_cleans_up_its_own_tempdir():
    store = PartitionedStateStore(8, owners=[0])
    store.append("k", (0.0, 1.0), Message(0, 0, 0.5, 1.0))
    mig = StateMigrator()  # no directory: mkdtemp on first migrate
    mig.migrate(store, [0, 1])
    spool_root = mig.directory
    assert spool_root is not None and os.path.isdir(spool_root)
    mig.cleanup()
    assert not os.path.exists(spool_root)
    mig.cleanup()  # idempotent
    mig.migrate(store, [0])  # and usable again afterwards


def test_migrator_publishes_gauges_and_snapshot_sees_them():
    bus = MetricsBus()
    store = PartitionedStateStore(16, owners=[0])
    for j in range(10):
        store.append(j, (0.0, 1.0), Message(0, j, 0.5, float(j)))
    mig = StateMigrator(bus=bus, label="s1")
    report = mig.migrate(store, [0, 1])
    mig.cleanup()
    assert bus.value("state.migrated_partitions", stream="s1") == len(report.moved)
    assert bus.value("state.migration_ms", stream="s1") == pytest.approx(report.duration_ms)
    assert bus.value("state.bytes_moved", stream="s1") == report.bytes_moved
    snap = MetricsSnapshot.capture(bus, stream="s1")
    assert snap.state_migration_ms == pytest.approx(report.duration_ms)


# -- continuous engine integration ------------------------------------------------


@pytest.fixture
def svc():
    s = PilotComputeService(devices=list(range(8)))
    yield s
    s.cancel()


def _continuous(svc, topic="st", *, bus=None, cores=2, **kw):
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic(topic, 1)  # single partition: in-order event time
    flink = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": cores, "type": "flink"})
    outs = []
    stream = flink.get_context().stream(
        cluster, topic, group="g",
        assigner=kw.pop("assigner", TumblingWindow(1.0)),
        window_fn=kw.pop("window_fn", lambda k, w, msgs: (k, w, sum(float(m.value[0]) for m in msgs), len(msgs))),
        key_fn=lambda m: int(m.value[1]) % 3,
        emit=outs.append, metrics=bus, **kw,
    )
    return cluster, flink, stream, outs


def _send(cluster, topic, lo, hi):
    prod = Producer(cluster, topic, serializer="npy")
    for i in range(lo, hi):
        prod.send(np.array([float(i), i]), timestamp=100.0 + i * 0.2)


def test_rescale_mid_stream_fires_identical_windows(svc):
    """Grow + shrink between windows changes nothing observable: same fired
    set, same aggregates, and the moved buffers took the serde round trip."""
    bus = MetricsBus()
    cluster, flink, stream, outs = _continuous(svc, bus=bus)
    stream.start()
    _send(cluster, "st", 0, 30)
    stream.await_windows(15, timeout=20)
    report = stream.rescale([0, 1, 2, 3])
    assert report.moved  # buffered state actually re-homed
    assert stream.store.owners == [0, 1, 2, 3]
    _send(cluster, "st", 30, 40)
    stream.await_windows(21, timeout=20)
    stream.rescale([0, 1])
    stream.stop()
    assert stream.stats.records == 40 and stream.stats.late_records == 0
    # reference run, no rescale
    svc2 = PilotComputeService(devices=list(range(8)))
    try:
        cluster2, _, s2, outs2 = _continuous(svc2, topic="st2")
        s2.start()
        _send(cluster2, "st2", 0, 40)
        s2.await_windows(21, timeout=20)
        s2.stop()
    finally:
        svc2.cancel()
    assert sorted(outs, key=str) == sorted(outs2, key=str)
    assert bus.value("state.migration_ms", stream="st") > 0.0


def test_extension_pilot_triggers_migration_automatically(svc):
    """paper Listing 4: submit_pilot(parent=engine) -> plugin.extend ->
    stream.rescale -> StateMigrator, no user code in the loop."""
    cluster, flink, stream, _ = _continuous(svc)
    stream.start()
    _send(cluster, "st", 0, 10)
    stream.await_windows(3, timeout=20)
    assert stream.last_migration is None
    ext = svc.submit_pilot(
        {"number_of_nodes": 1, "cores_per_node": 2, "type": "flink", "parent": flink})
    assert stream.last_migration is not None
    assert len(stream.store.owners) == 4  # 2 base + 2 extension devices
    ext.cancel()  # shrink migrates back
    assert len(stream.migrator.reports) == 2
    assert len(stream.store.owners) == 2
    spool_root = stream.migrator.directory
    assert spool_root is not None and os.path.isdir(spool_root)
    stream.stop()
    assert not os.path.exists(spool_root)  # tempdir spools die with the stream
    # teardown-order calls (plugin shrink after stop) must not migrate or
    # resurrect the spool on a dead stream
    assert stream.rescale([0]) is None
    assert stream.migrator.directory is None


def test_rescale_quiesces_inflight_window_fn(svc):
    """Regression: rescale() used to run concurrently with an in-flight
    window_fn/process call — it must block until the fire completes."""
    entered, release = threading.Event(), threading.Event()
    finished_at, rescaled_at = [], []

    def slow_window(k, w, msgs):
        entered.set()
        release.wait(10)
        finished_at.append(time.monotonic())
        return len(msgs)

    cluster, flink, stream, _ = _continuous(svc, window_fn=slow_window)
    stream.start()
    _send(cluster, "st", 0, 10)  # several closed windows -> slow_window runs
    assert entered.wait(10)

    t = threading.Thread(
        target=lambda: (stream.rescale([0, 1, 2]), rescaled_at.append(time.monotonic())),
        daemon=True,
    )
    t.start()
    time.sleep(0.3)
    assert not rescaled_at, "rescale() returned while a window_fn call was in flight"
    release.set()
    t.join(10)
    assert rescaled_at and finished_at
    assert rescaled_at[0] >= finished_at[0]
    stream.stop()


def test_rescale_runs_sync_barrier_before_migrating(svc):
    """An async (double-buffered) processor's sync() must land in-flight
    device work before its partitions are serialized — auto-wired from a
    bound window_fn, mirroring MicroBatchStream."""
    calls = []

    class Proc:
        def process(self, k, w, msgs):
            return len(msgs)

        def sync(self):
            calls.append("sync")

    proc = Proc()
    cluster, flink, stream, _ = _continuous(svc, window_fn=proc.process)
    assert stream.sync_fn is not None  # auto-wired
    stream.start()
    stream.rescale([0, 1])
    assert calls == ["sync"]
    stream.stop()


def test_session_windows_survive_migration(svc):
    """Session state (mergeable windows) migrates like any other buffer."""
    outs = []
    cluster, flink, stream, _ = _continuous(
        svc, assigner=SessionWindow(gap=1.0),
        window_fn=lambda k, w, msgs: (k, w, len(msgs)),
    )
    stream.emit = outs.append
    stream.start()
    prod = Producer(cluster, "st", serializer="npy")
    # two bursts per key separated by > gap; second burst closes the first
    for i in range(6):
        prod.send(np.array([float(i), i]), timestamp=100.0 + i * 0.1)
    time.sleep(0.3)
    stream.rescale([0, 1, 2])  # sessions still open: they ride the migration
    for i in range(6):
        prod.send(np.array([float(i), i]), timestamp=110.0 + i * 0.1)
    stream.await_windows(3, timeout=20)
    # fired sessions are pruned from the assigner (unbounded-growth guard);
    # only the still-open second-burst sessions remain
    for key in range(3):
        assert all(s[0] >= 110.0 for s in stream.assigner.sessions(key))
    stream.stop()
    fired = {(k, w): n for k, w, n in outs}
    assert len(fired) == 3  # one merged session per key fired
    assert all(n == 2 for n in fired.values())

"""Shared-memory transport: ring mechanics, frame serde, broker batch
APIs, reclaim safety, engine integration (docs/transport.md).

The zero-copy contract under test: same-host consumers read frames as
``numpy.frombuffer`` views into the ring; a view that outlives its slot
is *detected* (epoch mismatch -> SlotReclaimedError), never silently
corrupted; everything that can't ride the ring (rf>1, oversized frames,
cross-process copies) transparently falls back to copy-out with
identical results.
"""
import multiprocessing as mp
import pickle
import threading
import time

import numpy as np
import pytest

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup
from repro.broker.log import PartitionLog
from repro.broker.producer import Producer
from repro.broker.records import Record
from repro.transport import (
    FrameBatch,
    RingTimeout,
    SharedMemoryRing,
    ShmArrayView,
    ShmTransport,
    SlotReclaimedError,
    decode_frame,
    pack_frame,
)


def shm_cluster(topic="t", *, n_parts=1, slot_bytes=1 << 20, n_slots=16,
                replication_factor=1, n_nodes=1):
    cluster = BrokerCluster(n_nodes)
    transport = ShmTransport(slot_bytes=slot_bytes, n_slots=n_slots)
    cluster.attach_transport(transport)
    cluster.create_topic(topic, n_parts, replication_factor=replication_factor)
    transport.mount(topic)
    return cluster, transport


@pytest.fixture
def shm_setup():
    cluster, transport = shm_cluster()
    yield cluster, transport
    cluster.close()


# ---------------------------------------------------------------------------
# ring mechanics
# ---------------------------------------------------------------------------


def test_ring_alloc_write_view_release_roundtrip():
    ring = SharedMemoryRing(slot_bytes=256, n_slots=4)
    try:
        slot, epoch = ring.alloc()
        assert epoch % 2 == 1  # odd = live
        assert ring.free_slots == 3
        payload = b"hello transport"
        ring.write(slot, epoch, [payload])
        assert bytes(ring.view(slot, epoch)) == payload
        ring.release(slot, epoch)
        assert ring.free_slots == 4
        assert not ring.is_valid(slot, epoch)
        with pytest.raises(SlotReclaimedError):
            ring.view(slot, epoch)
    finally:
        ring.destroy()


def test_ring_write_rejects_oversized_frames():
    ring = SharedMemoryRing(slot_bytes=16, n_slots=2)
    try:
        slot, epoch = ring.alloc()
        with pytest.raises(ValueError):
            ring.write(slot, epoch, [b"x" * 32])
    finally:
        ring.destroy()


def test_ring_exhaustion_stalls_then_times_out():
    ring = SharedMemoryRing(slot_bytes=64, n_slots=2)
    try:
        ring.alloc()
        ring.alloc()
        t0 = time.monotonic()
        with pytest.raises(RingTimeout):
            ring.alloc(deadline=time.monotonic() + 0.15)
        assert time.monotonic() - t0 >= 0.1
        assert ring.stall_seconds > 0  # backpressure is observable
    finally:
        ring.destroy()


def test_ring_reader_refcount_defers_reclaim():
    ring = SharedMemoryRing(slot_bytes=64, n_slots=2)
    try:
        slot, epoch = ring.alloc()
        ring.write(slot, epoch, [b"pinned"])
        assert ring.retain(slot, epoch)
        ring.release(slot, epoch)  # producer done, but a reader holds it
        assert ring.is_valid(slot, epoch)
        assert ring.free_slots == 1
        ring.release_ref(slot, epoch)  # last reader out -> reclaimed
        assert not ring.is_valid(slot, epoch)
        assert ring.free_slots == 2
    finally:
        ring.destroy()


def test_ring_attach_by_name_is_self_describing():
    ring = SharedMemoryRing(slot_bytes=128, n_slots=3)
    try:
        slot, epoch = ring.alloc()
        ring.write(slot, epoch, [b"cross-handle"])
        other = SharedMemoryRing.attach(ring.name)
        assert (other.slot_bytes, other.n_slots) == (128, 3)
        assert bytes(other.view(slot, epoch)) == b"cross-handle"
        other.close()
    finally:
        ring.destroy()


# ---------------------------------------------------------------------------
# frame serde
# ---------------------------------------------------------------------------


def test_frame_roundtrip_mixed_payloads():
    vals = [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.ones((3, 4), dtype=np.float32) * 7,       # same group
        np.arange(5, dtype=np.int64),                # second group
        b"raw-bytes",                                # fallback: bytes
        np.float64(3.5),                             # fallback: 0-d
    ]
    ts = [10.0, 11.0, 12.0, 13.0, 14.0]
    frame = decode_frame(pack_frame(vals, ts, key=b"k7"))
    assert frame.timestamps == ts and frame.key == b"k7"
    assert np.array_equal(frame.values[0], vals[0])
    assert np.array_equal(frame.values[1], vals[1])
    assert np.array_equal(frame.values[2], vals[2])
    assert frame.values[3] == b"raw-bytes"
    assert float(frame.values[4]) == 3.5


def test_frame_roundtrip_structured_dtype():
    dt = np.dtype([("id", "<u4"), ("pos", "<f8", (3,)), ("flag", "?")])
    rows = np.zeros(4, dtype=dt)
    rows["id"] = [1, 2, 3, 4]
    rows["pos"] = np.arange(12).reshape(4, 3)
    rows["flag"] = [True, False, True, False]
    frame = decode_frame(pack_frame([rows, rows]))
    assert frame.values[0].dtype == dt  # dtype.str would have lost the fields
    assert np.array_equal(frame.values[1], rows)


def test_frame_zero_copy_views_alias_the_buffer():
    vals = [np.full((8,), i, dtype=np.int32) for i in range(4)]
    buf = bytearray(pack_frame(vals))
    raw = np.frombuffer(buf, dtype=np.uint8)
    zc = decode_frame(buf, zero_copy=True)
    co = decode_frame(buf, zero_copy=False)
    for v in zc.values:
        assert np.shares_memory(raw, v)  # true views, zero serde copies
    for v in co.values:
        assert not np.shares_memory(raw, v)  # default is detached copies
    for a, b in zip(zc.values, co.values):
        assert np.array_equal(a, b)


def test_zero_copy_view_across_reclaim_is_detected_not_corrupted(shm_setup):
    """Regression (ISSUE 8 satellite): a consumer holding zero-copy views
    across a slot reclaim must get an epoch-mismatch error on verify, not
    silently recycled bytes."""
    cluster, transport = shm_setup
    ring = transport.ring_for("t")
    prod = Producer(cluster, "t")
    group = ConsumerGroup(cluster, "g", "t")
    cons = Consumer(cluster, group, "m0", zero_copy=True)
    prod.send_batch([np.arange(64, dtype=np.float64)])
    [batch] = cons.poll_batch(timeout=1.0)
    view = batch.values[0]
    assert isinstance(view, ShmArrayView)
    batch.frame.verify()  # still live: fine
    cons.commit()          # advances the reclaim floor past the frame
    assert ring.free_slots == ring.n_slots, "commit should reclaim the slot"
    with pytest.raises(SlotReclaimedError):
        batch.frame.verify()
    with pytest.raises(SlotReclaimedError):
        view.verify()


# ---------------------------------------------------------------------------
# broker batch path
# ---------------------------------------------------------------------------


def test_append_many_single_batch_offsets_and_stats():
    log = PartitionLog("t", 0)
    recs = [Record(bytes([i]) * 4) for i in range(8)]
    offsets = log.append_many(recs)
    assert offsets == list(range(8))
    assert log.stats.appended_records == 8
    assert log.high_watermark == 8
    assert [r.offset for r in log.read(0, 100)] == offsets


def test_append_many_drop_policy_marks_holes():
    log = PartitionLog("t", 0, max_buffer_bytes=10, backpressure="drop")
    offsets = log.append_many([Record(b"x" * 4) for _ in range(4)])
    assert offsets == [0, 1, -1, -1]
    assert log.stats.dropped_records == 2


def test_send_batch_shm_uses_one_slot_and_tiny_records(shm_setup):
    cluster, transport = shm_setup
    ring = transport.ring_for("t")
    prod = Producer(cluster, "t")
    vals = [np.arange(256, dtype=np.float32) + i for i in range(20)]
    offsets = prod.send_batch(vals, key=b"k", timestamps=[float(i) for i in range(20)])
    assert offsets == list(range(20))
    assert ring.used_slots == 1  # 20 messages, one payload write
    log = cluster.topic("t").partitions[0]
    recs = log.read(0, 100)
    assert all(r.value[:1] == b"S" for r in recs)
    assert all(len(r.value) < 100 for r in recs)  # control plane only
    group = ConsumerGroup(cluster, "g", "t")
    cons = Consumer(cluster, group, "m0")
    msgs = cons.poll(max_records=64, timeout=1.0)
    assert len(msgs) == 20
    assert msgs[5].timestamp == 5.0
    for m, v in zip(msgs, vals):
        assert np.array_equal(m.value, v)
        assert not isinstance(m.value, ShmArrayView)  # default = copy-out


def test_send_batch_replicated_topic_copies_out():
    cluster, transport = shm_cluster("rep", replication_factor=2, n_nodes=2)
    try:
        prod = Producer(cluster, "rep")
        vals = [np.arange(16, dtype=np.int32) * i for i in range(5)]
        prod.send_batch(vals)
        assert transport.ring_for("rep").used_slots == 0  # rf>1: inline
        group = ConsumerGroup(cluster, "g", "rep")
        cons = Consumer(cluster, group, "m0")
        msgs = cons.poll(timeout=1.0)
        assert len(msgs) == 5
        for m, v in zip(msgs, vals):
            assert np.array_equal(m.value, v)
    finally:
        cluster.close()


def test_send_batch_oversized_frame_falls_back_inline():
    cluster, transport = shm_cluster("small", slot_bytes=1024)
    try:
        prod = Producer(cluster, "small")
        vals = [np.zeros(4096, dtype=np.float64)]  # 32KB >> 1KB slot
        prod.send_batch(vals)
        assert transport.ring_for("small").used_slots == 0
        group = ConsumerGroup(cluster, "g", "small")
        cons = Consumer(cluster, group, "m0")
        [m] = cons.poll(timeout=1.0)
        assert np.array_equal(m.value, vals[0])
    finally:
        cluster.close()


def test_poll_batch_groups_by_frame(shm_setup):
    cluster, _ = shm_setup
    prod = Producer(cluster, "t")
    prod.send_batch([np.ones(8, dtype=np.float32) * i for i in range(6)])
    prod.send_batch([np.ones(8, dtype=np.float32) * i for i in range(4)])
    group = ConsumerGroup(cluster, "g", "t")
    cons = Consumer(cluster, group, "m0")
    batches = cons.poll_batch(timeout=1.0, zero_copy=True)
    assert [len(b) for b in batches] == [6, 4]
    assert batches[0].offsets == list(range(6))
    assert batches[1].offsets == list(range(6, 10))
    assert float(batches[1].values[3][0]) == 3.0
    for b in batches:
        b.frame.verify()


# ---------------------------------------------------------------------------
# reclaim: commit floors, replay floors, backpressure
# ---------------------------------------------------------------------------


def test_slowest_group_pins_the_reclaim_floor(shm_setup):
    cluster, transport = shm_setup
    ring = transport.ring_for("t")
    prod = Producer(cluster, "t")
    fast = Consumer(cluster, ConsumerGroup(cluster, "fast", "t"), "f0")
    slow = Consumer(cluster, ConsumerGroup(cluster, "slow", "t"), "s0")
    for i in range(3):
        prod.send_batch([np.arange(32, dtype=np.float64) + i])
    assert ring.used_slots == 3
    fast.poll(timeout=1.0)
    fast.commit()
    # the slow group has registered but not committed: nothing reclaims
    assert ring.used_slots == 3
    slow.poll(timeout=1.0)
    slow.commit()
    assert ring.used_slots == 0


def test_replay_floor_holds_slots_past_commits(shm_setup):
    cluster, transport = shm_setup
    ring = transport.ring_for("t")
    prod = Producer(cluster, "t")
    cons = Consumer(cluster, ConsumerGroup(cluster, "g", "t"), "m0")
    # a checkpointing stream pins its replay horizon at offset 0 first
    cluster.set_replay_floor("g", "t", {0: 0})
    for i in range(3):
        prod.send_batch([np.arange(32, dtype=np.float64) + i])
    cons.poll(timeout=1.0)
    cons.commit()
    assert ring.used_slots == 3, "commit must not reclaim below the replay floor"
    # ... until the next checkpoint advances it
    cluster.set_replay_floor("g", "t", {0: 3})
    assert ring.used_slots == 0


def test_full_ring_backpressure_stalls_producer_and_feeds_io_stall():
    cluster, transport = shm_cluster("bp", slot_bytes=4096, n_slots=2)
    try:
        prod = Producer(cluster, "bp", send_timeout=5.0)
        cons = Consumer(cluster, ConsumerGroup(cluster, "g", "bp"), "m0")
        base_stall = cluster.io_stall_seconds()
        for i in range(2):
            prod.send_batch([np.arange(64, dtype=np.float64)])
        done = threading.Event()

        def produce_third():
            prod.send_batch([np.arange(64, dtype=np.float64)])
            done.set()

        t = threading.Thread(target=produce_third, daemon=True)
        t.start()
        assert not done.wait(0.3), "third batch should stall on the full ring"
        cons.poll(timeout=1.0)
        cons.commit()  # frees slots -> the stalled producer completes
        assert done.wait(5.0)
        assert cluster.io_stall_seconds() > base_stall  # elasticity signal
    finally:
        cluster.close()


def test_transport_unmount_unlinks_segment(shm_setup):
    cluster, transport = shm_setup
    name = transport.ring_for("t").name
    cluster.delete_topic("t")
    from multiprocessing import shared_memory
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name)


def test_broker_pilot_cancel_cleans_up_segments():
    from repro.core import PilotComputeService

    svc = PilotComputeService(devices=[0, 1])
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    transport = ShmTransport(n_slots=4)
    cluster.attach_transport(transport)
    cluster.create_topic("x", 1)
    transport.mount("x")
    name = transport.ring_for("x").name
    svc.cancel()
    from multiprocessing import shared_memory
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name)


# ---------------------------------------------------------------------------
# producer rate limiter (satellite fix)
# ---------------------------------------------------------------------------


def test_rate_limiter_sleeps_outside_the_lock(monkeypatch):
    cluster = BrokerCluster(1)
    cluster.create_topic("r", 1)
    prod = Producer(cluster, "r", rate_msgs_per_s=200.0)
    lock_held_during_sleep = []
    real_sleep = time.sleep

    def spy_sleep(seconds):
        lock_held_during_sleep.append(prod._lock.locked())

    monkeypatch.setattr(time, "sleep", spy_sleep)
    prod.send(np.zeros(4))
    prod.send(np.zeros(4))  # second send must wait for its slot
    monkeypatch.setattr(time, "sleep", real_sleep)
    assert lock_held_during_sleep, "the limiter never slept"
    assert not any(lock_held_during_sleep), (
        "rate-limit sleep while holding Producer._lock serializes all "
        "sender threads behind one sleeper")


def test_rate_limiter_paces_batches_by_element_count():
    cluster = BrokerCluster(1)
    cluster.create_topic("r", 1)
    prod = Producer(cluster, "r", rate_msgs_per_s=1000.0)
    t0 = time.monotonic()
    for _ in range(5):
        prod.send_batch([np.zeros(4) for _ in range(20)])
    # 100 msgs at 1000/s: the schedule spans >= ~80ms even though there
    # were only 5 batch calls
    assert time.monotonic() - t0 >= 0.08


# ---------------------------------------------------------------------------
# cross-process: workers attach to the segment by name
# ---------------------------------------------------------------------------


def _child_read_view(pickled, q):
    try:
        view = pickle.loads(pickled)  # reattaches the segment by name
        q.put(("sum", float(np.asarray(view).sum())))
        q.put(("valid", True))
    except SlotReclaimedError:
        q.put(("reclaimed", True))
    except Exception as exc:  # pragma: no cover
        q.put(("error", repr(exc)))


def _child_read_reclaimed(pickled, q):
    try:
        pickle.loads(pickled)
        q.put(("error", "reattach of a reclaimed slot succeeded"))
    except SlotReclaimedError:
        q.put(("reclaimed", True))
    except Exception as exc:  # pragma: no cover
        q.put(("error", repr(exc)))


def test_worker_process_attaches_view_by_name(shm_setup):
    cluster, transport = shm_setup
    prod = Producer(cluster, "t")
    cons = Consumer(cluster, ConsumerGroup(cluster, "g", "t"), "m0",
                    zero_copy=True)
    arr = np.arange(128, dtype=np.float64)
    prod.send_batch([arr])
    [batch] = cons.poll_batch(timeout=1.0)
    view = batch.values[0]
    payload = pickle.dumps(view)
    assert len(payload) < 512, "a pickled view must ship a descriptor, not bytes"
    ctx = mp.get_context("fork")
    q = ctx.Queue()
    p = ctx.Process(target=_child_read_view, args=(payload, q))
    p.start()
    p.join(10)
    results = dict(q.get(timeout=5) for _ in range(2))
    assert results.get("sum") == float(arr.sum())
    # now reclaim the slot and prove a late worker DETECTS it
    cons.commit()
    p2 = ctx.Process(target=_child_read_reclaimed, args=(payload, q))
    p2.start()
    p2.join(10)
    kind, val = q.get(timeout=5)
    assert kind == "reclaimed", val


# ---------------------------------------------------------------------------
# engines on transport="shm"
# ---------------------------------------------------------------------------


def test_microbatch_engine_processes_shm_batches_zero_copy():
    from repro.engines.microbatch import MicroBatchStream

    cluster, transport = shm_cluster("mb")
    try:
        seen = {"n": 0, "sum": 0.0, "zero_copy_values": 0}

        def process(state, msgs):
            for m in msgs:
                seen["n"] += 1
                seen["sum"] += float(np.asarray(m.value).sum())
                if isinstance(m.value, ShmArrayView):
                    seen["zero_copy_values"] += 1
            return state

        stream = MicroBatchStream(
            cluster, "mb", group="g", process_fn=process,
            batch_interval=0.05, transport="shm")
        stream.start()
        prod = Producer(cluster, "mb")
        total = 0.0
        for i in range(8):
            vals = [np.full((16,), i * 10 + j, dtype=np.float64) for j in range(10)]
            total += float(sum(v.sum() for v in vals))
            prod.send_batch(vals)
        deadline = time.monotonic() + 15
        while seen["n"] < 80 and time.monotonic() < deadline:
            time.sleep(0.02)
        stream.stop()
        assert seen["n"] == 80
        assert seen["sum"] == total
        assert seen["zero_copy_values"] == 80  # the ingest loop got views
    finally:
        cluster.close()


def test_continuous_engine_windows_identical_log_vs_shm():
    from repro.streaming import TumblingWindow
    from repro.engines.continuous import ContinuousStream

    def run(transport_mode):
        if transport_mode == "shm":
            cluster, _ = shm_cluster("cw")
        else:
            cluster = BrokerCluster(1)
            cluster.create_topic("cw", 1)
        results = {}
        stream = ContinuousStream(
            cluster, "cw", group="g", assigner=TumblingWindow(0.1),
            window_fn=lambda key, w, msgs: (key, w, float(np.sum(
                [m.value[1] for m in msgs])), len(msgs)),
            key_fn=lambda m: int(m.value[0]),
            emit=lambda out: results.__setitem__((out[0], out[1]),
                                                 (out[2], out[3])),
            transport=transport_mode,
        )
        stream.start()
        prod = Producer(cluster, "cw")
        for b in range(30):
            vals = [np.array([(b * 10 + j) % 3, float(b * 10 + j) * 1.25])
                    for j in range(10)]
            ts = [1000.0 + (b * 10 + j) * 0.01 for j in range(10)]
            prod.send_batch(vals, key=b"k", timestamps=ts)
        expected = (int(300 * 0.01 / 0.1) - 1) * 3
        stream.await_windows(expected, timeout=20)
        stream.stop()
        cluster.close()
        return results

    assert run("log") == run("shm")


# ---------------------------------------------------------------------------
# detector-simulator source + pipeline spec plumbing
# ---------------------------------------------------------------------------


def test_detector_source_batches_through_the_ring():
    from repro.miniapps import SOURCES, DetectorSimSource, SourceConfig

    assert SOURCES["detector"] is DetectorSimSource
    cluster, transport = shm_cluster("det", n_slots=32)
    try:
        src = DetectorSimSource(
            cluster, SourceConfig("det", total_messages=64),
            ny=32, nx=32, n_cached=4, frames_per_batch=16)
        src.start()
        deadline = time.monotonic() + 10
        while not src.finished and time.monotonic() < deadline:
            time.sleep(0.02)
        assert src.finished
        assert src.sent_records == 64
        log = cluster.topic("det").partitions[0]
        assert log.high_watermark == 64
        assert transport.ring_for("det").used_slots == 4  # 64/16 frames
        cons = Consumer(cluster, ConsumerGroup(cluster, "g", "det"), "m0")
        msgs = cons.poll(max_records=64, timeout=1.0)
        assert len(msgs) == 64
        assert msgs[0].value.dtype == np.uint16
        assert msgs[0].value.shape == (32, 32)
        # cache replay: frame 0 and frame 4 are the same cached payload
        assert np.array_equal(msgs[0].value, msgs[4].value)
    finally:
        src.stop()
        cluster.close()


def test_detector_source_hdf5_input(tmp_path):
    h5py = pytest.importorskip("h5py")
    from repro.miniapps import DetectorSimSource, SourceConfig

    path = tmp_path / "frames.h5"
    frames = np.arange(3 * 8 * 8, dtype=np.uint16).reshape(3, 8, 8)
    with h5py.File(path, "w") as f:
        f.create_dataset("frames", data=frames)
    cluster, _ = shm_cluster("h5")
    try:
        src = DetectorSimSource(
            cluster, SourceConfig("h5", total_messages=3),
            hdf5_path=str(path), n_cached=8, frames_per_batch=3)
        src.start()
        deadline = time.monotonic() + 10
        while not src.finished and time.monotonic() < deadline:
            time.sleep(0.02)
        cons = Consumer(cluster, ConsumerGroup(cluster, "g", "h5"), "m0")
        msgs = cons.poll(max_records=8, timeout=1.0)
        assert len(msgs) == 3
        for m, f in zip(msgs, frames):
            assert np.array_equal(m.value, f)
    finally:
        src.stop()
        cluster.close()


def test_pipeline_spec_roundtrips_transport_fields():
    from repro.pipeline import Pipeline, PipelineSpec

    spec = (
        Pipeline.named("shm-pipe")
        .broker(nodes=1, transport="shm",
                transport_options={"slot_bytes": 1 << 16, "n_slots": 8})
        .topic("frames", partitions=1)
        .source("frames", kind="detector", total_messages=10)
        .stage("agg", topic="frames", processor=lambda state, msgs: state,
               transport="shm")
        .build()
    )
    assert spec.broker.transport == "shm"
    assert spec.broker.transport_options == {"slot_bytes": 1 << 16, "n_slots": 8}
    assert spec.stage("agg").transport == "shm"
    back = PipelineSpec.from_dict(spec.to_dict())
    assert back == spec


def test_builder_rejects_bad_transport_combinations():
    from repro.pipeline import Pipeline, PipelineValidationError

    with pytest.raises(PipelineValidationError) as exc:
        (
            Pipeline.named("bad")
            .broker(transport="carrier-pigeon")
            .topic("x", partitions=1)
            .stage("s", topic="x", processor=lambda st, ms: st,
                   transport="shm")
            .build()
        )
    msg = str(exc.value)
    assert "carrier-pigeon" in msg
    assert "requires the broker" in msg

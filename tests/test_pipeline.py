"""Declarative pipeline API: spec serde, builder validation, run lifecycle.

The lifecycle tests drive real (in-process) pilots through small pipelines
and assert the runner's ordering guarantees: reverse-order teardown even
when a stage dies mid-run or provisioning fails half-way, and idempotent
``stop()``.
"""
import time

import numpy as np
import pytest

from repro.elastic import LatencyPolicy, MetricsSnapshot
from repro.pipeline import (
    Pipeline,
    PipelineSpec,
    PipelineValidationError,
    register_processor,
    register_source,
)
from repro.miniapps import StreamSource


# ---------------------------------------------------------------------------
# fixtures: tiny source + processors
# ---------------------------------------------------------------------------


@register_source("vec8")
class _Vec8Source(StreamSource):
    def make_message(self, rng, i):
        return rng.normal(size=(8,))


@register_processor("count_msgs")
def _count(state, msgs):
    return (state or 0) + len(msgs)


def _tiny(name="t", **stage_kw):
    return (Pipeline.named(name)
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=400, total_messages=64)
            .stage("s", topic="in", processor="count_msgs",
                   batch_interval=0.05, backpressure=False, **stage_kw)
            .build())


# ---------------------------------------------------------------------------
# spec serde
# ---------------------------------------------------------------------------


def test_spec_round_trips_dict_and_json():
    spec = (Pipeline.named("rt")
            .broker(nodes=2, io_rate_per_node=1e6)
            .topic("a", partitions=4).topic("b", partitions=2)
            .source("a", kind="cluster", rate_msgs_per_s=100, n_producers=2,
                    rate_schedule=[(1.0, 100), (2.0, 300)],
                    n_clusters=4, dim=3)
            .stage("first", topic="a", processor="kmeans", cores_per_node=2,
                   emits=True, output_topic="b", n_clusters=4, dim=3)
            .stage("second", topic="b", processor="count_msgs",
                   engine="continuous", window={"window": "tumbling", "size": 0.5})
            .sink("drain", topic="b")
            .elastic("first", policy="latency", up_frac=0.7, interval=0.2)
            .build())
    assert PipelineSpec.from_dict(spec.to_dict()) == spec
    assert PipelineSpec.from_json(spec.to_json()) == spec
    # the dict form is genuinely plain data (JSON survives a full cycle)
    import json

    assert json.loads(spec.to_json()) == spec.to_dict()


def test_spec_is_frozen_and_does_not_alias_caller_dicts():
    opts = {"n_clusters": 4}
    spec = (Pipeline.named("fz").topic("a")
            .stage("s", topic="a", processor="kmeans", **opts).build())
    opts["n_clusters"] = 99
    assert spec.stage("s").options["n_clusters"] == 4
    with pytest.raises(AttributeError):
        spec.stage("s").topic = "other"


# ---------------------------------------------------------------------------
# builder validation
# ---------------------------------------------------------------------------


def test_builder_collects_all_errors():
    with pytest.raises(PipelineValidationError) as ei:
        (Pipeline.named("bad")
         .topic("a").topic("b")
         .stage("s1", topic="ghost", processor="nope", engine="weird")
         .stage("s1", topic="a", processor="count_msgs")
         .elastic("missing", policy="alien")
         .build())
    text = str(ei.value)
    for frag in ("unknown topic 'ghost'", "unknown processor 'nope'",
                 "unknown engine 'weird'", "duplicate stage name 's1'",
                 "unknown stage 'missing'", "unknown elastic policy 'alien'"):
        assert frag in text, f"missing {frag!r} in:\n{text}"


def test_builder_rejects_topic_cycles_and_emit_mismatches():
    with pytest.raises(PipelineValidationError) as ei:
        (Pipeline.named("cyc")
         .topic("a").topic("b")
         .stage("f", topic="a", processor="count_msgs", emits=True, output_topic="b")
         .stage("g", topic="b", processor="count_msgs", emits=True, output_topic="a")
         .build())
    assert "topic cycle" in str(ei.value)
    with pytest.raises(PipelineValidationError) as ei:
        (Pipeline.named("em").topic("a").topic("b")
         .stage("f", topic="a", processor="count_msgs", output_topic="b")
         .build())
    assert "needs emits=True" in str(ei.value)


def test_builder_validates_state_partitions_at_build_time():
    with pytest.raises(PipelineValidationError) as ei:
        (Pipeline.named("sp").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous",
                window={"window": "tumbling", "size": 1.0}, state_partitions=0)
         .build())
    assert "state_partitions must be >= 1" in str(ei.value)
    # the default and explicit sizes round-trip through the spec
    spec = (Pipeline.named("sp2").topic("a")
            .stage("s", topic="a", processor="count_msgs", engine="continuous",
                   window={"window": "tumbling", "size": 1.0}, state_partitions=16)
            .build())
    assert spec.stage("s").state_partitions == 16
    assert PipelineSpec.from_json(spec.to_json()) == spec


def test_builder_validates_policy_params_at_build_time():
    with pytest.raises(PipelineValidationError) as ei:
        (Pipeline.named("pp").topic("a")
         .stage("s", topic="a", processor="count_msgs")
         .elastic("s", policy="threshold")  # high_lag/low_lag missing
         .build())
    assert "high_lag" in str(ei.value)
    # latency policy needs no explicit batch_interval: injected from the stage
    spec = (Pipeline.named("lat").topic("a")
            .stage("s", topic="a", processor="count_msgs", batch_interval=0.2)
            .elastic("s", policy="latency")
            .build())
    assert spec.stage("s").elastic.policy == "latency"


# ---------------------------------------------------------------------------
# run lifecycle
# ---------------------------------------------------------------------------


def test_run_processes_and_tears_down_in_reverse_order():
    spec = _tiny("lifecycle")
    with spec.run(devices=2) as run:
        run.await_batches("s", 1, timeout=20)
        assert run.stream("s").stats.records > 0
    assert run.errors == []
    # teardown is the exact reverse of start order
    assert run.teardown_log == ["source:in", "stream:s", "service"]
    # the run's pilots are gone and the pool is whole again
    assert run.service.pool.leased_devices == 0
    assert run.service.pilots == []


def test_run_stop_is_idempotent():
    spec = _tiny("double-stop")
    run = spec.run(devices=2).start()
    run.await_batches("s", 1, timeout=20)
    run.stop()
    log_after_first = list(run.teardown_log)
    run.stop()  # second stop must be a no-op, not a re-teardown
    assert run.teardown_log == log_after_first
    assert run.errors == []


def test_run_teardown_order_survives_mid_run_stage_failure():
    @register_processor("explode_after_2")
    class Exploding:
        def __init__(self):
            self.batches = 0

        def process(self, state, msgs):
            self.batches += 1
            if self.batches > 2:
                raise RuntimeError("stage blew up mid-run")
            return (state or 0) + len(msgs)

    spec = (Pipeline.named("boom")
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=400)
            .stage("s", topic="in", processor="explode_after_2",
                   batch_interval=0.05, backpressure=False)
            .build())
    with spec.run(devices=2) as run:
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline and run.stream("s")._error is None:
            time.sleep(0.05)
        assert run.stream("s")._error is not None
    # the dead stage's error is collected at teardown, not raised, and the
    # components behind it (source first, service last) still came down
    assert run.teardown_log == ["source:in", "stream:s", "service"]
    assert any("stage blew up" in str(e) for e in run.errors)
    assert run.service.pool.leased_devices == 0


def test_run_unwinds_when_provisioning_fails_half_way():
    @register_processor("broken_factory")
    class BrokenFactory:
        def __init__(self):
            raise RuntimeError("cannot construct processor")

    spec = (Pipeline.named("halfway")
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=100)
            .stage("s", topic="in", processor="broken_factory")
            .build())
    run = spec.run(devices=2)
    with pytest.raises(RuntimeError, match="cannot construct"):
        run.start()
    # broker + engine pilots that did come up were released again
    assert run.service.pool.leased_devices == 0
    assert run.teardown_log[-1] == "service"


def test_run_chains_stages_through_topics_and_sinks():
    @register_processor("double_vals")
    def double_vals(state, msgs):
        return (state or 0) + len(msgs), [np.asarray(m.value) * 2.0 for m in msgs]

    spec = (Pipeline.named("chain")
            .topic("raw", partitions=2).topic("out", partitions=2)
            .source("raw", kind="vec8", rate_msgs_per_s=400, total_messages=16)
            .stage("x2", topic="raw", processor="double_vals",
                   emits=True, output_topic="out",
                   batch_interval=0.05, backpressure=False)
            .sink("collect", topic="out")
            .build())
    with spec.run(devices=2) as run:
        run.await_batches("x2", 1, timeout=20)
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline and not run.sink("collect").items:
            time.sleep(0.05)
        items = list(run.sink("collect").items)
    assert items, "sink should observe the doubled stream"
    assert all(v.shape == (8,) for v in items)
    assert run.errors == []


@pytest.mark.slow
def test_run_elastic_closed_loop_scales_up_and_down():
    """The examples/elastic_pipeline.py scenario, compressed."""
    capacity = {"n": 2}

    @register_processor("slow_stage")
    class Slow:
        def process(self, state, msgs):
            time.sleep(len(msgs) * 0.01 / capacity["n"])
            return (state or 0) + len(msgs)

        def on_rescale(self, devices):
            capacity["n"] = max(len(devices), 1)
            return None

    spec = (Pipeline.named("elastic")
            .topic("points", partitions=4)
            .source("points", kind="vec8", rate_msgs_per_s=60,
                    rate_schedule=[(0.5, 60), (4.0, 300), (4.0, 40)])
            .stage("work", topic="points", processor="slow_stage",
                   cores_per_node=2, batch_interval=0.05,
                   max_batch_records=32, backpressure=False)
            .elastic("work", policy="threshold", high_lag=80, low_lag=15,
                     up_stable=2, down_stable=3, interval=0.1, cooldown=1.0,
                     min_devices=2, max_devices=6, devices_per_step=2)
            .build())
    with spec.run(devices=8) as run:
        ctl, t0 = run.controller("work"), time.monotonic()
        while time.monotonic() - t0 < 25:
            if run.scenario("points").finished and ctl.devices == 2:
                break
            time.sleep(0.25)
        assert ctl.events.of("scale_up"), "burst should trigger a scale-up"
        assert ctl.events.of("scale_down"), "drain should trigger a scale-down"
    assert run.teardown_log[-1] == "service"
    assert run.service.pool.leased_devices == 0


def test_run_surfaces_sink_errors_at_teardown():
    from repro.pipeline import register_sink

    @register_sink("explode_sink")
    def explode_sink(msg):
        raise RuntimeError("sink blew up")

    spec = (Pipeline.named("sinkboom")
            .topic("in", partitions=1)
            .source("in", kind="vec8", rate_msgs_per_s=200, total_messages=8)
            .stage("s", topic="in", processor="count_msgs",
                   batch_interval=0.05, backpressure=False)
            .sink("bad", topic="in", fn="explode_sink")
            .build())
    with spec.run(devices=2) as run:
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline and run.sink("bad").error is None:
            time.sleep(0.05)
        assert run.sink("bad").error is not None
    assert any("sink blew up" in str(e) for e in run.errors)


def test_builder_rejects_undeclared_source_and_output_topics():
    # no silent topic auto-creation: a typo'd source topic must fail build()
    with pytest.raises(PipelineValidationError, match="unknown topic 'poinst'"):
        (Pipeline.named("typo").topic("points")
         .source("poinst", kind="vec8")
         .stage("s", topic="points", processor="count_msgs")
         .build())
    with pytest.raises(PipelineValidationError, match="unknown topic 'owt'"):
        (Pipeline.named("typo2").topic("a")
         .stage("s", topic="a", processor="count_msgs", emits=True,
                output_topic="owt")
         .build())


def test_run_keeps_every_source_on_a_shared_topic():
    spec = (Pipeline.named("twosrc")
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=100, total_messages=4, seed=1)
            .source("in", kind="vec8", rate_msgs_per_s=100, total_messages=4, seed=2)
            .stage("s", topic="in", processor="count_msgs",
                   batch_interval=0.05, backpressure=False)
            .build())
    with spec.run(devices=2) as run:
        assert run.source("in", 0) is not run.source("in", 1)
        run.await_batches("s", 1, timeout=20)


def test_snapshot_capture_scoped_to_one_stream():
    """A controller watching stage A must not see stage B's gauges."""
    from repro.elastic import MetricsBus

    bus = MetricsBus()
    bus.publish("stream.latency_p99", 0.45, stream="b")
    bus.publish("stream.latency_p99", 0.005, stream="a")
    bus.publish("stream.busy_frac", 0.9, stream="b")
    bus.publish("stream.lag", 500, stream="b")
    bus.publish("stream.lag", 2, stream="a")
    scoped = MetricsSnapshot.capture(bus, stream="a")
    assert scoped.latency_p99 == pytest.approx(0.005)
    assert scoped.busy_frac == 0.0
    assert scoped.lag == 2
    # unscoped capture aggregates every stream's lag
    assert MetricsSnapshot.capture(bus).lag == 502
    # a labeled probe wins for the matching stream only; stream b still
    # falls back to its own stream.lag gauge
    bus.publish("elastic.lag", 7, stream="a")
    assert MetricsSnapshot.capture(bus, stream="a").lag == 7
    assert MetricsSnapshot.capture(bus, stream="b").lag == 500
    # unscoped capture prefers any probe sample (newest across label sets)
    bus.publish("elastic.lag", 999)
    assert MetricsSnapshot.capture(bus).lag == 999


def test_builder_rejects_latency_policy_on_continuous_stage():
    with pytest.raises(PipelineValidationError, match="no latency quantiles"):
        (Pipeline.named("lc").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous")
         .elastic("s", policy="latency")
         .build())


def test_processor_with_defaulted_params_is_not_called_as_factory():
    @register_processor("defaulted_proc")
    def defaulted_proc(state, msgs=()):
        return (state or 0) + len(msgs)

    from repro.pipeline.registry import make_processor

    assert make_processor("defaulted_proc", {}) is defaulted_proc


def test_two_stages_on_one_topic_get_distinct_metric_labels():
    spec = (Pipeline.named("sharedtopic")
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=200, total_messages=16)
            .stage("a", topic="in", processor="count_msgs",
                   batch_interval=0.05, backpressure=False)
            .stage("b", topic="in", processor="count_msgs",
                   batch_interval=0.05, backpressure=False)
            .build())
    with spec.run(devices=2) as run:
        assert run.stream("a").metrics_label != run.stream("b").metrics_label
        run.await_batches("a", 1, timeout=20)
        run.await_batches("b", 1, timeout=20)
        # each stage's gauges live under its own label on the shared bus,
        # qualified by pipeline name so two runs sharing a bus never collide.
        # Poll: the engine publishes stream.lag *after* bumping the batch
        # counter await_batches watches, so the gauges can trail slightly.
        want = {"sharedtopic/in/a", "sharedtopic/in/b"}
        deadline = time.monotonic() + 10
        labels = set()
        while time.monotonic() < deadline and not want <= labels:
            labels = set(run.bus.latest_by_label("stream.lag", "stream"))
            time.sleep(0.05)
        assert want <= labels


def test_elastic_on_continuous_stage_has_a_working_lag_probe():
    from repro.pipeline import register_processor as _rp

    @_rp("win_count")
    def win_count(key, window, msgs):
        return len(msgs)

    spec = (Pipeline.named("contel")
            .topic("in", partitions=2)
            .source("in", kind="vec8", rate_msgs_per_s=100, total_messages=8)
            .stage("s", topic="in", processor="win_count",
                   engine="continuous", window={"window": "tumbling", "size": 0.2})
            .elastic("s", policy="threshold", high_lag=1e9, low_lag=0,
                     interval=0.1)
            .build())
    with spec.run(devices=2) as run:
        ctl = run.controller("s")
        ctl.step()  # must not raise: ContinuousStream.lag() exists now
        assert ctl._last_error is None
        assert run.lag("s") >= 0.0


# ---------------------------------------------------------------------------
# on_rescale constructor kwarg (both engines)
# ---------------------------------------------------------------------------


def test_on_rescale_constructor_kwarg_micro_batch():
    from repro.core import PilotComputeService

    svc = PilotComputeService(devices=[0, 1])
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("t", 1)
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1, "type": "spark"})
    seen = []
    stream = pilot.get_context().stream(
        cluster, "t", group="g", process_fn=lambda s, m: s,
        on_rescale=lambda devices: (seen.append(list(devices)), "state")[1],
    )
    stream.rescale([0, 1])
    assert seen == [[0, 1]] and stream.state == "state"
    stream.on_rescale = lambda devices: "reassigned"  # post-hoc still works
    stream.rescale([0])
    assert stream.state == "reassigned"
    svc.cancel()


def test_on_rescale_constructor_kwarg_continuous():
    from repro.core import PilotComputeService
    from repro.streaming.windows import TumblingWindow

    svc = PilotComputeService(devices=[0, 1])
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("t", 1)
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1, "type": "flink"})
    seen = []
    pilot.get_context().stream(
        cluster, "t", group="g", assigner=TumblingWindow(1.0),
        window_fn=lambda k, w, m: None, on_rescale=seen.append,
    )
    # extension pilots fire the hook through the plugin, like micro-batch
    from repro.core import PilotComputeDescription

    ext = svc.submit_pilot(PilotComputeDescription(
        number_of_nodes=1, cores_per_node=1, framework="flink", parent=pilot))
    assert len(seen) == 1 and len(seen[0]) == 2
    ext.cancel()
    assert len(seen) == 2 and len(seen[1]) == 1
    svc.cancel()


# ---------------------------------------------------------------------------
# LatencyPolicy
# ---------------------------------------------------------------------------


def _snap(p50=0.0, p99=0.0, lag=0.0, t=0.0):
    return MetricsSnapshot(
        t=t, lag=lag, records_per_sec=0.0, processing_delay=0.0,
        scheduling_delay=0.0, busy_frac=0.0, devices_total=8,
        devices_leased=2, utilization=0.25, pipeline_devices=2,
        latency_p50=p50, latency_p99=p99,
    )


def test_latency_policy_scales_up_when_p99_nears_batch_interval():
    p = LatencyPolicy(batch_interval=0.1, up_frac=0.8, up_stable=2)
    assert p.decide(_snap(p50=0.05, p99=0.09)).delta_devices == 0  # 1st obs
    d = p.decide(_snap(p50=0.05, p99=0.09))
    assert d.scale_up and d.delta_devices == 1
    # counter reset after acting
    assert p.decide(_snap(p50=0.05, p99=0.09)).delta_devices == 0


def test_latency_policy_scales_down_on_low_p50_and_drained_lag():
    p = LatencyPolicy(batch_interval=0.1, down_frac=0.3, down_stable=2,
                      max_lag_for_down=10)
    assert p.decide(_snap(p50=0.01, p99=0.02, lag=5)).delta_devices == 0
    d = p.decide(_snap(p50=0.01, p99=0.02, lag=5))
    assert d.scale_down
    # lag not drained -> no scale-down even with low latency
    p2 = LatencyPolicy(batch_interval=0.1, down_stable=1, max_lag_for_down=10)
    assert p2.decide(_snap(p50=0.01, p99=0.02, lag=500)).delta_devices == 0


def test_latency_policy_holds_between_bands_and_rejects_bad_interval():
    p = LatencyPolicy(batch_interval=0.1)
    for _ in range(5):
        assert p.decide(_snap(p50=0.05, p99=0.05)).delta_devices == 0
    with pytest.raises(ValueError):
        LatencyPolicy(batch_interval=0.0)


def test_latency_policy_selectable_from_spec_runner():
    """End-to-end: ElasticSpec(policy="latency") builds a LatencyPolicy with
    the stage's batch interval injected."""
    from repro.pipeline.registry import resolve_policy

    cls = resolve_policy("latency")
    assert cls is LatencyPolicy
    built = (Pipeline.named("l2").topic("a")
             .stage("s", topic="a", processor="count_msgs", batch_interval=0.25)
             .elastic("s", policy="latency", up_frac=0.9)
             .build())
    el = built.stage("s").elastic
    assert el.params == {"up_frac": 0.9}
    with built.run(devices=2) as run:
        ctl = run.controller("s")
        assert isinstance(ctl.policy, LatencyPolicy)
        assert ctl.policy.batch_interval == 0.25
        assert ctl.policy.up_frac == 0.9


# ---------------------------------------------------------------------------
# SLOPolicy (absolute-latency contract) + serving wiring
# ---------------------------------------------------------------------------


def test_slo_policy_absolute_threshold_with_hysteresis():
    from repro.elastic import SLOPolicy

    p = SLOPolicy(slo_p99=0.1, up_stable=2, down_stable=2)
    assert p.decide(_snap(p99=0.2)).delta_devices == 0  # 1st breach holds
    d = p.decide(_snap(p99=0.2))
    assert d.scale_up and d.delta_devices == 1
    assert p.decide(_snap(p99=0.05)).delta_devices == 0  # mid-band
    assert p.decide(_snap(p99=0.01)).delta_devices == 0
    assert p.decide(_snap(p99=0.01)).delta_devices == -1
    # no latency signal (0.0 = no samples yet) never scales down
    for _ in range(5):
        assert p.decide(_snap(p99=0.0)).delta_devices == 0
    # undrained lag blocks scale-down even under a quiet p99
    p2 = SLOPolicy(slo_p99=0.1, down_stable=1, max_lag_for_down=10)
    assert p2.decide(_snap(p99=0.01, lag=500)).delta_devices == 0
    with pytest.raises(ValueError):
        SLOPolicy(slo_p99=0.0)
    with pytest.raises(ValueError):
        SLOPolicy(slo_p99=0.1, up_margin=0.3, down_margin=0.5)


def test_slo_policy_selectable_from_spec_and_rejected_on_inline_continuous():
    from repro.elastic import SLOPolicy
    from repro.pipeline.registry import resolve_policy

    assert resolve_policy("slo") is SLOPolicy
    built = (Pipeline.named("slo1").topic("a")
             .stage("s", topic="a", processor="count_msgs")
             .elastic("s", policy="slo", slo_p99=0.25)
             .build())
    with built.run(devices=2) as run:
        ctl = run.controller("s")
        assert isinstance(ctl.policy, SLOPolicy)
        assert ctl.policy.slo_p99 == 0.25
    # inline continuous publishes no latency quantiles -> spec is invalid
    with pytest.raises(PipelineValidationError, match="no latency quantiles"):
        (Pipeline.named("slo2").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous")
         .elastic("s", policy="slo", slo_p99=0.25)
         .build())


def test_builder_validates_async_emit():
    # negative depth
    with pytest.raises(PipelineValidationError, match=">= 0"):
        (Pipeline.named("ae1").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous",
                async_emit=-1)
         .build())
    # only meaningful on the continuous engine
    with pytest.raises(PipelineValidationError, match="continuous engine"):
        (Pipeline.named("ae2").topic("a")
         .stage("s", topic="a", processor="count_msgs", async_emit=2)
         .build())
    # inline executor only (mp workers overlap across processes already)
    with pytest.raises(PipelineValidationError, match="inline"):
        (Pipeline.named("ae3").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous",
                executor="mp", async_emit=2)
         .build())
    # valid spec round-trips the field
    spec = (Pipeline.named("ae4").topic("a")
            .stage("s", topic="a", processor="count_msgs", engine="continuous",
                   async_emit=2)
            .build())
    assert spec.stage("s").async_emit == 2
    assert PipelineSpec.from_dict(spec.to_dict()) == spec


def test_builder_validates_preemptible():
    # needs a crash checkpoint to resume from
    with pytest.raises(PipelineValidationError, match="checkpoint_every"):
        (Pipeline.named("pe1").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous")
         .elastic("s", min_devices=0, preemptible=True, high_lag=10, low_lag=1)
         .build())
    # a nonzero floor means the stage is never driven to zero
    with pytest.raises(PipelineValidationError, match="min_devices == 0"):
        (Pipeline.named("pe2").topic("a")
         .stage("s", topic="a", processor="count_msgs", engine="continuous",
                checkpoint_every=10)
         .elastic("s", min_devices=1, preemptible=True, high_lag=10, low_lag=1)
         .build())
    # micro-batch stages have no crash-checkpoint spool at all
    with pytest.raises(PipelineValidationError, match="continuous"):
        (Pipeline.named("pe3").topic("a")
         .stage("s", topic="a", processor="count_msgs")
         .elastic("s", min_devices=0, preemptible=True, high_lag=10, low_lag=1)
         .build())
    # valid spec round-trips the flag (and old dicts default it off)
    spec = (Pipeline.named("pe4").topic("a")
            .stage("s", topic="a", processor="count_msgs", engine="continuous",
                   checkpoint_every=10)
            .elastic("s", min_devices=0, preemptible=True, high_lag=10, low_lag=1)
            .build())
    assert spec.stage("s").elastic.preemptible
    assert PipelineSpec.from_dict(spec.to_dict()) == spec
    d = spec.to_dict()
    del d["stages"][0]["elastic"]["preemptible"]
    assert not PipelineSpec.from_dict(d).stage("s").elastic.preemptible


def test_async_emit_reaches_the_continuous_stream():
    from repro.pipeline import register_processor as _rp

    @_rp("win_len_ae")
    def win_len_ae(key, window, msgs):
        return len(msgs)

    spec = (Pipeline.named("aerun")
            .topic("in", partitions=1)
            .source("in", kind="vec8", rate_msgs_per_s=200, total_messages=12)
            .stage("s", topic="in", processor="win_len_ae",
                   engine="continuous", window={"window": "tumbling", "size": 0.05},
                   async_emit=3)
            .build())
    with spec.run(devices=1) as run:
        stream = run.stream("s")
        assert stream.async_emit == 3 and stream._emit_window is not None
        stream.await_windows(1, timeout=20)


def test_runner_injects_metrics_bus_into_factories_that_take_it():
    from repro.pipeline.registry import make_processor, register_processor as _rp
    from repro.elastic import MetricsBus

    class _BusAware:
        def __init__(self, k=1, metrics=None):
            self.k, self.metrics = k, metrics

        def process(self, state, msgs):
            return state

    _rp("bus_aware_app", _BusAware)
    bus = MetricsBus()
    app = make_processor("bus_aware_app", {"k": 2}, metrics=bus)
    assert app.metrics is bus and app.k == 2
    # explicit option wins over injection
    app = make_processor("bus_aware_app", {"metrics": None}, metrics=bus)
    assert app.metrics is None
    # factories without the kwarg are untouched; plain fns stay plain
    assert make_processor("count_msgs", {}, metrics=bus)

"""Sequence-parallel WKV6/SSD == single-device chunked cores (8 devices)."""


def test_wkv6_sharded_matches_chunked(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.rwkv6 import wkv6_chunked
from repro.runtime.sharding import ShardingRules
from repro.runtime.sequence_parallel import wkv6_sharded

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind="train")
B, H, T, N = 2, 3, 64, 16
ks = jax.random.split(jax.random.key(0), 5)
r, k, v = (jax.random.normal(ks[i], (B, H, T, N)) for i in range(3))
w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, N)) - 1.0)
u = jax.random.normal(ks[4], (H, N)) * 0.1
S0 = jnp.zeros((B, H, N, N))
o_ref, s_ref = wkv6_chunked(r, k, v, w, u, S0, chunk=8)
with mesh:
    o, s = jax.jit(lambda *a: wkv6_sharded(*a, rules, chunk=8))(r, k, v, w, u)
np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-4)
np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-4)

# gradients flow (train path)
def loss(r, k, v, w):
    with mesh:
        o, _ = wkv6_sharded(r, k, v, w, u, rules, chunk=8)
    return jnp.sum(jnp.sin(o))
def loss_ref(r, k, v, w):
    o, _ = wkv6_chunked(r, k, v, w, u, S0, chunk=8)
    return jnp.sum(jnp.sin(o))
g = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(r, k, v, w)
g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(r, k, v, w)
for a, b in zip(g, g_ref):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)
print("WKV6 SHARDED OK")
""",
        n_devices=8,
    )


def test_ssd_sharded_matches_chunked(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.mamba2 import ssd_chunked
from repro.runtime.sharding import ShardingRules
from repro.runtime.sequence_parallel import ssd_sharded

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind="train")
Bt, T, H, P, N = 2, 64, 3, 8, 16
ks = jax.random.split(jax.random.key(1), 6)
x = jax.random.normal(ks[0], (Bt, T, H, P))
dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H)))
A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
Bm = jax.random.normal(ks[3], (Bt, T, 1, N))
Cm = jax.random.normal(ks[4], (Bt, T, 1, N))
D = jax.random.normal(ks[5], (H,)) * 0.1
S0 = jnp.zeros((Bt, H, P, N))
y_ref, s_ref = ssd_chunked(x, dt, A, Bm, Cm, D, S0, chunk=8)
with mesh:
    y, s = jax.jit(lambda *a: ssd_sharded(*a, rules, chunk=8))(x, dt, A, Bm, Cm, D)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-4)
print("SSD SHARDED OK")
""",
        n_devices=8,
    )

"""Property tests for the elastic/scheduling layer.

Two invariant families, checked over randomized inputs:

* **liveness** — any monotone ramp of a policy's driving signal (lag,
  latency, broker stall) that passes its watermark eventually produces a
  scale-up decision, for *every* ScalingPolicy. This is the generalized
  form of the watermark boundary bug fixed in the predictive-scheduling
  PR: a strict ``>`` up-leg passes threshold-crossing tests but fails
  exactly-at-watermark ramps.
* **fair-share safety** — ``weighted_fair_share`` never exceeds capacity
  (unless the floors alone already do — base pilots physically hold
  their floors) and never allocates below any request's floor, across
  random request books including infeasible ones (floors-sum > capacity).

Generation uses Hypothesis when it is installed; the same properties are
always also driven by a seeded ``random.Random`` sweep so the suite does
not silently thin out on machines without it.
"""
import random

import pytest

from repro.elastic import (
    BinPackingPolicy,
    BrokerSaturationPolicy,
    ForecastPolicy,
    LatencyPolicy,
    MetricsSnapshot,
    PIDScalingPolicy,
    SLOPolicy,
    ThresholdHysteresisPolicy,
)
from repro.scheduler import ResourceRequest, weighted_fair_share

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _snap(lag=0.0, p99=0.0, stall=0.0, rps=0.0, busy=1.0, t=0.0, devices=2,
          demands=None):
    return MetricsSnapshot(
        t=t, lag=lag, records_per_sec=rps, processing_delay=0.0,
        scheduling_delay=0.0, busy_frac=busy, devices_total=8,
        devices_leased=devices, utilization=devices / 8,
        pipeline_devices=devices, latency_p99=p99, broker_stall_frac=stall,
        stage_demands=demands or {},
    )


# every policy, with a snapshot maker that maps one scalar "load" ramp
# onto its driving signal; the watermark each ramp must pass is 100.0
POLICIES = {
    "threshold": (
        lambda: ThresholdHysteresisPolicy(high_lag=100.0, low_lag=1.0,
                                          up_stable=2),
        lambda v, i: _snap(lag=v),
    ),
    "pid": (
        # setpoint *below* the watermark: at lag=100 the proportional term
        # is kp * 50 / lag_per_device = 0.5, clear of the 0.25 deadband
        lambda: PIDScalingPolicy(target_lag=50.0, kp=1.0, ki=0.0, kd=0.0),
        lambda v, i: _snap(lag=v, t=float(i)),
    ),
    "latency": (
        lambda: LatencyPolicy(batch_interval=125.0, up_frac=0.8, up_stable=2),
        lambda v, i: _snap(p99=v),  # watermark = 0.8 * 125 = 100
    ),
    "slo": (
        lambda: SLOPolicy(slo_p99=100.0, up_margin=1.0, up_stable=2),
        lambda v, i: _snap(p99=v),
    ),
    "binpack": (
        # fixed stage demand, lag-proportional boost: a rising backlog
        # inflates packed demand past the incumbent device count
        lambda: BinPackingPolicy(device_records_per_sec=100.0,
                                 lag_norm=100.0),
        lambda v, i: _snap(lag=v, demands={"s": 150.0}),
    ),
    "broker_saturation": (
        lambda: BrokerSaturationPolicy(high_stall=100.0, up_stable=2),
        lambda v, i: _snap(stall=v),
    ),
    "forecast": (
        lambda: ForecastPolicy(min_observations=2, horizon=1.0,
                               target_lag=0.0),
        # a growing backlog with nonzero throughput: the model must infer
        # rising arrivals and ask for more than the 2 current devices
        lambda v, i: _snap(lag=v, rps=50.0, t=float(i)),
    ),
}


def _ramp_triggers_scale_up(name, ramp):
    """Drive ``ramp`` (monotone, ends >= watermark) through a fresh policy,
    then hold the final value; some decision along the way must scale up."""
    make_policy, make_snap = POLICIES[name]
    policy = make_policy()
    values = list(ramp) + [ramp[-1]] * 10  # hold: hysteresis may need
    for i, v in enumerate(values):         # up_stable consecutive samples
        if policy.decide(make_snap(float(v), i)).delta_devices > 0:
            return True
    return False


def _random_ramp(rng):
    """Monotone non-decreasing, crosses (or lands exactly on) 100."""
    n = rng.randint(1, 12)
    steps = sorted(rng.uniform(0.0, 99.9) for _ in range(n))
    peak = rng.choice([100.0, rng.uniform(100.0, 500.0)])
    return steps + [peak]


@pytest.mark.parametrize("name", sorted(POLICIES))
@pytest.mark.parametrize("seed", range(20))
def test_monotone_ramp_eventually_scales_up(name, seed):
    rng = random.Random(seed * 997 + hash(name) % 1000)
    ramp = _random_ramp(rng)
    assert _ramp_triggers_scale_up(name, ramp), \
        f"{name}: ramp {ramp} never triggered a scale-up"


def test_flat_at_watermark_ramp_scales_up_every_policy():
    """The exact boundary case the `>` vs `>=` bug hid: the signal climbs
    to the watermark and sits there, never exceeding it."""
    for name in POLICIES:
        assert _ramp_triggers_scale_up(name, [50.0, 100.0]), \
            f"{name}: flat-at-watermark ramp never scaled up"


# ---------------------------------------------------------------------------
# weighted_fair_share safety
# ---------------------------------------------------------------------------


def _random_book(rng):
    n = rng.randint(1, 8)
    reqs = []
    for i in range(n):
        lo = rng.randint(0, 4)
        hi = rng.choice([None, lo + rng.randint(0, 8)])
        reqs.append(ResourceRequest(
            f"r{i}", min_devices=lo, max_devices=hi,
            weight=rng.choice([0.5, 1.0, 2.0, 3.5]),
            priority=rng.randint(0, 2),
            target=rng.randint(0, 20),
        ))
    capacity = rng.randint(0, 30)
    return reqs, capacity


def _check_fair_share(reqs, capacity):
    alloc = weighted_fair_share(reqs, capacity)
    floors = sum(r.min_devices for r in reqs)
    assert set(alloc) == {r.name for r in reqs}
    for r in reqs:
        assert alloc[r.name] >= r.min_devices, \
            f"{r.name}: floor {r.min_devices} violated ({alloc[r.name]})"
        assert alloc[r.name] <= max(r.demand, r.min_devices), \
            f"{r.name}: granted {alloc[r.name]} above demand {r.demand}"
    assert sum(alloc.values()) <= max(capacity, floors), (
        f"allocated {sum(alloc.values())} of {capacity} "
        f"(floors {floors}): over-commit"
    )


@pytest.mark.parametrize("seed", range(200))
def test_fair_share_respects_capacity_and_floors(seed):
    reqs, capacity = _random_book(random.Random(seed))
    _check_fair_share(reqs, capacity)


def test_fair_share_infeasible_floors_grant_exactly_the_floors():
    """floors-sum > capacity: nothing beyond the floors is handed out
    (the base pilots already hold the floors; the surplus demand waits)."""
    reqs = [ResourceRequest("a", min_devices=5, target=10),
            ResourceRequest("b", min_devices=5, target=10, priority=1)]
    assert weighted_fair_share(reqs, 6) == {"a": 5, "b": 5}


if HAVE_HYPOTHESIS:
    ramp_strategy = st.lists(
        st.floats(min_value=0.0, max_value=99.9), min_size=0, max_size=12,
    ).map(sorted).flatmap(
        lambda steps: st.floats(min_value=100.0, max_value=500.0).map(
            lambda peak: steps + [peak])
    )

    @given(name=st.sampled_from(sorted(POLICIES)), ramp=ramp_strategy)
    @settings(max_examples=200, deadline=None)
    def test_monotone_ramp_scales_up_hypothesis(name, ramp):
        assert _ramp_triggers_scale_up(name, ramp)

    request_strategy = st.builds(
        lambda i, lo, extra, w, pr, tgt, unbounded: ResourceRequest(
            f"r{i}", min_devices=lo,
            max_devices=None if unbounded else lo + extra,
            weight=w, priority=pr, target=tgt),
        i=st.integers(0, 10**6), lo=st.integers(0, 4),
        extra=st.integers(0, 8), w=st.sampled_from([0.5, 1.0, 2.0, 3.5]),
        pr=st.integers(0, 2), tgt=st.integers(0, 20),
        unbounded=st.booleans(),
    )

    @given(reqs=st.lists(request_strategy, min_size=1, max_size=8,
                         unique_by=lambda r: r.name),
           capacity=st.integers(0, 30))
    @settings(max_examples=300, deadline=None)
    def test_fair_share_safety_hypothesis(reqs, capacity):
        _check_fair_share(reqs, capacity)

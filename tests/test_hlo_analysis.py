"""HLO analyzer: trip-count-corrected flops/collectives vs ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.hlo_analysis import analyze_hlo, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert shape_bytes("bf16[61,24,224,2048]") == 61 * 24 * 224 * 2048 * 2
    assert shape_bytes("(f32[2,3], s32[])") == 24 + 4
    assert shape_bytes("pred[4]") == 4
    assert shape_bytes("s32[]") == 4


def _compiled(L, unroll):
    def f(w, x):
        def layer(x, wi):
            return jnp.tanh(x @ wi), ()

        if unroll:
            for i in range(L):
                x, _ = layer(x, w[i])
        else:
            x, _ = jax.lax.scan(layer, x, w)
        return x.sum()

    return (
        jax.jit(jax.grad(f))
        .lower(
            jax.ShapeDtypeStruct((L, 128, 128), jnp.float32),
            jax.ShapeDtypeStruct((32, 128), jnp.float32),
        )
        .compile()
    )


def test_scan_trip_count_correction():
    L = 8
    scanned = analyze_hlo(_compiled(L, False).as_text())
    cost = _compiled(L, True).cost_analysis()
    if isinstance(cost, list):  # pinned jax returns one dict per device
        cost = cost[0]
    unrolled_truth = cost["flops"]
    analytic = 3 * L * 2 * 32 * 128 * 128  # fwd + 2x bwd matmuls
    assert scanned.while_trip_counts, "no while loops detected"
    assert all(t == L for t in scanned.while_trip_counts.values())
    # within 10% of both the analytic count and XLA's unrolled count
    assert abs(scanned.flops - analytic) / analytic < 0.10
    assert abs(scanned.flops - unrolled_truth) / unrolled_truth < 0.10


def test_scanned_flops_scale_with_depth():
    f4 = analyze_hlo(_compiled(4, False).as_text()).flops
    f8 = analyze_hlo(_compiled(8, False).as_text()).flops
    assert 1.8 < f8 / f4 < 2.2  # raw cost_analysis would report ~1.0


def test_collective_bytes_on_sharded_module(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.runtime.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((8,), ("model",))

def f(x, w):
    y = x @ w            # w col-sharded -> y col-sharded
    return y.sum()       # cross-shard reduction -> all-reduce

c = jax.jit(f, in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P(None, "model")))).lower(
    jax.ShapeDtypeStruct((64, 256), jnp.float32),
    jax.ShapeDtypeStruct((256, 512), jnp.float32),
).compile()
h = analyze_hlo(c.as_text())
print("COUNTS", h.collective_counts)
print("BYTES", h.collective_bytes)
""",
        n_devices=8,
    )
    assert "all-reduce" in out
    bytes_line = [l for l in out.splitlines() if l.startswith("BYTES")][0]
    assert float(bytes_line.split()[1]) >= 4.0  # at least the scalar partial sums

"""Broker semantics: logs, offsets, consumer groups, backpressure, serde."""
import threading
import time

import numpy as np
import pytest

# property tests need hypothesis (requirements-dev.txt); the plain unit
# tests below must keep running without it
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = settings = st = None

from repro.broker import (
    BackpressureError,
    BrokerCluster,
    Consumer,
    ConsumerGroup,
    PartitionLog,
    Producer,
    Record,
    decode_array,
    decode_msg,
    encode_array,
    encode_msg,
)


def test_partition_log_offsets_monotonic():
    log = PartitionLog("t", 0)
    offs = [log.append(Record(b"x" * 10)) for _ in range(100)]
    assert offs == list(range(100))
    assert log.high_watermark == 100
    recs = log.read(10, max_records=5)
    assert [r.offset for r in recs] == [10, 11, 12, 13, 14]


def test_partition_log_retention_trims_oldest():
    log = PartitionLog("t", 0, max_buffer_bytes=1000, retention_bytes=100)
    for _ in range(50):
        log.append(Record(b"x" * 10))
    assert log.earliest > 0
    assert log.buffered_bytes <= 100
    # reads below the earliest offset clamp forward
    recs = log.read(0, max_records=5)
    assert recs[0].offset == log.earliest


def test_backpressure_block_then_drain():
    log = PartitionLog("t", 0, max_buffer_bytes=100, backpressure="block")
    for _ in range(10):
        log.append(Record(b"x" * 10))
    done = []

    def producer():
        log.append(Record(b"y" * 10), timeout=5)
        done.append(1)

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.1)
    assert not done  # blocked
    log.ack(5)  # consumer frees space
    t.join(2)
    assert done
    assert log.stats.blocked_seconds > 0


def test_backpressure_error_policy():
    log = PartitionLog("t", 0, max_buffer_bytes=50, backpressure="error")
    for _ in range(5):
        log.append(Record(b"x" * 10))
    with pytest.raises(BackpressureError):
        log.append(Record(b"x" * 10))


def test_consumer_group_rebalance_covers_all_partitions():
    cluster = BrokerCluster(2)
    cluster.create_topic("t", 7)
    g = ConsumerGroup(cluster, "g", "t")
    c1 = Consumer(cluster, g, "a")
    c2 = Consumer(cluster, g, "b")
    c3 = Consumer(cluster, g, "c")
    parts = c1.assignment + c2.assignment + c3.assignment
    assert sorted(parts) == list(range(7))  # partition of the partitions
    c2.close()
    parts = c1.assignment + c3.assignment
    assert sorted(parts) == list(range(7))


def test_commit_and_rewind_exactly_once_semantics():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 2)
    prod = Producer(cluster, "t", serializer="raw")
    for i in range(20):
        prod.send(bytes([i]))
    g = ConsumerGroup(cluster, "g", "t")
    c = Consumer(cluster, g, "m", deserialize=False)
    first = c.poll(10)
    c.commit()
    second = c.poll(10)
    # crash before committing the second poll -> rewind replays it
    c.rewind_to_committed()
    replay = c.poll(10)
    assert [m.value for m in replay] == [m.value for m in second]


def test_elastic_node_add_remove_and_failure():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 4)
    n0 = cluster.n_nodes
    nid = cluster.add_node()
    assert cluster.n_nodes == n0 + 1
    cluster.fail_node(nid)
    assert cluster.n_nodes == n0
    # data still reachable after failover
    prod = Producer(cluster, "t", serializer="raw")
    assert prod.send(b"alive") >= 0


def test_replicated_topic_places_replicas_on_distinct_nodes():
    cluster = BrokerCluster(3)
    t = cluster.create_topic("t", 4, replication_factor=2)
    for p in range(4):
        holders = t.holders(p)
        assert len(holders) == 2 == len(set(holders))
        assert holders[0] == t.leaders[p]
    # the list-of-logs view resolves to the leader copies
    assert [log.partition for log in t.partitions] == [0, 1, 2, 3]


def test_fail_node_promotes_follower_without_acked_loss():
    cluster = BrokerCluster(3)
    cluster.create_topic("t", 2, replication_factor=2)
    prod = Producer(cluster, "t", serializer="raw")
    for _ in range(40):
        prod.send(b"v")  # round-robins both partitions
    dead = cluster.topic("t").leaders[0]
    cluster.fail_node(dead)
    assert cluster.failovers >= 1
    assert cluster.lost_records == 0
    # every partition still serves its whole log from a promoted leader
    total = sum(len(cluster.read("t", p, 0, 1000)) for p in range(2))
    assert total == 40
    # and the rebalance restored the replication factor on the survivors
    t = cluster.topic("t")
    for p in range(2):
        assert len(t.replicas[p]) == 2
        assert dead not in t.replicas[p]
        follower = [n for n in t.replicas[p] if n != t.leaders[p]][0]
        assert (t.replicas[p][follower].high_watermark
                == t.leader_log(p).high_watermark)


def test_fail_node_unreplicated_loses_records_but_offsets_stay_monotonic():
    cluster = BrokerCluster(2)
    cluster.create_topic("t", 1, replication_factor=1)
    prod = Producer(cluster, "t", serializer="raw")
    for _ in range(30):
        prod.send(b"x")
    cluster.fail_node(cluster.topic("t").leaders[0])
    assert cluster.lost_records == 30
    # the partition restarts empty at the old high watermark: the next send
    # continues the offset sequence instead of reusing burned offsets
    assert prod.send(b"y") == 30
    recs = cluster.read("t", 0, 0, 100)
    assert [r.offset for r in recs] == [30]


def test_consumer_group_generation_bumps_after_node_loss():
    cluster = BrokerCluster(2)
    cluster.create_topic("t", 2, replication_factor=2)
    g = ConsumerGroup(cluster, "g", "t")
    c = Consumer(cluster, g, "m")
    assert c.assignment == [0, 1]
    gen = g.generation
    cluster.fail_node(cluster.topic("t").leaders[0])
    assert g.generation > gen  # members re-sync on their next poll
    assert c.assignment == [0, 1]


def test_committed_offsets_survive_failover():
    cluster = BrokerCluster(2)
    cluster.create_topic("t", 1, replication_factor=2)
    prod = Producer(cluster, "t", serializer="raw")
    for i in range(20):
        prod.send(bytes([i % 3]))
    g = ConsumerGroup(cluster, "g", "t")
    c = Consumer(cluster, g, "m", deserialize=False)
    first = c.poll(10)
    assert len(first) == 10
    c.commit()
    cluster.fail_node(cluster.topic("t").leaders[0])
    assert cluster.committed("g", "t", 0) == 10
    # a restarted member resumes exactly at the commit on the new leader
    c2 = Consumer(cluster, ConsumerGroup(cluster, "g2", "t"), "m2", deserialize=False)
    c2.seek(0, cluster.committed("g", "t", 0))
    replay = c2.poll(100)
    assert [m.offset for m in replay] == list(range(10, 20))


if st is not None:

    @given(st.lists(st.integers(0, 255), min_size=0, max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_msg_serde_roundtrip(xs):
        data = {"xs": bytes(xs), "n": len(xs)}
        assert decode_msg(encode_msg(data)) == data
        assert decode_msg(encode_msg(data, compress=True)) == data

    @given(
        st.integers(1, 50),
        st.integers(1, 8),
        st.sampled_from([np.float32, np.float64, np.int32, np.uint8]),
        st.booleans(),
    )
    @settings(max_examples=50, deadline=None)
    def test_array_serde_roundtrip(n, d, dtype, compress):
        arr = (np.random.default_rng(0).normal(size=(n, d)) * 100).astype(dtype)
        out = decode_array(encode_array(arr, compress=compress))
        np.testing.assert_array_equal(arr, out)
        assert out.dtype == dtype

    @given(st.lists(st.binary(min_size=1, max_size=32), min_size=1, max_size=64),
           st.integers(1, 5))
    @settings(max_examples=30, deadline=None)
    def test_keyed_routing_is_stable(keys, n_parts):
        """Records with equal keys always land in the same partition."""
        cluster = BrokerCluster(1)
        cluster.create_topic("t", n_parts)
        prod = Producer(cluster, "t", serializer="raw")
        placement = {}
        for k in keys:
            prod.send(b"v", key=k)
        for p in range(n_parts):
            for r in cluster.topic("t").partitions[p].read(0, 1000):
                assert placement.setdefault(r.key, p) == p

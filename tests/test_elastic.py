"""Elastic autoscaling: MetricsBus, policies, and the closed reconcile loop.

The scenario test reproduces the paper's dynamic-resourcing experiment
(Fig. 8) in miniature: a MASS rate step overloads the base pilot, the
ElasticController grows it with an extension pilot, lag drains, the rate
drops, and the controller shrinks back — all asserted from MetricsBus
history and the controller's event log.
"""
import time

import numpy as np
import pytest

from repro.core import PilotComputeDescription, PilotComputeService
from repro.elastic import (
    BinPackingPolicy,
    BrokerSaturationPolicy,
    ElasticConfig,
    ElasticController,
    ForecastPolicy,
    LatencyPolicy,
    MetricsBus,
    MetricsSnapshot,
    PIDScalingPolicy,
    SLOPolicy,
    ThresholdHysteresisPolicy,
    first_fit_decreasing,
    timeline,
)
from repro.miniapps import RateStepScenario, SourceConfig, StreamSource


# ---------------------------------------------------------------------------
# metrics bus
# ---------------------------------------------------------------------------


def test_metrics_bus_latest_sum_and_history():
    bus = MetricsBus()
    bus.publish("stream.lag", 10, stream="a")
    bus.publish("stream.lag", 5, stream="b")
    bus.publish("stream.lag", 20, stream="a")
    assert bus.value("stream.lag", stream="a") == 20
    assert bus.sum_latest("stream.lag") == 25
    assert bus.latest("stream.lag").value == 20  # newest across label sets
    assert [s.value for s in bus.history("stream.lag")] == [10, 5, 20]
    assert bus.latest_by_label("stream.lag", "stream") == {"a": 20.0, "b": 5.0}


def test_metrics_bus_subscribe_and_rate():
    bus = MetricsBus()
    seen = []
    unsub = bus.subscribe(seen.append)
    bus.publish("c", 0, t=0.0)
    bus.publish("c", 50, t=5.0)
    assert bus.rate("c", window=10.0) == pytest.approx(10.0)
    unsub()
    bus.publish("c", 60, t=6.0)
    assert len(seen) == 2


def test_metrics_bus_survives_raising_subscriber():
    bus = MetricsBus()

    def broken(sample):
        raise RuntimeError("observer crashed")

    bus.subscribe(broken)
    s = bus.publish("x", 1.0)  # must not propagate into the publisher thread
    assert s.value == 1.0 and bus.value("x") == 1.0


def test_snapshot_capture_prefers_probe_lag_and_reads_pool():
    svc = PilotComputeService(devices=list(range(4)))
    bus = MetricsBus()
    bus.publish("stream.lag", 100, stream="a")
    bus.publish("elastic.lag", 42)
    bus.publish("stream.busy_frac", 0.8, stream="a")
    bus.publish("stream.records_per_sec", 120.0, stream="a")
    snap = MetricsSnapshot.capture(bus, svc.pool)
    assert snap.lag == 42  # probe wins over stream gauges
    assert snap.busy_frac == 0.8
    assert snap.devices_total == 4 and snap.devices_leased == 0
    assert snap.stage_demands == {"a": 120.0}
    svc.cancel()


# ---------------------------------------------------------------------------
# device pool (autoscaler churn safety)
# ---------------------------------------------------------------------------


def test_device_pool_release_is_idempotent():
    from repro.core import DevicePool

    pool = DevicePool(devices=list(range(6)))
    lease = pool.acquire(4, 1)
    assert pool.free_devices == 2 and pool.leased_devices == 4
    assert pool.utilization == pytest.approx(4 / 6)
    saved = list(lease.devices)
    pool.release(lease)
    assert pool.free_devices == 6 and pool.leased_devices == 0
    lease.devices = saved  # simulate a double release of the same devices
    pool.release(lease)
    assert pool.free_devices == 6  # not duplicated into the free list


# ---------------------------------------------------------------------------
# policies (pure decide() — no threads)
# ---------------------------------------------------------------------------


def _snap(lag, busy=0.0, leased=2, demands=None, t=0.0, pipeline=None,
          p50=0.0, p99=0.0, stall=0.0, migration_ms=0.0, rps=None):
    return MetricsSnapshot(
        t=t, lag=lag,
        records_per_sec=sum((demands or {}).values()) if rps is None else rps,
        processing_delay=0.0, scheduling_delay=0.0, busy_frac=busy,
        devices_total=8, devices_leased=leased, utilization=leased / 8,
        pipeline_devices=leased if pipeline is None else pipeline,
        stage_demands=demands or {},
        latency_p50=p50, latency_p99=p99, broker_stall_frac=stall,
        state_migration_ms=migration_ms, state_migration_t=t,
    )


def test_all_hysteresis_policies_act_on_the_exact_watermark():
    """Boundary bug (predictive-scheduling PR): ThresholdHysteresisPolicy
    used a strict ``>`` on the up-leg while Latency/SLO/BrokerSaturation
    used ``>=`` — and the in-band ``else`` zeroes BOTH counters, so a
    signal sitting *exactly* on the watermark never accumulated toward
    ``up_stable`` there. Every policy's up-leg must be inclusive: two
    flat-at-watermark observations scale up."""
    cases = [
        (ThresholdHysteresisPolicy(high_lag=100, low_lag=10, up_stable=2),
         lambda: _snap(lag=100.0)),
        (LatencyPolicy(batch_interval=1.0, up_frac=0.8, up_stable=2),
         lambda: _snap(lag=0.0, p99=0.8)),  # p99 == up_frac * interval
        (SLOPolicy(slo_p99=0.5, up_margin=1.0, up_stable=2),
         lambda: _snap(lag=0.0, p99=0.5)),  # p99 == up_margin * slo
        (BrokerSaturationPolicy(high_stall=0.3, up_stable=2),
         lambda: _snap(lag=0.0, stall=0.3)),  # stall == high watermark
    ]
    for policy, make in cases:
        name = type(policy).__name__
        first = policy.decide(make())
        assert first.delta_devices == 0, f"{name}: acted before up_stable"
        second = policy.decide(make())
        assert second.delta_devices > 0, \
            f"{name}: flat-at-watermark signal never scaled up"


def test_threshold_policy_hysteresis_and_busy_guard():
    p = ThresholdHysteresisPolicy(high_lag=100, low_lag=10, up_stable=2, down_stable=2)
    assert p.decide(_snap(150)).delta_devices == 0  # first observation
    assert p.decide(_snap(150)).delta_devices > 0  # stable -> act
    assert p.decide(_snap(150)).delta_devices == 0  # counter reset after acting
    # mid-band resets both counters
    p.decide(_snap(150))
    assert p.decide(_snap(50)).delta_devices == 0
    assert p.decide(_snap(150)).delta_devices == 0  # not consecutive anymore
    # low lag but still busy: the guard blocks scale-down
    for _ in range(5):
        assert p.decide(_snap(2, busy=0.9)).delta_devices == 0
    assert p.decide(_snap(2, busy=0.1)).delta_devices == 0
    assert p.decide(_snap(2, busy=0.1)).delta_devices < 0


def test_pid_policy_sign_and_deadband():
    p = PIDScalingPolicy(target_lag=50, lag_per_device=100.0)
    assert p.decide(_snap(500, t=0.0)).delta_devices == 0  # first-update init
    assert p.decide(_snap(500, t=1.0)).delta_devices > 0  # far above target
    p2 = PIDScalingPolicy(target_lag=50, lag_per_device=100.0)
    p2.decide(_snap(50, t=0.0))
    assert p2.decide(_snap(55, t=1.0)).delta_devices == 0  # inside deadband
    p3 = PIDScalingPolicy(target_lag=500, lag_per_device=100.0)
    p3.decide(_snap(0, t=0.0))
    assert p3.decide(_snap(0, t=1.0, busy=0.1)).delta_devices < 0
    # saturated pipeline never shrinks even when lag is below target
    p4 = PIDScalingPolicy(target_lag=500, lag_per_device=100.0)
    p4.decide(_snap(0, t=0.0))
    assert p4.decide(_snap(0, t=1.0, busy=0.9)).delta_devices == 0


def test_first_fit_decreasing_and_binpacking_policy():
    bins = first_fit_decreasing({"a": 90, "b": 60, "c": 40, "d": 10}, 100)
    assert sorted(map(sorted, bins)) == [["a", "d"], ["b", "c"]]
    with pytest.raises(ValueError):
        first_fit_decreasing({"a": 1}, 0)

    p = BinPackingPolicy(device_records_per_sec=100, headroom=0.0, lag_weight=0.0)
    # 150 + 60 rec/s at 100/device -> 3 devices (oversized stage keeps a
    # dedicated pair of devices)
    snap = _snap(0, leased=2, demands={"big": 150, "small": 60})
    assert p.desired_devices(snap) == 3
    assert p.decide(snap).delta_devices == 1
    # backlog inflates demand -> extra catch-up devices
    p_lag = BinPackingPolicy(device_records_per_sec=100, headroom=0.0,
                             lag_weight=1.0, lag_norm=100.0)
    assert p_lag.desired_devices(_snap(100, leased=2, demands={"big": 150, "small": 60})) > 3
    # matched demand -> hold
    assert p.decide(_snap(0, leased=3, demands={"big": 150, "small": 60})).delta_devices == 0
    # sized against the pipeline, not pool-wide leases: an unrelated pilot
    # holding 3 extra devices must not suppress this pipeline's grow
    skewed = _snap(0, leased=6, pipeline=2, demands={"big": 150, "small": 60})
    assert p.decide(skewed).delta_devices == 1


# ---------------------------------------------------------------------------
# forecast policy (predictive, cost-aware)
# ---------------------------------------------------------------------------


def _feed_saturated(policy, *, devices, rps, lag=10.0, ticks=6):
    """Drive the RLS with capacity-limited snapshots (lag > 0) at a fixed
    operating point so mu converges to rps / devices."""
    last = None
    for i in range(ticks):
        last = policy.decide(_snap(lag, t=float(i), pipeline=devices,
                                   leased=devices, rps=rps))
    return last


def test_forecast_policy_holds_until_min_observations():
    p = ForecastPolicy(min_observations=3)
    assert p.decide(_snap(500.0, t=0.0, pipeline=1, rps=10.0)).delta_devices == 0
    assert p.decide(_snap(500.0, t=1.0, pipeline=1, rps=10.0)).delta_devices == 0
    # third snapshot: model is trusted, the huge backlog forces a grow
    d = p.decide(_snap(500.0, t=2.0, pipeline=1, rps=10.0))
    assert d.absolute and d.delta_devices > 0


def test_forecast_policy_learns_service_rate_and_sizes_from_arrivals():
    p = ForecastPolicy(horizon=5.0, headroom=0.0, min_observations=2)
    # 2 devices pushing 100 rec/s while backlogged -> mu ~= 50 rec/s/dev
    _feed_saturated(p, devices=2, rps=100.0)
    assert p.service_rate == pytest.approx(50.0, rel=0.05)
    # constant lag + 100 rec/s throughput -> arrivals ~= 100 rec/s
    assert p.arrival_rate == pytest.approx(100.0, rel=0.05)
    # arrivals step to ~200 rec/s (lag growing 100/tick on top of the
    # 100 rec/s the 2 devices still push): forecast asks for ~4 devices
    # *before* the backlog is large — sized from the predicted inflow
    last = None
    for i in range(6, 14):
        lag = 10.0 + (i - 5) * 100.0
        last = p.decide(_snap(lag, t=float(i), pipeline=2, leased=2, rps=100.0))
    assert last.absolute
    want = 2 + last.delta_devices
    assert want >= 4
    # and the forecast itself is consistent: at `want` devices the
    # predicted lag is near target, at the current size it keeps growing
    snap = _snap(810.0, t=14.0, pipeline=2, rps=100.0)
    assert p.predicted_lag(snap, want) < p.predicted_lag(snap, 2)


def test_forecast_policy_migration_gate_blocks_marginal_rescale():
    # converge the model first (no migration cost published yet)
    p = ForecastPolicy(horizon=1.0, headroom=0.0, min_observations=2,
                       migration_gain_ratio=1.0)
    _feed_saturated(p, devices=2, rps=100.0)
    # a 1-device grow gains mu*1*horizon = 50 records over the horizon;
    # a 10 s migration pause piles up arrival*10 = 1000 records -> hold
    snap = _snap(60.0, t=20.0, pipeline=2, rps=100.0, migration_ms=10_000.0)
    held = p.decide(snap)
    assert held.delta_devices == 0
    assert "migration gate" in held.reason
    # same snapshot without the cost is released
    p2 = ForecastPolicy(horizon=1.0, headroom=0.0, min_observations=2,
                        migration_gain_ratio=1.0)
    _feed_saturated(p2, devices=2, rps=100.0)
    free = p2.decide(_snap(60.0, t=20.0, pipeline=2, rps=100.0))
    assert free.delta_devices != 0
    # gain scales with |delta|: a big enough backlog buys its way through
    # the same gate because the rescale adds many devices at once
    p3 = ForecastPolicy(horizon=1.0, headroom=0.0, min_observations=2,
                        migration_gain_ratio=1.0)
    _feed_saturated(p3, devices=2, rps=100.0)
    big = p3.decide(_snap(5000.0, t=20.0, pipeline=2, rps=100.0,
                          migration_ms=1_000.0))
    assert big.delta_devices > 0


def test_forecast_policy_releases_idle_devices():
    p = ForecastPolicy(horizon=5.0, min_observations=2)
    _feed_saturated(p, devices=4, rps=200.0)
    # arrivals die off: zero lag, zero throughput -> shrink toward 1
    last = None
    for i in range(6, 12):
        last = p.decide(_snap(0.0, t=float(i), pipeline=4, leased=4, rps=0.0))
    assert last.absolute and 4 + last.delta_devices == 1


def test_forecast_policy_ignores_idle_samples_for_mu():
    p = ForecastPolicy(min_observations=1)
    # idle trickle: lag == 0, busy far below saturation -> RLS must not
    # learn mu ~= 1 rec/s/dev from it
    for i in range(5):
        p.decide(_snap(0.0, t=float(i), busy=0.1, pipeline=4, rps=4.0))
    assert p.service_rate == p.min_mu  # still the floor, not 1.0


def test_forecast_policy_validates_params():
    with pytest.raises(ValueError):
        ForecastPolicy(horizon=0.0)
    with pytest.raises(ValueError):
        ForecastPolicy(forgetting=0.0)
    with pytest.raises(ValueError):
        ForecastPolicy(arrival_alpha=1.5)


# ---------------------------------------------------------------------------
# controller (deterministic, step-driven)
# ---------------------------------------------------------------------------


def test_controller_grows_and_shrinks_extension_pilots():
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    base = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
    lags = iter([500, 500, 0, 0, 0, 0])
    ctl = ElasticController(
        svc, base, bus,
        ThresholdHysteresisPolicy(high_lag=100, low_lag=10, up_stable=2, down_stable=2),
        config=ElasticConfig(min_devices=2, max_devices=6, devices_per_step=2, cooldown=0.0),
        lag_probe=lambda: next(lags),
    )
    assert ctl.devices == 2
    ctl.step()
    up = ctl.step()
    assert up.delta_devices == 2 and ctl.devices == 4
    assert len(base.children) == 1 and svc.pool.leased_devices == 4
    ctl.step()
    down = ctl.step()
    assert down.delta_devices == -2 and ctl.devices == 2
    assert base.children == [] and svc.pool.leased_devices == 2
    # min_devices floor: further scale-down decisions are no-ops
    ctl.step()
    ctl.step()
    assert ctl.devices == 2 and not ctl.events.of("scale_down")[1:]
    ups, downs = ctl.events.of("scale_up"), ctl.events.of("scale_down")
    assert [e.devices_after for e in ups] == [4]
    assert [e.devices_after for e in downs] == [2]
    assert bus.series("elastic.devices")[-1][1] == 2
    svc.cancel()


def test_controller_defers_rescale_while_migration_cost_amortizes():
    """With ``migration_cost_frac`` set, an expensive recent state
    migration holds the controller (publishing ``elastic.rescale_deferred``)
    until cost / elapsed drops below the configured fraction."""
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    base = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
    ctl = ElasticController(
        svc, base, bus,
        ThresholdHysteresisPolicy(high_lag=100, low_lag=10, up_stable=1),
        config=ElasticConfig(cooldown=0.0, interval=0.5, migration_cost_frac=0.5),
        lag_probe=lambda: 1000.0,
    )
    # a 2s migration just happened: amortization window = 2.0 / 0.5 = 4s
    bus.publish("state.migration_ms", 2000.0)
    held = ctl.step()
    assert held.delta_devices == 0 and ctl.devices == 2
    assert bus.value("elastic.rescale_deferred") == 1.0
    # same cost, but long enough ago that it has amortized: scaling resumes
    bus.publish("state.migration_ms", 2000.0, t=time.monotonic() - 10.0)
    up = ctl.step()
    assert up.delta_devices > 0 and ctl.devices > 2
    # cheap migrations (cost <= frac * interval) never defer
    bus.publish("state.migration_ms", 50.0)
    snap = MetricsSnapshot.capture(bus, svc.pool)
    assert ctl._migration_deferred(time.monotonic(), snap) is False
    svc.cancel()


def test_migration_deferral_reads_the_snapshot_not_the_bus():
    """Regression (predictive-scheduling PR): ``_migration_deferred`` used
    to re-read ``bus.latest("state.migration_ms")`` instead of the snapshot
    captured two lines earlier — so it could see a *newer* sample than the
    one the policy decided on, and (without a stream label) another
    stage's sample entirely. The gate must consume the snapshot's
    stream-filtered ``state_migration_ms``/``state_migration_t``."""
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    base = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
    ctl = ElasticController(
        svc, base, bus,
        ThresholdHysteresisPolicy(high_lag=100, low_lag=10, up_stable=1),
        config=ElasticConfig(cooldown=0.0, interval=0.5, migration_cost_frac=0.5),
        lag_probe=lambda: 1000.0,
        stream="mine",
    )
    # an expensive migration on ANOTHER stream must not defer this
    # controller: its snapshot filters to stream="mine", where no
    # migration ever ran
    bus.publish("state.migration_ms", 5000.0, stream="other")
    up = ctl.step()
    assert up.delta_devices > 0, \
        "another stream's migration cost deferred this controller"
    assert bus.latest("elastic.rescale_deferred", stream="mine") is None
    # and the snapshot view is what gates: a fresh expensive migration on
    # OUR stream defers, even though the bus's newest unlabeled read would
    # have been the other stream's
    bus.publish("state.migration_ms", 2000.0, stream="mine")
    held = ctl.step()  # cooldown=0, so only the deferral can hold this
    assert held.delta_devices == 0
    assert bus.value("elastic.rescale_deferred", stream="mine") == 1.0
    svc.cancel()


def test_controller_rejects_scale_up_without_headroom():
    svc = PilotComputeService(devices=list(range(2)))
    bus = MetricsBus()
    base = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
    ctl = ElasticController(
        svc, base, bus,
        ThresholdHysteresisPolicy(high_lag=10, low_lag=1, up_stable=1),
        config=ElasticConfig(cooldown=0.0, devices_per_step=2),
        lag_probe=lambda: 1000.0,
    )
    ctl.step()
    assert ctl.devices == 2
    assert ctl.events.of("rejected")
    svc.cancel()


def test_controller_treats_policy_delta_as_absolute_devices():
    """BinPackingPolicy returns absolute device deltas; the controller must
    round to lease granularity, not multiply (which would oscillate)."""
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    base = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "spark"})
    policy = BinPackingPolicy(device_records_per_sec=100, headroom=0.0, lag_weight=0.0)
    ctl = ElasticController(svc, base, bus, policy,
                            config=ElasticConfig(min_devices=2, max_devices=8,
                                                 devices_per_step=2, cooldown=0.0))
    bus.publish("stream.records_per_sec", 350.0, stream="s")  # FFD wants 4
    ctl.step()
    assert ctl.devices == 4  # +2 devices exactly, not 2*devices_per_step
    ctl.step()
    assert ctl.devices == 4  # converged: no grow/shrink oscillation
    bus.publish("stream.records_per_sec", 150.0, stream="s")  # FFD wants 2
    ctl.step()
    assert ctl.devices == 2
    # odd target between lease multiples (FFD wants 3, leases come in 2s):
    # grow rounds up once, then the -1 surplus rounds DOWN to 0 -> stable
    bus.publish("stream.records_per_sec", 250.0, stream="s")
    ctl.step()
    assert ctl.devices == 4
    for _ in range(3):
        ctl.step()
        assert ctl.devices == 4, "odd absolute target must hold, not flap"
    svc.cancel()


def test_idle_stream_zeroes_throughput_gauge():
    """Starved stream must publish 0 records/sec — a latched burst-time
    value would pin demand-driven policies at the burst size forever."""
    svc = PilotComputeService(devices=list(range(2)))
    bus = MetricsBus()
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("idle", 1)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    stream = ctx.stream(cluster, "idle", group="g", process_fn=lambda s, m: s,
                        batch_interval=0.05, backpressure=False, metrics=bus)
    stream.start()
    from repro.broker import Producer

    prod = Producer(cluster, "idle", serializer="npy")
    for i in range(4):
        prod.send(np.array([float(i)]))
    stream.await_batches(1, timeout=10)
    deadline = time.monotonic() + 5
    while bus.value("stream.records_per_sec", -1.0, stream="idle") != 0.0 \
            and time.monotonic() < deadline:
        time.sleep(0.05)
    assert bus.value("stream.records_per_sec", -1.0, stream="idle") == 0.0
    stream.stop()
    svc.cancel()


def test_source_rate_zero_pauses_instead_of_flooding():
    svc = PilotComputeService(devices=[0])
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("p", 1)
    src = _TinySource(cluster, SourceConfig("p", rate_msgs_per_s=200))
    src.start()
    time.sleep(0.3)
    src.set_rate(0)
    time.sleep(0.05)  # drain the in-flight send
    paused_at = src.sent_records
    time.sleep(0.4)
    assert src.sent_records <= paused_at + 1, "rate 0 must pause, not unthrottle"
    src.set_rate(100)
    time.sleep(0.5)
    assert src.sent_records > paused_at + 5, "source did not resume after pause"
    src.stop()
    svc.cancel()


def test_timeline_export_is_json_serializable():
    import json

    bus = MetricsBus()
    bus.publish("elastic.devices", 2, t=10.0)
    bus.publish("elastic.devices", 4, t=11.0)
    bus.publish("stream.lag", 7, t=10.5, stream="t")
    from repro.elastic import ScalingEvent

    tl = timeline(bus, [ScalingEvent(11.0, "scale_up", 2, 2, 4, "test")])
    blob = json.loads(json.dumps(tl))
    assert blob["series"]["elastic.devices"] == [[0.0, 2.0], [1.0, 4.0]]
    assert blob["events"][0]["action"] == "scale_up"
    assert blob["events"][0]["t"] == 1.0


# ---------------------------------------------------------------------------
# the closed loop (acceptance scenario)
# ---------------------------------------------------------------------------


class _TinySource(StreamSource):
    def make_message(self, rng, i):
        return np.array([float(i)])


def _build_pipeline(svc, bus, *, per_msg=0.01, base_devices=2):
    """Broker + micro-batch pilot whose throughput scales with its device
    count: processing one batch costs ``len(msgs) * per_msg / n_devices``
    seconds, and ``on_rescale`` re-reads the device count — the same
    data-parallel re-sharding contract real MASA apps implement."""
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("points", 4)
    engine = svc.submit_pilot(
        {"number_of_nodes": 1, "cores_per_node": base_devices, "type": "spark"})
    ctx = engine.get_context()
    capacity = {"n": base_devices}

    def process(state, msgs):
        time.sleep(len(msgs) * per_msg / max(capacity["n"], 1))
        return (state or 0) + len(msgs)

    stream = ctx.stream(cluster, "points", group="g", process_fn=process,
                        batch_interval=0.05, max_batch_records=32,
                        backpressure=False, metrics=bus)

    def on_rescale(devices):
        capacity["n"] = max(len(devices), 1)
        return stream.state

    stream.on_rescale = on_rescale
    return cluster, engine, stream


def _run_rate_step(policy, steps, *, config, phase_timeout=25.0):
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    cluster, engine, stream = _build_pipeline(svc, bus)
    src = _TinySource(cluster, SourceConfig("points", rate_msgs_per_s=steps[0][1]))
    ctl = ElasticController(svc, engine, bus, policy, config=config,
                            lag_probe=lambda: sum(stream.lag().values()))
    scenario = RateStepScenario(src, steps)
    stream.start()
    src.start()
    ctl.start()
    scenario.start()
    try:
        # each phase gets its own budget so a slow (loaded) earlier phase
        # cannot starve the later assertions
        deadline = time.monotonic() + phase_timeout
        # phase 1: the rate step must provoke an extension pilot
        while not ctl.events.of("scale_up") and time.monotonic() < deadline:
            time.sleep(0.05)
        assert ctl.events.of("scale_up"), (
            f"no scale-up; lag tail={bus.series('elastic.lag')[-8:]}")
        # phase 2: with the extension in place, lag must drain back under the
        # scale-up threshold (a standing in-flight backlog of ~rate*cycle
        # remains while the high rate lasts, so "recovered" = below high water)
        deadline = time.monotonic() + phase_timeout
        while sum(stream.lag().values()) >= 80 and time.monotonic() < deadline:
            time.sleep(0.1)
        assert sum(stream.lag().values()) < 80, "lag never recovered after scale-up"
        # phase 3: wait for the schedule to actually apply its final low-rate
        # step (an early transient shrink mid-burst would otherwise let us
        # read the timeline before the rate ever dropped), then the
        # controller must settle back on the base pilot
        deadline = time.monotonic() + phase_timeout
        while len(scenario.transitions) < len(scenario.steps) and time.monotonic() < deadline:
            time.sleep(0.05)
        assert len(scenario.transitions) == len(scenario.steps)
        deadline = time.monotonic() + phase_timeout
        while ctl.devices > 2 and time.monotonic() < deadline:
            time.sleep(0.1)
        assert ctl.devices == 2, f"did not shrink (events={list(ctl.events)})"
        assert ctl.events.of("scale_down")
        return svc, bus, ctl, stream, scenario
    finally:
        scenario.stop()
        src.stop()
        ctl.shutdown()
        stream.stop()
        svc.cancel()


def test_rate_step_triggers_scale_up_then_scale_down():
    policy = ThresholdHysteresisPolicy(high_lag=80, low_lag=15,
                                       up_stable=2, down_stable=3)
    config = ElasticConfig(interval=0.1, min_devices=2, max_devices=6,
                           devices_per_step=2, cooldown=1.2)
    svc, bus, ctl, stream, scenario = _run_rate_step(
        policy, [(1.0, 60), (4.5, 300), (20.0, 40)], config=config)

    up = ctl.events.of("scale_up")[0]
    assert up.devices_before == 2 and up.devices_after == 4
    # MetricsBus history shows the causal chain: lag crossed the high water
    # mark on the bus BEFORE the controller acted, and promptly
    highs = [(t, v) for t, v in bus.series("elastic.lag") if v > 80 and t <= up.t]
    assert highs, "scale-up without a high-lag observation on the bus"
    assert up.t - highs[0][0] <= 3.0, "reconcile reacted too slowly"
    # the extension (not the later rate drop) is what tamed the lag: history
    # shows it back under high water while the 2x rate was still applied
    t_rate_drop = scenario.transitions[2][0]
    recovered = [v for t, v in bus.series("elastic.lag") if up.t < t <= t_rate_drop]
    assert recovered and min(recovered) < 80, "lag not tamed before the rate dropped"
    # devices timeline went base -> extended -> base
    devs = [v for _, v in bus.series("elastic.devices")]
    assert max(devs) >= 4 and devs[-1] == 2
    # pool accounting is clean after churn: base engine + nothing leaked
    assert svc.pool.leased_devices == 0  # everything cancelled in teardown


@pytest.mark.slow
def test_rate_step_pid_policy_closed_loop():
    policy = PIDScalingPolicy(target_lag=40, lag_per_device=60.0, ki=0.05)
    config = ElasticConfig(interval=0.1, min_devices=2, max_devices=6,
                           devices_per_step=2, cooldown=1.2)
    _, bus, ctl, _, _ = _run_rate_step(
        policy, [(1.0, 60), (5.0, 300), (20.0, 40)], config=config)
    assert ctl.events.of("scale_up") and ctl.events.of("scale_down")
    devs = [v for _, v in bus.series("elastic.devices")]
    assert max(devs) >= 4 and devs[-1] == 2
